"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-style grad step on CPU; asserts output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model_zoo as zoo


def _batch_for(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.frontend_dim)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = zoo.init_params(cfg, seed=0)
    batch = _batch_for(cfg)
    logits, aux = zoo.forward_lm(params, cfg, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = zoo.init_params(cfg, seed=1)
    batch = _batch_for(cfg, seed=1)

    def loss_fn(p):
        loss, _ = zoo.lm_loss(p, cfg, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    # a simple SGD step keeps everything finite
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2, _ = zoo.lm_loss(new_params, cfg, batch)
    assert bool(jnp.isfinite(loss2))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """Prefill on S tokens then one decode step == forward on S+1 tokens."""
    cfg = get_config(arch, smoke=True)
    params = zoo.init_params(cfg, seed=2)
    B, S = 2, 12
    batch = _batch_for(cfg, B=B, S=S + 1, seed=2)
    full_logits, _ = zoo.forward_lm(params, cfg, batch)

    prompt = {**batch, "tokens": batch["tokens"][:, :S]}
    logits_p, caches = zoo.prefill(params, cfg, prompt, max_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full_logits[:, S - 1]),
        rtol=2e-2, atol=2e-2)

    logits_d, _ = zoo.decode_step(params, cfg, caches, batch["tokens"][:, S:S + 1])
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, S]),
        rtol=2e-2, atol=2e-2)
