"""Rate-adaptive uplink codec control: windowed SLA telemetry (the
`SLATracker.window` bugfix), rate-aware codec re-admission at replan
time, the (frontier x pool x codec) plan search, codec-migration
hysteresis, EF-residual flush at the swap, and executed-migration
counting on the full (assignment, codec) plan identity."""

import numpy as np
import pytest

from repro.core import codecs as cd
from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import Objective, place_frontier
from repro.core.sla import (SLA, UPLINK_RELAXED, UPLINK_SATURATED,
                            SLATracker, codec_candidates, pick_codec)
from repro.streams.generators import HyperplaneStream

LOOSE = SLA(max_latency_s=1e3, error_budget=11.0)   # only rate drives replans


def _pipe(dim=8):
    return pl.standard_stream_pipeline(dim=dim, sample_rate=0.5)


def _batches(n, dim=8, n_per=32, seed=0):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per)
    return [gen.batch(i, n_per) for i in range(n)]


# ---------------------------------------------------------------------------
# satellite bugfix: SLATracker honors `window`, violations age out
# ---------------------------------------------------------------------------

def test_sla_tracker_recovers_after_clean_stretch():
    """Regression: `window` used to be ignored (deques hardcoded
    maxlen=1000, violations/checks were lifetime counters), so ok()
    could never recover after an early violation burst."""
    t = SLATracker(SLA(max_latency_s=0.1), window=20)
    for _ in range(10):
        t.observe(0.5, 1e4)              # violation burst
    assert not t.ok()
    assert t.violation_rate == pytest.approx(1.0)
    for _ in range(20):                  # a full window of clean behavior
        t.observe(0.01, 1e4)
    assert t.violation_rate == 0.0
    assert t.ok(), "violations must age out of the window"
    # lifetime counters remain for audit
    assert t.violations == 10 and t.checks == 30


def test_sla_tracker_deques_honor_window():
    t = SLATracker(SLA(), window=5)
    for i in range(50):
        t.observe(0.01 * i, 100.0 + i)
    assert len(t.latencies) == 5 and len(t.throughputs) == 5
    assert list(t.throughputs) == [145.0, 146.0, 147.0, 148.0, 149.0]
    assert t.window_checks == 5


def test_sla_tracker_partial_window_rates():
    t = SLATracker(SLA(max_latency_s=0.1, min_throughput=50.0), window=100)
    t.observe(0.5, 100.0)                # latency violation only
    t.observe(0.01, 10.0)                # throughput violation only
    t.observe(0.01, 100.0)               # clean
    assert t.violation_rate == pytest.approx(2 / 3)
    assert t.latency_violation_rate == pytest.approx(1 / 3)
    assert t.throughput_violation_rate == pytest.approx(1 / 3)
    r = t.report()
    assert r["violation_rate"] == pytest.approx(2 / 3)
    assert r["window_checks"] == 3.0


def test_sla_tracker_rejects_nonpositive_window():
    with pytest.raises(ValueError, match="window"):
        SLATracker(SLA(), window=0)


def test_interleaved_trackers_recover_independently():
    """Multi-tenant telemetry: two trackers with DIFFERENT windows fed
    from one shared clock (the fleet's round-robin interleave) must keep
    fully independent state — a shared saturation burst ages out of each
    tracker at its own window, and one tenant's recovery never reads the
    other's history."""
    sla = SLA(max_latency_s=0.1)
    short = SLATracker(sla, window=10)
    long = SLATracker(sla, window=40)
    # shared clean warmup, then a shared 12-step saturation burst — the
    # same (latency, throughput) sample goes to both, as when one
    # congested uplink slows every tenant's round
    for _ in range(20):
        for t in (short, long):
            t.observe(0.01, 1e4)
    for _ in range(12):
        for t in (short, long):
            t.observe(0.5, 1e4)
    assert not short.ok() and not long.ok()
    assert short.violation_rate == pytest.approx(1.0)      # window=10 < burst
    # long window not yet full: 32 samples observed, 12 violating
    assert long.violation_rate == pytest.approx(12 / 32)
    # 10 clean interleaved rounds: the short window is fully clean and
    # recovers; the long window still carries the burst
    for _ in range(10):
        for t in (short, long):
            t.observe(0.01, 1e4)
    assert short.ok() and short.violation_rate == 0.0
    assert not long.ok()
    assert long.violation_rate == pytest.approx(12 / 40)
    # after enough rounds the long window ages the burst out too
    for _ in range(30):
        long.observe(0.01, 1e4)
    assert long.ok() and long.violation_rate == 0.0
    # the recovered short tracker was untouched by long's extra steps
    assert short.window_checks == 10 and short.violation_rate == 0.0
    # lifetime audit counters stay per-tenant
    assert short.violations == 12 and long.violations == 12
    assert short.checks == 42 and long.checks == 72


# ---------------------------------------------------------------------------
# satellite bugfix: observe() before initial_plan()
# ---------------------------------------------------------------------------

def test_observe_before_initial_plan_takes_initial_lazily():
    """Regression: observe() before initial_plan() raised IndexError on
    history[-1]; it now takes the initial plan lazily."""
    g = pl.fanout_stream_graph(dim=16)
    ctl = OffloadController(g.costs(), cm.ClusterSpec.edge_cloud(), graph=g)
    d = ctl.observe(step=7, rate=1e4)
    assert d.reason == "initial"
    assert d.step == 7
    assert ctl.history and ctl.migrations() == 0
    # and the controller proceeds normally afterwards
    d2 = ctl.observe(step=8, rate=1e4)
    assert d2.reason == "hold"


# ---------------------------------------------------------------------------
# rate-aware admission policy (sla.codec_candidates / pick_codec)
# ---------------------------------------------------------------------------

def test_pick_codec_without_report_is_static_admission():
    assert pick_codec(SLA(error_budget=11.0)).name == "topk_int8_ef"
    assert pick_codec(SLA(error_budget=0.0)).name == "identity"


def test_saturated_report_admits_full_escalation_ladder():
    names = [c.name for c in codec_candidates(
        SLA(error_budget=11.0), report={"uplink_utilization": 0.95,
                                        "violation_rate": 0.0})]
    assert names == ["identity", "int8_ef", "topk_ef", "topk_int8_ef"]
    # and the single-codec pick escalates to the cheapest wire
    c = pick_codec(SLA(error_budget=11.0),
                   report={"uplink_utilization": 1.5, "violation_rate": 0.0})
    assert c.name == "topk_int8_ef"


def test_relaxed_link_deescalates_to_lossless():
    c = pick_codec(SLA(error_budget=11.0),
                   report={"uplink_utilization": 0.1, "violation_rate": 0.0,
                           "codec": "topk_int8_ef"})
    assert c.name == "identity"


def test_nonbandwidth_violations_deescalate_even_in_dead_band():
    """Latency violations with an unsaturated link come from compute/
    staleness, not bandwidth — compression is not buying anything, go
    lossless. (A bare report without per-cause rates falls back to the
    aggregate violation_rate.)"""
    c = pick_codec(SLA(error_budget=11.0),
                   report={"uplink_utilization": 0.7, "violation_rate": 0.2,
                           "latency_violation_rate": 0.2,
                           "codec": "topk_int8_ef"})
    assert c.name == "identity"
    bare = pick_codec(SLA(error_budget=11.0),
                      report={"uplink_utilization": 0.7,
                              "violation_rate": 0.2,
                              "codec": "topk_int8_ef"})
    assert bare.name == "identity"


def test_throughput_violations_do_not_force_lossless():
    """Regression: throughput violations are bandwidth symptoms — in the
    dead band they must KEEP the incumbent lossy codec (de-escalating
    would starve the wire harder), not de-escalate to lossless."""
    cands = codec_candidates(
        SLA(error_budget=11.0),
        report={"uplink_utilization": 0.7, "violation_rate": 0.2,
                "latency_violation_rate": 0.0,
                "throughput_violation_rate": 0.2,
                "codec": "topk_int8_ef"})
    assert [c.name for c in cands] == ["topk_int8_ef"]


def test_dead_band_keeps_the_incumbent_codec():
    mid = (UPLINK_RELAXED + UPLINK_SATURATED) / 2
    for inc in ("int8_ef", "topk_ef"):
        cands = codec_candidates(
            SLA(error_budget=11.0),
            report={"uplink_utilization": mid, "violation_rate": 0.0,
                    "codec": inc})
        assert [c.name for c in cands] == [inc]


def test_rate_aware_admission_never_exceeds_budget():
    """Acceptance invariant: telemetry can narrow the candidate set but
    never admit past the error budget."""
    reports = [None,
               {"uplink_utilization": 5.0, "violation_rate": 0.0},
               {"uplink_utilization": 0.0, "violation_rate": 1.0},
               {"uplink_utilization": 0.7, "violation_rate": 0.0,
                "codec": "topk_int8_ef"}]
    for budget in np.linspace(0.0, 12.0, 25):
        sla = SLA(error_budget=float(budget))
        for rep in reports:
            for c in codec_candidates(sla, report=rep):
                assert c.error_bound <= budget + 1e-12, (budget, rep, c.name)
            assert pick_codec(sla, report=rep).error_bound <= budget + 1e-12


# ---------------------------------------------------------------------------
# the codec as a searched plan dimension (placement)
# ---------------------------------------------------------------------------

def test_codec_search_restores_feasibility_under_saturation():
    """At a rate where every identity-codec plan over-runs the uplink,
    the (frontier x pool x codec) search must find a feasible lossy
    plan and record the codec it was priced under."""
    g = _pipe(dim=8)
    spec = cm.ClusterSpec.edge_cloud()
    rate = 8e7
    ident, _ = place_frontier(g, spec, rate, codecs=["identity"])
    assert not ident.feasible, "ramp rate must saturate the identity uplink"
    plan, frontier = place_frontier(
        g, spec, rate, codecs=["identity", "int8_ef", "topk_int8_ef"])
    assert plan.feasible
    assert plan.uplink_codec in ("int8_ef", "topk_int8_ef")
    assert plan.uplink_utilization < 1.0


def test_codec_search_ties_resolve_toward_first_candidate():
    """With no uplink pressure the scores differ only by the tiny
    uplink term; candidates are passed most-faithful-first so a lossy
    codec must EARN its place via the score, and identity-only search
    stays identical to the historical behavior."""
    g = _pipe(dim=8)
    spec = cm.ClusterSpec.edge_cloud()
    plan, _ = place_frontier(g, spec, 1e3, codecs=["identity"])
    assert plan.uplink_codec == "identity"
    base, _ = place_frontier(g, spec, 1e3)
    assert base.uplink_codec is None
    assert base.assignment == plan.assignment
    assert base.latency_s == pytest.approx(plan.latency_s)


# ---------------------------------------------------------------------------
# controller: codec escalation/de-escalation with hysteresis
# ---------------------------------------------------------------------------

def _ramp_controller(**kw):
    g = _pipe(dim=8)
    return OffloadController(g.costs(), cm.ClusterSpec.edge_cloud(), graph=g,
                             codec="topk_int8_ef", sla_spec=LOOSE, **kw)


def test_controller_deescalates_and_reescalates_once_each():
    rates = [8e7] * 10 + [1e4] * 10 + [8e7] * 10
    ctl = _ramp_controller()
    ctl.initial_plan(rates[0])
    for step, r in enumerate(rates):
        ctl.observe(step, r)
    codecs = [d.codec for d in ctl.history]
    changes = [(a, b) for a, b in zip(codecs, codecs[1:]) if a != b]
    assert changes == [("topk_int8_ef", "identity"),
                       ("identity", "topk_int8_ef")], codecs


def test_codec_cooldown_blocks_flapping():
    """Within codec_cooldown decisions of a swap, replans keep the
    incumbent codec even when admission would change it."""
    rates = [8e7] * 3 + [1e4] * 3 + [8e7] * 3 + [1e4] * 3
    ctl = _ramp_controller(cooldown=1, codec_cooldown=100)
    ctl.initial_plan(rates[0])
    for step, r in enumerate(rates):
        ctl.observe(step, r)
    codecs = {d.codec for d in ctl.history}
    assert codecs == {"topk_int8_ef"}, (
        "codec_cooldown must pin the codec through the oscillation")


def test_codec_change_is_a_plan_identity_change():
    """Plan identity keys on (assignment, codec): a codec-only swap
    counts as a migration even when the frontier never moves."""
    ctl = _ramp_controller(cooldown=1, codec_cooldown=1)
    ctl.initial_plan(1e4)        # low rate, lossy incumbent
    d = ctl.observe(1, 3e4)      # out of band -> replan -> de-escalate
    assert d.codec == "identity"
    assert d.frontier == ctl.history[0].frontier
    assert ctl.migrations() == 1


def test_fixed_codec_controller_unchanged_without_sla_spec():
    """No sla_spec -> the historical fixed-codec behavior: the codec is
    pinned no matter what the rate does."""
    g = _pipe(dim=8)
    ctl = OffloadController(g.costs(), cm.ClusterSpec.edge_cloud(), graph=g,
                            codec="int8_ef", cooldown=1)
    assert not ctl._adaptive
    ctl.initial_plan(1e4)
    for step, r in enumerate([8e7, 1e3, 8e7, 1e3], start=1):
        d = ctl.observe(step, r)
        assert d.codec == "int8_ef"


def test_user_declared_link_codec_survives_adaptive_replans():
    """A per-link codec the user declared is pinned: the blanket
    candidate fills only undeclared uplinks (with_uplink_codec default),
    so adaptive control cannot override an explicit topology choice."""
    spec = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=1e9, latency=20e-3,
                       codec="int8_ef")])
    g = _pipe(dim=8)
    ctl = OffloadController(g.costs(), spec, graph=g, codec="int8_ef",
                            sla_spec=SLA(max_latency_s=1e3, error_budget=0.1),
                            cooldown=1, codec_cooldown=1)
    ctl.initial_plan(1e4)
    d = ctl.observe(1, 8e7)
    # the declared link keeps int8_ef regardless of the blanket pick
    spec2 = ctl.resources.with_uplink_codec(d.codec)
    assert spec2.link("edge", "cloud").codec == "int8_ef"


# ---------------------------------------------------------------------------
# orchestrator: live codec migration, EF-residual flush, executed
# migration counting on full plan identity
# ---------------------------------------------------------------------------

def test_swap_codec_flushes_stale_residuals():
    orch = Orchestrator(StreamJob("swap", dim=8, sla=LOOSE))
    assert orch.codec.name == "topk_int8_ef"
    orch._uplink_residuals["x"] = np.ones((4, 8), np.float32)
    orch._swap_codec("int8_ef", step=3)
    assert orch.codec.name == "int8_ef"
    assert orch._uplink_residuals == {}, (
        "a stale residual from the old codec must not leak into the new")
    assert any(d == "3:codec topk_int8_ef->int8_ef"
               for d in orch.metrics.decisions)
    # swapping to identity tears the wire transform down entirely
    orch._uplink_residuals["x"] = np.ones((4, 8), np.float32)
    orch._swap_codec("identity", step=9)
    assert orch._uplink_residuals == {} and orch._uplink is None


def test_orchestrated_ramp_escalates_codec_once_each_way():
    """The satellite system test: a saturating rate ramp drives a live
    codec escalation and back at migration boundaries — no restart,
    exactly one codec migration each way, never over budget."""
    rates = [8e7] * 10 + [1e4] * 10 + [8e7] * 10
    job = StreamJob("ramp", dim=8, sla=LOOSE)
    orch = Orchestrator(job)
    m = orch.run(_batches(30), rate_fn=lambda s: rates[min(s, len(rates) - 1)])
    assert m.codec == "topk_int8_ef"          # the initial admission pick
    changes = [(a, b) for a, b in zip(m.codecs, m.codecs[1:]) if a != b]
    assert changes == [("topk_int8_ef", "identity"),
                       ("identity", "topk_int8_ef")], m.codecs
    # codec migrations land at replan boundaries, visible in decisions
    assert sum(1 for d in m.decisions if ":codec " in d) == 2
    # never admits over budget (acceptance)
    for name in set(m.codecs):
        assert cd.get_codec(name).error_bound <= job.sla.error_budget + 1e-12
    # the run ends lossy: residuals are live again after the last swap
    assert orch._uplink_residuals


def test_orchestrated_ramp_ending_lossless_leaves_no_residuals():
    """After the de-escalation swap the EF residuals are flushed and the
    identity codec never reseeds them — stale carry cannot survive a
    codec migration."""
    rates = [8e7] * 10 + [1e4] * 10
    orch = Orchestrator(StreamJob("down", dim=8, sla=LOOSE))
    m = orch.run(_batches(20), rate_fn=lambda s: rates[min(s, len(rates) - 1)])
    assert m.codecs[-1] == "identity"
    assert "identity" not in m.codecs[:5]      # it did start lossy
    assert orch._uplink_residuals == {}


def test_executed_migrations_count_codec_only_changes():
    """Satellite: executed-migration counting keys on the full
    (assignment, codec) identity, not the frontier view — a codec swap
    with an unmoved frontier still counts."""
    rates = [1e4] * 10 + [3e4] * 6         # small rate step: frontier holds
    job = StreamJob("idkey", dim=8, sla=LOOSE)
    orch = Orchestrator(job)
    m = orch.run(_batches(16), rate_fn=lambda s: rates[min(s, len(rates) - 1)])
    frontier_changes = sum(1 for a, b in zip(m.assignments, m.assignments[1:])
                           if a != b)
    assert frontier_changes == 0, "the frontier view must not move here"
    assert m.codecs[0] == "topk_int8_ef" and m.codecs[-1] == "identity"
    assert m.migrations == 1, (
        "the codec-only swap is a plan-identity change and must be counted")
    assert len(m.plan_identities) == len(m.codecs) == 16


def test_windowed_sla_recovers_within_an_orchestrated_run():
    """Acceptance: a windowed-clean SLA report returns ok()==True after
    earlier violations age out — inside a live run, with the tracker
    window wired through StreamJob."""
    # 30s latency budget: no real batch on any machine comes close, so
    # the only violations are the seeded burst below (deterministic)
    job = StreamJob("win", dim=8, sla=SLA(max_latency_s=30.0), sla_window=8)
    orch = Orchestrator(job)
    # an earlier violation burst on the tracker the run inherits (the
    # deterministic stand-in for a compile/stall stretch)
    for _ in range(5):
        orch.sla.observe(100.0, 1e4)
    assert not orch.sla.ok()
    orch.run(_batches(30), rate_fn=lambda s: 1e4)
    assert orch.sla.violations == 5
    assert orch.sla.ok(), "clean stretch must age the violations out"


def test_adaptive_ramp_identity_budget_stays_bitwise():
    """The PR 3 invariant survives the new control dimension: under a
    zero error budget the candidate set is exactly [identity], so a
    rate-ramp run (partition migrating!) stays bitwise-identical to the
    pinned all-cloud reference."""
    rates = [8e7] * 6 + [1e4] * 6
    data = _batches(12, n_per=16)
    a = Orchestrator(StreamJob("a", dim=8, sla=SLA(max_latency_s=1e3))).run(
        data, rate_fn=lambda s: rates[min(s, len(rates) - 1)],
        record_outputs=True)
    assert set(a.codecs) == {"identity"}
    b = Orchestrator(StreamJob("b", dim=8, sla=SLA(max_latency_s=1e3))).run(
        data, rate_fn=lambda s: rates[min(s, len(rates) - 1)],
        fixed_cut=0, record_outputs=True)
    assert b.migrations == 0, "a pinned reference run executes 0 migrations"
    for x, y in zip(a.outputs, b.outputs):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])
