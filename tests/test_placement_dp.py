"""Polynomial DP placement (ROADMAP item 5): differential tests of
``place_frontier_dp`` against the exhaustive oracles, the dispatch
policy, the exhaustive-oracle size caps, codec tie/dedup regressions,
placement edge cases, and the measured-operator-cost loop."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import placement as P
from repro.core import selftune
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.pipeline import Op, OpGraph, fanout_stream_graph
from repro.core.placement import (Objective, frontier_plans, place_exhaustive,
                                  place_frontier, place_frontier_dp,
                                  place_graph_exhaustive)
from repro.core.sla import SLA
from repro.streams.generators import HyperplaneStream

OBJ = Objective()


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------

def _noop(s, b):
    return s, {}


def random_graph(rng, n_ops):
    """Random DAG: op j reads 1-3 random earlier channels (30% also the
    source), 80% edge-capable."""
    ops = []
    for j in range(n_ops):
        reads = ["src"] if j == 0 else None
        if j > 0:
            k = rng.integers(1, min(j, 3) + 1)
            parents = sorted(rng.choice(j, size=k, replace=False).tolist())
            reads = [f"k{i}" for i in parents]
            if rng.random() < 0.3:
                reads.append("src")
        cost = cm.OperatorCost(
            name=f"op{j}",
            flops_per_event=float(rng.integers(10, 10**7)),
            bytes_per_event=float(rng.integers(8, 4096)),
            out_bytes_per_event=float(rng.integers(1, 2048)),
            edge_capable=bool(rng.random() < 0.8),
        )
        ops.append(Op(name=f"op{j}", fn=_noop, init=dict,
                      reads=reads, writes=[f"k{j}"], cost=cost))
    return OpGraph(ops)


def multipool_spec(codec=None):
    """2 edge pools / 2 cloud pods with declared (partly lossy) links."""
    pools = {
        "edge_a": cm.Resource("edge_a", "edge", chips=1, flops=2e12,
                              mem_bw=4e11, mem_cap=8e9, net_bw=1e9,
                              energy_w=30.0),
        "edge_b": cm.Resource("edge_b", "edge", chips=1, flops=1e12,
                              mem_bw=2e11, mem_cap=4e9, net_bw=5e8,
                              energy_w=15.0),
        "cloud": cm.Resource("cloud", "cloud", chips=4, flops=5e12,
                             mem_bw=8e11, mem_cap=32e9, net_bw=1e10,
                             energy_w=300.0),
        "cloud_b": cm.Resource("cloud_b", "cloud", chips=8, flops=5e12,
                               mem_bw=8e11, mem_cap=64e9, net_bw=1e10,
                               energy_w=500.0),
    }
    links = [cm.Link("edge_a", "cloud", bw=2e8, latency=0.03),
             cm.Link("edge_b", "cloud", bw=1e8, latency=0.05),
             cm.Link("edge_a", "edge_b", bw=5e8, latency=0.005)]
    spec = cm.ClusterSpec(pools, links=links)
    if codec:
        spec = spec.with_uplink_codec(codec)
    return spec


def chain_graph(n_ops, edge_capable_all=True):
    ops = []
    for j in range(n_ops):
        cost = cm.OperatorCost(f"op{j}", 1e4 * (j + 1), 256.0, 128.0,
                               edge_capable=edge_capable_all or j != 0)
        ops.append(Op(name=f"op{j}", fn=_noop, init=dict,
                      reads=["src"] if j == 0 else [f"k{j - 1}"],
                      writes=[f"k{j}"], cost=cost))
    return OpGraph(ops)


# ---------------------------------------------------------------------------
# tentpole: DP == oracle / enumeration
# ---------------------------------------------------------------------------

def test_dp_matches_full_oracle_on_random_dags():
    """DP score matches the all-assignments oracle (which also searches
    non-frontier placements — frontier optimality within the lattice is
    all the search promises, so compare through the enumeration's best
    frontier plan AND check it against the full oracle's)."""
    rng = np.random.default_rng(0)
    spec = multipool_spec()
    for seed in range(12):
        g = random_graph(np.random.default_rng(seed), 2 + seed % 4)
        rate = float(rng.choice([1e3, 1e4, 1e5]))
        plan_dp, frontier_dp = place_frontier_dp(g, spec, rate, OBJ)
        plan_en, frontier_en = place_frontier(g, spec, rate, OBJ,
                                              method="enumerate")
        assert plan_dp.assignment == plan_en.assignment, f"seed {seed}"
        assert frontier_dp == frontier_en, f"seed {seed}"
        oracle = place_graph_exhaustive(g, spec, rate, OBJ)
        best_frontier = min((p for _, p in frontier_plans(g, spec, rate, OBJ)),
                            key=OBJ.score)
        assert OBJ.score(plan_dp) <= OBJ.score(best_frontier) * 1.0001
        # oracle may beat the lattice (non-downward-closed assignment);
        # never the other way around
        assert OBJ.score(oracle) <= OBJ.score(plan_dp) * 1.0001


def test_dp_matches_enumeration_8ops_plan_identical():
    spec = multipool_spec()
    for seed in (3, 11, 27):
        g = random_graph(np.random.default_rng(seed), 8)
        plan_dp, frontier_dp = place_frontier_dp(g, spec, 2e4, OBJ)
        plan_en, frontier_en = place_frontier(g, spec, 2e4, OBJ,
                                              method="enumerate")
        assert plan_dp.assignment == plan_en.assignment, f"seed {seed}"
        assert frontier_dp == frontier_en
        assert plan_dp.uplink_codec == plan_en.uplink_codec
        assert OBJ.score(plan_dp) == pytest.approx(OBJ.score(plan_en))


def test_dp_codec_ladder_matches_enumeration():
    """With codec candidates the winning (frontier, pools, codec) triple
    is identical between engines, including the tie direction."""
    spec = multipool_spec()
    codecs = ["topk_int8_ef", "identity", "int8_ef"]   # adverse order
    for seed in (1, 5, 9, 16):
        g = random_graph(np.random.default_rng(seed), 3 + seed % 4)
        plan_dp, f_dp = place_frontier_dp(g, spec, 5e4, OBJ, codecs)
        plan_en, f_en = place_frontier(g, spec, 5e4, OBJ, codecs,
                                       method="enumerate")
        assert plan_dp.assignment == plan_en.assignment, f"seed {seed}"
        assert plan_dp.uplink_codec == plan_en.uplink_codec, f"seed {seed}"
        assert f_dp == f_en


def test_dp_small_cases_certified_exact():
    """On differential-test sizes the label fronts are far below the
    width cap: the sweep is exhaustive and says so via ``truncated``."""
    spec = multipool_spec()
    g = random_graph(np.random.default_rng(2), 6)
    stats = {}
    place_frontier_dp(g, spec, 1e4, OBJ, stats=stats)
    assert stats["truncated"] is False
    assert 0 < stats["labels_peak"] <= 4096
    assert stats["labels_expanded"] > 0


def test_dp_beam_degrades_loudly_not_silently():
    """A tiny ``max_labels`` clips the exact sweep — the result is still
    a valid plan but ``truncated`` flags that optimality is no longer
    certified."""
    spec = multipool_spec()
    g = random_graph(np.random.default_rng(0), 8)
    stats = {}
    plan, frontier = place_frontier_dp(g, spec, 1e4, OBJ, max_labels=1,
                                       stats=stats)
    assert stats["truncated"] is True
    assert set(plan.assignment) == set(g.names)
    exact, _ = place_frontier_dp(g, spec, 1e4, OBJ)
    assert OBJ.score(exact) <= OBJ.score(plan) * 1.0001


# ---------------------------------------------------------------------------
# dispatch policy
# ---------------------------------------------------------------------------

def test_auto_dispatch_small_graph_stays_on_enumeration(monkeypatch):
    def boom(*a, **k):  # pragma: no cover - would mean dispatch is wrong
        raise AssertionError("DP must not run for a small graph")
    monkeypatch.setattr(P, "place_frontier_dp", boom)
    g = fanout_stream_graph(dim=8)
    plan, frontier = place_frontier(g, multipool_spec(), 1e4, OBJ)
    assert set(plan.assignment) == set(g.names)


def test_auto_dispatch_large_graph_routes_to_dp(monkeypatch):
    calls = {}
    real = P.place_frontier_dp

    def spy(*a, **k):
        calls["dp"] = True
        return real(*a, **k)
    monkeypatch.setattr(P, "place_frontier_dp", spy)
    g = chain_graph(30)
    plan, frontier = place_frontier(g, multipool_spec(), 1e4, OBJ)
    assert calls.get("dp") is True
    assert set(plan.assignment) == set(g.names)
    # and the explicit engines agree on what they return
    plan_dp, f_dp = real(g, multipool_spec(), 1e4, OBJ)
    assert plan_dp.assignment == plan.assignment
    assert f_dp == frontier


def test_method_validation():
    g = fanout_stream_graph(dim=8)
    with pytest.raises(ValueError, match="method"):
        place_frontier(g, multipool_spec(), 1e4, OBJ, method="guess")


# ---------------------------------------------------------------------------
# satellite: exhaustive-oracle size caps
# ---------------------------------------------------------------------------

def test_place_exhaustive_size_cap():
    ops = [cm.OperatorCost(f"s{i}", 1e3, 64, 32) for i in range(40)]
    with pytest.raises(ValueError, match=r"would enumerate .*~1e"):
        place_exhaustive(ops, {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD},
                         1e4, OBJ)
    # explicit opt-in raises the cap
    plan = place_exhaustive(ops[:4],
                            {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD},
                            1e4, OBJ, max_states=100)
    assert set(plan.assignment) == {f"s{i}" for i in range(4)}


def test_place_graph_exhaustive_size_cap():
    g = chain_graph(40)
    with pytest.raises(ValueError, match="max_states"):
        place_graph_exhaustive(g, multipool_spec(), 1e4, OBJ)
    small = chain_graph(3)
    plan = place_graph_exhaustive(small, multipool_spec(), 1e4, OBJ,
                                  max_states=1000)
    assert set(plan.assignment) == set(small.names)


# ---------------------------------------------------------------------------
# satellite: codec dedup + most-faithful ties
# ---------------------------------------------------------------------------

def test_frontier_plans_no_duplicate_frontiers_under_codec_ties():
    """When every uplink declares its own codec, the blanket candidates
    collapse to one effective spec: each frontier must appear exactly
    once (the historical bug yielded one duplicate plan per redundant
    candidate)."""
    spec = multipool_spec(codec="int8_ef")    # every uplink now declared
    g = random_graph(np.random.default_rng(7), 4)
    plans = list(frontier_plans(g, spec, 1e4, OBJ,
                                codecs=["topk_int8_ef", "int8_ef",
                                        "identity"]))
    frontiers = [f for f, _ in plans]
    assert len(frontiers) == len(set(frontiers))
    assert len(frontiers) == sum(1 for _ in g.frontiers())


def test_codec_score_ties_resolve_most_faithful_first():
    """A plan with no uplink crossing scores identically under every
    codec: both engines must pick the most faithful candidate, whatever
    order the candidates were passed in."""
    g = chain_graph(3)
    # roomy edge: everything fits on the edge pool, no crossing
    edge = cm.Resource("edge", "edge", chips=4, flops=1e13, mem_bw=8e11,
                       mem_cap=64e9, net_bw=1e10, energy_w=10.0)
    spec = cm.ClusterSpec(pools=[edge, cm.CLOUD_POD])
    for method in ("enumerate", "dp"):
        plan, frontier = place_frontier(
            g, spec, 1e3, OBJ, codecs=["topk_int8_ef", "int8_ef", "identity"],
            method=method)
        assert frontier == frozenset(g.names), method
        assert plan.uplink_codec == "identity", method


def test_unknown_codec_name_raises():
    g = chain_graph(3)
    for method in ("enumerate", "dp"):
        with pytest.raises(ValueError):
            place_frontier(g, multipool_spec(), 1e4, OBJ,
                           codecs=["no_such_codec"], method=method)


# ---------------------------------------------------------------------------
# satellite: placement edge cases
# ---------------------------------------------------------------------------

def test_single_kind_cluster_raises_for_both_engines():
    g = chain_graph(3)
    edge_only = cm.ClusterSpec(pools=[cm.EDGE_NODE])
    cloud_only = cm.ClusterSpec(pools=[cm.CLOUD_POD])
    for spec in (edge_only, cloud_only):
        for method in ("enumerate", "dp"):
            with pytest.raises(ValueError, match="at least one"):
                place_frontier(g, spec, 1e4, OBJ, method=method)
        with pytest.raises(ValueError, match="at least one"):
            place_frontier_dp(g, spec, 1e4, OBJ)


def test_disconnected_components_agree():
    """Two source-only chains share no channels: the frontier lattice is
    a product of the per-component lattices and both engines walk it to
    the same plan."""
    ops = []
    for comp in ("a", "b"):
        for j in range(3):
            cost = cm.OperatorCost(f"{comp}{j}", 5e3 * (j + 1), 128, 64)
            ops.append(Op(name=f"{comp}{j}", fn=_noop, init=dict,
                          reads=["src"] if j == 0 else [f"{comp}k{j - 1}"],
                          writes=[f"{comp}k{j}"], cost=cost))
    g = OpGraph(ops)
    spec = multipool_spec()
    plan_dp, f_dp = place_frontier_dp(g, spec, 1e4, OBJ)
    plan_en, f_en = place_frontier(g, spec, 1e4, OBJ, method="enumerate")
    assert plan_dp.assignment == plan_en.assignment
    assert f_dp == f_en


def test_edge_incapable_root_forces_all_cloud():
    """If the DAG's root op cannot run on the edge, downward-closure
    makes the empty frontier the only feasible one — both engines must
    find it rather than an infeasible edge placement."""
    g = chain_graph(4, edge_capable_all=False)   # op0 edge_capable=False
    spec = multipool_spec()
    for method in ("enumerate", "dp"):
        plan, frontier = place_frontier(g, spec, 1e4, OBJ, method=method)
        assert frontier == frozenset(), method
        assert plan.feasible, method
        assert all(spec[p].kind == "cloud"
                   for p in plan.assignment.values()), method


# ---------------------------------------------------------------------------
# controller integration
# ---------------------------------------------------------------------------

def _controller(method):
    g = fanout_stream_graph(dim=8)
    sla = SLA(max_latency_s=1e3, error_budget=11.0)
    return OffloadController(g.costs(), multipool_spec(), graph=g,
                             codec="topk_int8_ef", sla_spec=sla,
                             cooldown=1, codec_cooldown=1,
                             placement_method=method)


def test_controller_defaults_to_dp():
    g = fanout_stream_graph(dim=8)
    ctl = OffloadController(g.costs(), multipool_spec(), graph=g)
    assert ctl.placement_method == "dp"


def test_controller_dp_vs_enumerate_identical_histories():
    """The DP default must not change a single control decision: same
    rate trace -> same assignments, codecs, reasons, migration count."""
    ctls = {m: _controller(m) for m in ("dp", "enumerate")}
    rates = [5e6, 1e3, 5e6, 1e3, 5e6, 2e4, 5e6, 1e3]
    for ctl in ctls.values():
        ctl.initial_plan(5e6)
        for step, rate in enumerate(rates):
            ctl.observe(step, rate)
    dp, en = ctls["dp"], ctls["enumerate"]
    assert dp.migrations() == en.migrations()
    assert [(d.reason, d.codec, tuple(sorted(d.assignment.items())))
            for d in dp.history] == \
           [(d.reason, d.codec, tuple(sorted(d.assignment.items())))
            for d in en.history]


# ---------------------------------------------------------------------------
# measured operator costs (self-tuning loop)
# ---------------------------------------------------------------------------

def _batch(dim=8, n=32, seed=0):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n)
    b = gen.batch(0, n)
    bd = {k: jnp.asarray(v) for k, v in b.data.items()}
    bd["rng"] = __import__("jax").random.PRNGKey(0)
    return bd


def test_measure_operator_costs_measures_and_preserves_flags():
    g = fanout_stream_graph(dim=8)
    measured, notes = selftune.measure_operator_costs(g, _batch())
    assert measured, f"nothing measured (notes: {notes})"
    declared = {op.name: op.cost for op in g.ops}
    for name, c in measured.items():
        assert c.flops_per_event > 0
        assert c.bytes_per_event > 0
        assert c.edge_capable == declared[name].edge_capable
    if "drift" in measured:
        assert measured["drift"].edge_capable is False


def test_set_measured_costs_validates_and_clears():
    g = fanout_stream_graph(dim=8)
    declared = g.costs()
    with pytest.raises(ValueError, match="unknown ops"):
        g.set_measured_costs({"ghost": declared[0]})
    # install an override, see it in costs(), clear it back
    from dataclasses import replace
    g.set_measured_costs({"normalize": replace(declared[0],
                                               flops_per_event=123.0,
                                               edge_capable=False)})
    assert g.cost_of("normalize").flops_per_event == 123.0
    # semantic flag survives the override
    assert g.cost_of("normalize").edge_capable is True
    g.set_measured_costs(None)
    assert g.cost_of("normalize").flops_per_event == \
        declared[0].flops_per_event


def test_orchestrator_measured_costs_end_to_end():
    gen = HyperplaneStream(dim=8, seed=1, horizon=96)
    batches = [gen.batch(i, 32) for i in range(3)]
    job = StreamJob("measured", dim=8, cluster=multipool_spec(),
                    measured_costs=True)
    m = Orchestrator(job).run(batches)
    assert any(d.startswith("0:measured-costs") for d in m.decisions), \
        m.decisions
    assert m.events == 96


# ---------------------------------------------------------------------------
# per-link energy model: the DP mirrors the evaluator's energy term
# ---------------------------------------------------------------------------

def energy_spec(epb_scale: float) -> cm.ClusterSpec:
    """The multipool topology with per-link transmit energy declared
    (different joules/byte per link, scaled by epb_scale)."""
    base = multipool_spec()
    links = [cm.Link("edge_a", "cloud", bw=2e8, latency=0.03,
                     energy_per_byte=3e-7 * epb_scale),
             cm.Link("edge_b", "cloud", bw=1e8, latency=0.05,
                     energy_per_byte=8e-7 * epb_scale),
             cm.Link("edge_a", "edge_b", bw=5e8, latency=0.005,
                     energy_per_byte=1e-7 * epb_scale)]
    return cm.ClusterSpec(dict(base.pools), links=links)


def test_dp_matches_enumeration_under_link_energy():
    """With energy_per_byte on the links AND an energy-weighted
    objective, DP and enumeration must still be plan-identical — the DP
    tables mirror the evaluator's link-energy arithmetic exactly."""
    obj = Objective(latency_weight=1.0, energy_weight=25.0)
    for scale in (0.0, 1.0, 100.0):
        spec = energy_spec(scale)
        for seed in (1, 5, 13, 21):
            g = random_graph(np.random.default_rng(seed), 6)
            plan_dp, f_dp = place_frontier_dp(g, spec, 1e4, obj)
            plan_en, f_en = place_frontier(g, spec, 1e4, obj,
                                           method="enumerate")
            assert plan_dp.assignment == plan_en.assignment, \
                f"scale {scale} seed {seed}"
            assert f_dp == f_en
            assert obj.score(plan_dp) == pytest.approx(obj.score(plan_en))
            assert plan_dp.energy_w == pytest.approx(plan_en.energy_w)


def test_dp_energy_term_matches_evaluator_repricing():
    """The DP's internal energy accumulation must agree with pricing its
    winning assignment through evaluate_graph_plan (the differential
    oracle for the satellite's new energy term)."""
    spec = energy_spec(10.0)
    obj = Objective(latency_weight=1.0, energy_weight=25.0)
    for seed in (2, 9):
        g = random_graph(np.random.default_rng(seed), 7)
        plan_dp, _ = place_frontier_dp(g, spec, 1e4, obj)
        repriced = cm.evaluate_graph_plan(
            g.costs(), g.flow_edges, plan_dp.assignment, spec, 1e4,
            source_consumers=g.source_consumers,
            source_bytes=g.source_bytes_per_event)
        assert plan_dp.energy_w == pytest.approx(repriced.energy_w)
