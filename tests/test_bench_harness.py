"""Bench harness + perf-trajectory gate: the --smoke/--out JSON contract
and compare.py's regression semantics (these are CI's perf guardrails, so
they get the same test coverage as product code)."""

import json

import numpy as np
import pytest

from benchmarks import compare as bench_compare
from benchmarks import run as bench_run


def _snapshot(rows):
    """A synthetic s2ce-bench/1 document."""
    return {"schema": bench_run.BENCH_SCHEMA, "git_sha": "deadbee",
            "backend": "cpu", "jax_version": "0.0.0",
            "rows": [{"name": n, "median_us": m, "p90_us": m * 1.2,
                      "iters": 5, "units": u, "bytes": None}
                     for n, m, u in rows]}


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


# ---------------------------------------------------------------------------
# run.py: BenchStat / --smoke / --only / --out
# ---------------------------------------------------------------------------

def test_benchstat_is_a_float_with_stats():
    s = bench_run.BenchStat(10.0, p90_us=14.0, iters=7, nbytes=64)
    assert float(s) == 10.0 and s + 1 == 11.0       # old call sites work
    assert f"{s:.2f}" == "10.00"
    assert s.p90_us == 14.0 and s.iters == 7 and s.nbytes == 64
    bare = bench_run.BenchStat(3.5)                 # manual-timer rows
    assert bare.p90_us == 3.5 and bare.iters == 1 and bare.nbytes is None


def test_timeit_returns_sampled_stat():
    s = bench_run._timeit(lambda x: x + 1, 41, warmup=1, iters=5, nbytes=8)
    assert isinstance(s, bench_run.BenchStat)
    assert s > 0 and s.p90_us >= s and s.iters == 5 and s.nbytes == 8


def test_smoke_only_out_writes_schema(tmp_path):
    out = tmp_path / "BENCH_test.json"
    rc = bench_run.main(["--smoke", "--only", "sketch", "--out", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["schema"] == bench_run.BENCH_SCHEMA
    assert doc["backend"] and doc["jax_version"] and doc["git_sha"]
    assert "timestamp" not in doc                   # determinism by design
    assert len(doc["rows"]) >= 1
    for row in doc["rows"]:
        assert set(row) == {"name", "median_us", "p90_us", "iters",
                            "units", "bytes"}
        assert row["median_us"] > 0 and row["iters"] >= 1
        assert isinstance(row["name"], str) and isinstance(row["units"], str)


def test_out_is_deterministic_modulo_timings(tmp_path):
    a, b = tmp_path / "a.json", tmp_path / "b.json"
    assert bench_run.main(["--smoke", "--only", "sketch", "--out", str(a)]) == 0
    assert bench_run.main(["--smoke", "--only", "sketch", "--out", str(b)]) == 0
    da, db = json.loads(a.read_text()), json.loads(b.read_text())
    strip = lambda d: {**d, "rows": [
        {k: v for k, v in r.items()
         if k not in ("median_us", "p90_us", "units")} for r in d["rows"]]}
    assert strip(da) == strip(db)                   # only timings may differ
    assert [r["name"] for r in da["rows"]] == [r["name"] for r in db["rows"]]


def test_only_filter_unknown_name_runs_nothing(tmp_path):
    out = tmp_path / "empty.json"
    rc = bench_run.main(["--smoke", "--only", "no_such_bench",
                         "--out", str(out)])
    assert rc == 0
    assert json.loads(out.read_text())["rows"] == []


def test_committed_baseline_is_valid_and_covers_smoke():
    """The committed trajectory point must stay loadable, well-formed,
    and >= 10 smoke rows (the gate is meaningless on a thin baseline)."""
    doc = bench_compare.load(bench_compare.latest_baseline())
    assert doc["schema"] == bench_run.BENCH_SCHEMA
    rows = doc["rows"]
    assert len(rows) >= 10
    names = [r["name"] for r in rows]
    assert len(names) == len(set(names))            # names are the join key
    for must in ("sketch_countmin_8192", "kernel_ef_int8_64k",
                 "pipeline_step_cut4", "pipeline_step_cut4_xla"):
        assert must in names


# ---------------------------------------------------------------------------
# compare.py: gate semantics
# ---------------------------------------------------------------------------

BASE = [("fast_row", 10.0, "x"), ("slow_row", 1000.0, "y"),
        ("other_row", 500.0, "z")]


def test_compare_passes_identical_replay(tmp_path):
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    new = _write(tmp_path, "new.json", _snapshot(BASE))
    assert bench_compare.main([new, "--baseline", base]) == 0


def test_compare_flags_2x_regression(tmp_path):
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    slowed = [(n, m * 2 if n == "slow_row" else m, u) for n, m, u in BASE]
    new = _write(tmp_path, "new.json", _snapshot(slowed))
    assert bench_compare.main([new, "--baseline", base]) == 1
    failures, _ = bench_compare.compare(_snapshot(slowed), _snapshot(BASE))
    assert len(failures) == 1 and "slow_row" in failures[0]


def test_compare_flags_1_5x_regression(tmp_path):
    """The acceptance bar: a synthetic 1.5x slowdown must exit nonzero
    at the default 1.25x threshold."""
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    slowed = [(n, m * 1.5 if n == "slow_row" else m, u) for n, m, u in BASE]
    new = _write(tmp_path, "new.json", _snapshot(slowed))
    assert bench_compare.main([new, "--baseline", base]) == 1


def test_compare_noise_floor_never_gates(tmp_path):
    """Sub-min-us rows can swing wildly without failing the gate."""
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    noisy = [(n, m * 5 if n == "fast_row" else m, u) for n, m, u in BASE]
    new = _write(tmp_path, "new.json", _snapshot(noisy))
    assert bench_compare.main([new, "--baseline", base]) == 0


def test_compare_missing_row_fails(tmp_path):
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    new = _write(tmp_path, "new.json", _snapshot(BASE[:-1]))
    assert bench_compare.main([new, "--baseline", base]) == 1


def test_compare_error_row_fails(tmp_path):
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    errored = [(n, m, "ERROR ValueError: boom" if n == "slow_row" else u)
               for n, m, u in BASE]
    new = _write(tmp_path, "new.json", _snapshot(errored))
    assert bench_compare.main([new, "--baseline", base]) == 1


def test_compare_new_rows_are_reported_not_gated(tmp_path):
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    grown = BASE + [("brand_new_row", 9999.0, "w")]
    new = _write(tmp_path, "new.json", _snapshot(grown))
    assert bench_compare.main([new, "--baseline", base]) == 0
    _, lines = bench_compare.compare(_snapshot(grown), _snapshot(BASE))
    assert any("brand_new_row" in l and l.startswith("new") for l in lines)


def test_compare_calibrate_normalizes_machine_speed(tmp_path):
    """A uniformly-2x-slower machine passes when calibrated by any row,
    but a real extra regression on top of that still fails."""
    base = _write(tmp_path, "base.json", _snapshot(BASE))
    uniform = [(n, m * 2, u) for n, m, u in BASE]
    new = _write(tmp_path, "uniform.json", _snapshot(uniform))
    assert bench_compare.main([new, "--baseline", base]) == 1  # uncalibrated
    assert bench_compare.main([new, "--baseline", base,
                               "--calibrate", "other_row"]) == 0
    worse = [(n, m * 2 * (1.6 if n == "slow_row" else 1), u)
             for n, m, u in BASE]
    new2 = _write(tmp_path, "worse.json", _snapshot(worse))
    assert bench_compare.main([new2, "--baseline", base,
                               "--calibrate", "other_row"]) == 1


def test_compare_rejects_non_snapshot(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"hello": 1}))
    with pytest.raises(ValueError):
        bench_compare.load(str(bad))
