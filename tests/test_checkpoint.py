"""Checkpointing: roundtrip, async publish, latest-step, GC, restore-into-
different-dtype, and manifest metadata."""

import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.dist import checkpoint as ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32),
                   "layers": [jnp.ones((3,)), jnp.zeros((2, 2))]},
        "opt": {"m": jnp.full((8, 4), 0.5)},
    }


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 10, t, meta={"loss": 1.5})
    restored, meta = ckpt.restore(tmp_path, t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)
    assert meta["step"] == 10
    assert meta["meta"]["loss"] == 1.5


def test_latest_step_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    restored, meta = ckpt.restore(tmp_path, t)
    assert meta["step"] == 5
    # gc kept only 2
    kept = sorted(p.name for p in tmp_path.iterdir())
    assert len([k for k in kept if k.startswith("step_")]) == 2


def test_async_checkpointer_nonblocking(tmp_path):
    t = jax.tree.map(lambda x: jnp.tile(x, (64, 1))
                     if x.ndim == 2 else x, _tree())
    ac = ckpt.AsyncCheckpointer(tmp_path)
    t0 = time.perf_counter()
    ac.save(100, t)
    submit_time = time.perf_counter() - t0
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 100
    restored, _ = ckpt.restore(tmp_path, t)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, restored)


def test_restore_casts_to_like_dtype(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    ckpt.save(tmp_path, 1, t)
    like = {"w": jnp.zeros((4,), jnp.bfloat16)}
    restored, _ = ckpt.restore(tmp_path, like)
    assert restored["w"].dtype == jnp.bfloat16


def test_restore_missing_leaf_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError, match="extra"):
        ckpt.restore(tmp_path, {"w": jnp.ones((4,)), "extra": jnp.ones((2,))})


def test_restore_extra_leaf_raises(tmp_path):
    ckpt.save(tmp_path, 1, {"w": jnp.ones((4,)), "gone": jnp.ones((2,))})
    with pytest.raises(ValueError, match="gone"):
        ckpt.restore(tmp_path, {"w": jnp.ones((4,))})
