"""Differential-test oracles: EVERY Pallas kernel against its pure-jnp
twin in ``kernels/ref.py``, across randomized shapes/dtypes, in interpret
mode — so the whole kernel surface is exercised on CPU-only CI (the
``kernels-interpret`` job runs this file with ``JAX_PALLAS_INTERPRET=1``).

Two layers are covered:

* the raw kernels (``interpret=True`` passed explicitly), swept over
  seeded random shapes — bitwise where the kernel math is exact (hashing,
  count-min), tolerance elsewhere (reductions that reassociate);
* the jit'd dispatch wrappers in ``kernels/ops.py`` with the interpret
  env forced — previously this layer had zero CPU coverage. Each wrapper
  call uses shapes unique to this file: the interpret flag is read at
  trace time (NOT a jit static arg), so a cache hit from a same-shape
  trace made under different env would silently test the wrong path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels import ref
from repro.kernels.countmin import countmin_update, countmin_update_query
from repro.kernels.ef_codec import ef_int8_roundtrip, ef_topk_int8_roundtrip
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan_bd
from repro.kernels.preprocess import fused_hash_features, fused_normalize
from repro.kernels.rwkv6_wkv import rwkv6_wkv


# ---------------------------------------------------------------------------
# Fused preprocess: impute + Welford + normalize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("impute", [True, False])
def test_fused_normalize_random_shapes(seed, impute):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(3, 200))
    d = int(rng.integers(1, 40))
    block = int(rng.choice([8, 32, 256]))
    n0 = float(rng.integers(0, 500))
    mean0 = rng.normal(size=d).astype(np.float32)
    m20 = (rng.random(d).astype(np.float32) + 0.1) * max(n0, 1.0)
    x = (rng.normal(size=(n, d)) + rng.normal(size=d)).astype(np.float32)
    if impute:
        x[rng.random((n, d)) < 0.15] = np.nan
    y, n1, mean1, m21 = fused_normalize(
        jnp.asarray(x), n0, mean0, m20, impute=impute, block=block,
        interpret=True)
    yr, n1r, mean1r, m21r = ref.fused_normalize_ref(
        x, n0, mean0, m20, impute=impute)
    # raw-moment vs centered two-pass accumulation: tolerance, not bitwise
    np.testing.assert_allclose(float(n1), float(n1r))
    np.testing.assert_allclose(np.asarray(mean1), np.asarray(mean1r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(m21), np.asarray(m21r),
                               rtol=2e-3, atol=1e-2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    assert not np.isnan(np.asarray(y)).any()


def test_fused_normalize_matches_streams_composition():
    """The oracle itself is pinned to the streams/preprocess composition,
    so kernel -> ref -> production path is one chain of guarantees."""
    from repro.streams import preprocess as prep
    rng = np.random.default_rng(7)
    x = rng.normal(size=(33, 9)).astype(np.float32)
    x[2, 4] = np.nan
    st = prep.NormState(jnp.asarray(12.0),
                        jnp.asarray(rng.normal(size=9), jnp.float32),
                        jnp.asarray(rng.random(9) * 12, jnp.float32))
    st2, y2 = prep.norm_update_apply(st, prep.impute_with_mean(
        st, jnp.asarray(x)))
    yr, n1r, mean1r, m21r = ref.fused_normalize_ref(
        x, 12.0, np.asarray(st.mean), np.asarray(st.m2))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(st2.m2), np.asarray(m21r))


# ---------------------------------------------------------------------------
# Fused feature hashing (bitwise: pure int32 arithmetic on both paths)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_fused_hash_features_bitwise(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(1, 150))
    f = int(rng.integers(1, 9))
    dim = int(rng.choice([16, 64, 256]))
    block = int(rng.choice([8, 64]))
    ids = jnp.asarray(rng.integers(0, 1 << 20, (n, f)), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    out = fused_hash_features(ids, vals, dim, seed=seed + 1, block=block,
                              interpret=True)
    want = ref.hash_features_ref(ids, vals, dim, seed=seed + 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


# ---------------------------------------------------------------------------
# Count-Min fused update+query (exact: integer counts in fp32 < 2^24)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_countmin_update_query_exact(seed):
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(5, 2000))
    depth = int(rng.integers(1, 5))
    width = int(rng.choice([32, 128, 512]))
    block = int(rng.choice([64, 1024]))
    ids = jnp.asarray(rng.integers(0, 50_000, n), jnp.int32)
    seeds = jnp.asarray(rng.integers(1, 2**14, (depth, 2)) * 2 + 1, jnp.int32)
    table = jnp.asarray(rng.integers(0, 100, (depth, width)), jnp.int32)
    new_table, est = countmin_update_query(ids, table, seeds, block=block,
                                           interpret=True)
    want_table, want_est = ref.countmin_update_query_ref(ids, table, seeds)
    np.testing.assert_array_equal(np.asarray(new_table),
                                  np.asarray(want_table))
    np.testing.assert_array_equal(np.asarray(est), np.asarray(want_est))


def test_countmin_update_query_consistent_with_update():
    """The fused kernel's table must equal countmin_update's increment
    applied to the prior table (same hash family, same exactness)."""
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, 9999, 777), jnp.int32)
    seeds = jnp.asarray(rng.integers(1, 2**14, (3, 2)) * 2 + 1, jnp.int32)
    table = jnp.zeros((3, 64), jnp.int32)
    inc = countmin_update(ids, 3, 64, seeds, interpret=True)
    new_table, _ = countmin_update_query(ids, table, seeds, interpret=True)
    np.testing.assert_array_equal(np.asarray(new_table), np.asarray(inc))


# ---------------------------------------------------------------------------
# EF codec round-trips (<=1 ulp vs ref; telescoping identity near-exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_ef_int8_roundtrip_matches_ref(seed):
    rng = np.random.default_rng(300 + seed)
    shape = tuple(rng.integers(1, 60, size=int(rng.integers(1, 3))))
    block = int(rng.choice([16, 512]))
    x = jnp.asarray(rng.normal(size=shape) * 3, jnp.float32)
    res = jnp.asarray(rng.normal(size=shape) * 0.01, jnp.float32)
    dec, rout = ef_int8_roundtrip(res, x, block=block, interpret=True)
    decr, routr = ref.ef_int8_roundtrip_ref(res, x)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(decr),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rout), np.asarray(routr),
                               rtol=0, atol=1e-6)
    # EF telescoping identity: decoded + residual' == x + residual
    np.testing.assert_allclose(np.asarray(dec + rout), np.asarray(x + res),
                               rtol=0, atol=1e-6)
    # int8 quantization really happened: <= 255 distinct decoded values
    assert len(np.unique(np.asarray(dec))) <= 255


@pytest.mark.parametrize("seed", range(5))
def test_ef_topk_int8_roundtrip_matches_ref(seed):
    rng = np.random.default_rng(400 + seed)
    size = int(rng.integers(4, 3000))
    k = int(rng.integers(1, size + 1))
    block = int(rng.choice([16, 512]))
    x = jnp.asarray(rng.normal(size=size), jnp.float32)
    res = jnp.asarray(rng.normal(size=size) * 0.05, jnp.float32)
    dec, rout = ef_topk_int8_roundtrip(res, x, k, block=block, interpret=True)
    decr, routr = ref.ef_topk_int8_roundtrip_ref(res, x, k)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(decr),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(rout), np.asarray(routr),
                               rtol=0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dec + rout), np.asarray(x + res),
                               rtol=0, atol=1e-6)
    # threshold selection keeps >= k coordinates (== k for tie-free draws)
    nnz = int((np.asarray(dec) != 0).sum())
    assert nnz >= min(k, size)


def test_ef_residual_stays_bounded_over_stream():
    """50 EF round-trips through the fused kernel: the carried residual
    must stay bounded by ~one quantum of the running peak, not grow."""
    rng = np.random.default_rng(9)
    res = jnp.zeros((257,), jnp.float32)
    for step in range(50):
        x = jnp.asarray(rng.normal(size=257), jnp.float32)
        dec, res = ef_int8_roundtrip(res, x, block=64, interpret=True)
    assert float(jnp.max(jnp.abs(res))) < 2.5 * float(jnp.max(jnp.abs(x))) / 127


# ---------------------------------------------------------------------------
# Existing kernels: compact random-shape oracle checks (flash/rwkv/mamba)
# so this one file sweeps the full kernel surface under interpret mode.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(2))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_random_oracle(seed, dtype):
    rng = np.random.default_rng(500 + seed)
    S = int(rng.choice([64, 96]))
    H, D = 2, 64
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (1, S, H, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (1, S, H, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("seed", range(2))
def test_rwkv6_wkv_random_oracle(seed):
    rng = np.random.default_rng(600 + seed)
    S = int(rng.choice([24, 40]))
    hs = int(rng.choice([16, 32]))
    ks = jax.random.split(jax.random.PRNGKey(seed + 50), 6)
    r = jax.random.normal(ks[0], (1, S, 2, hs)) * 0.5
    k = jax.random.normal(ks[1], (1, S, 2, hs)) * 0.5
    v = jax.random.normal(ks[2], (1, S, 2, hs)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (1, S, 2, hs)) - 2.0)
    u = jax.random.normal(ks[4], (2, hs)) * 0.3
    h0 = jax.random.normal(ks[5], (1, 2, hs, hs)) * 0.1
    o, h = rwkv6_wkv(r, k, v, lw, u, h0, chunk=8, interpret=True)
    o_ref, h_ref = ref.rwkv6_wkv_ref(r, k, v, lw, u, h0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("seed", range(2))
def test_mamba_scan_random_oracle(seed):
    rng = np.random.default_rng(700 + seed)
    S = int(rng.choice([24, 48]))
    dI = int(rng.choice([32, 64]))
    ks = jax.random.split(jax.random.PRNGKey(seed + 80), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (1, S, dI)) - 2)
    x = jax.random.normal(ks[1], (1, S, dI))
    Bm = jax.random.normal(ks[2], (1, S, 4))
    Cm = jax.random.normal(ks[3], (1, S, 4))
    A = -jnp.exp(jax.random.normal(ks[4], (dI, 4)) * 0.5)
    h0 = jnp.zeros((1, dI, 4), jnp.float32)
    y, h = mamba_scan_bd(dt, x, Bm, Cm, A, h0, chunk=8, bd=32, interpret=True)
    y_ref, h_ref = ref.mamba_scan_ref(dt, x, Bm, Cm, A, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ops.py dispatch wrappers under forced interpret (the layer that had
# zero CPU coverage). Shapes here are deliberately unique to this file —
# see module docstring for the jit-cache hazard.
# ---------------------------------------------------------------------------

class TestDispatchWrappers:

    @pytest.fixture(autouse=True)
    def _force_interpret(self, monkeypatch):
        monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
        assert kops.pallas_available()

    def test_fused_normalize_wrapper(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(41, 13)), jnp.float32)
        y, n1, mean1, m21 = kops.fused_normalize(
            x, jnp.asarray(0.0), jnp.zeros(13), jnp.zeros(13))
        yr, n1r, mean1r, m21r = ref.fused_normalize_ref(
            x, 0.0, np.zeros(13, np.float32), np.zeros(13, np.float32))
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        assert float(n1) == 41.0

    def test_hash_features_wrapper(self):
        rng = np.random.default_rng(1)
        ids = jnp.asarray(rng.integers(0, 99999, (29, 5)), jnp.int32)
        vals = jnp.asarray(rng.normal(size=(29, 5)), jnp.float32)
        out = kops.hash_features(ids, vals, dim=37)
        want = ref.hash_features_ref(ids, vals, 37)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    def test_countmin_update_query_wrapper(self):
        rng = np.random.default_rng(2)
        ids = jnp.asarray(rng.integers(0, 5000, 311), jnp.int32)
        seeds = jnp.asarray(rng.integers(1, 2**14, (2, 2)) * 2 + 1, jnp.int32)
        table = jnp.zeros((2, 53), jnp.int32)
        new_table, est = kops.countmin_update_query(ids, table, seeds)
        want_table, want_est = ref.countmin_update_query_ref(ids, table, seeds)
        np.testing.assert_array_equal(np.asarray(new_table),
                                      np.asarray(want_table))
        np.testing.assert_array_equal(np.asarray(est), np.asarray(want_est))

    def test_ef_wrappers(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(173,)), jnp.float32)
        res = jnp.zeros((173,), jnp.float32)
        dec, rout = kops.ef_int8_roundtrip(res, x)
        decr, routr = ref.ef_int8_roundtrip_ref(res, x)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(decr),
                                   rtol=0, atol=1e-6)
        dec, rout = kops.ef_topk_int8_roundtrip(res, x, k=17)
        decr, routr = ref.ef_topk_int8_roundtrip_ref(res, x, 17)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(decr),
                                   rtol=0, atol=1e-6)


def test_pallas_available_tracks_env(monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    monkeypatch.delenv("JAX_PALLAS_INTERPRET", raising=False)
    on_tpu = jax.default_backend() == "tpu"
    assert kops.pallas_available() == on_tpu
    monkeypatch.setenv("JAX_PALLAS_INTERPRET", "1")
    assert kops.pallas_available()
    monkeypatch.delenv("JAX_PALLAS_INTERPRET")
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    assert kops.pallas_available()
