"""Optimizers: correctness vs hand math, convergence, state-axes trees."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import optim as O


def _quadratic_losses(opt, steps=200, dim=8, seed=0):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=(dim,)), jnp.float32)
    params = {"w": jnp.zeros((dim,))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    losses = []
    for step in range(steps):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, jnp.asarray(step))
        losses.append(float(loss(params)))
    return losses


@pytest.mark.parametrize("name,opt", [
    ("adamw", O.adamw(1e-1, weight_decay=0.0)),
    ("lion", O.lion(3e-2, weight_decay=0.0)),
    ("adafactor", O.adafactor(1e-1)),
    ("sgd", O.sgd(5e-2)),
])
def test_optimizer_converges_on_quadratic(name, opt):
    losses = _quadratic_losses(opt)
    tol = 0.15 if name == "lion" else 0.05   # sign updates plateau in an lr-ball
    assert losses[-1] < losses[0] * tol, f"{name}: {losses[-1]} vs {losses[0]}"


def test_adamw_first_step_matches_hand_math():
    opt = O.adamw(0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    g = {"w": jnp.asarray([0.5])}
    new_p, _ = opt.update(g, state, params, jnp.asarray(0))
    # bias-corrected mhat = g, vhat = g^2 -> step = lr * g/|g| = lr
    np.testing.assert_allclose(np.asarray(new_p["w"]), [1.0 - 0.1], rtol=1e-4)


def test_adamw_fp32_master_keeps_precision_with_bf16_params():
    opt = O.adamw(1e-3, weight_decay=0.0, fp32_master=True)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    p, s = params, state
    for i in range(10):
        p, s = opt.update(g, s, p, jnp.asarray(i))
    # master accumulated updates far below bf16 resolution of 1.0
    assert float(s["master"]["w"][0]) < 1.0 - 5e-3
    assert p["w"].dtype == jnp.bfloat16


def test_adafactor_memory_is_sublinear():
    params = {"w": jnp.zeros((64, 128))}
    st = O.adafactor(1e-2).init(params)
    n_state = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(st))
    assert n_state == 64 + 128     # factored, not 64*128


def test_state_axes_tree_matches_state_structure():
    params = {"a": jnp.zeros((4, 8)), "b": jnp.zeros((8,))}
    axes = {"a": ("embed", "ff"), "b": ("ff",)}
    for opt in [O.adamw(1e-3, fp32_master=True), O.lion(1e-3),
                O.adafactor(1e-3), O.sgd(1e-3)]:
        st = opt.init(params)
        ax = opt.state_axes(axes)
        # axes tuples sit at (or above) each state leaf: mapping must work
        jax.tree.map(lambda leaf: leaf, st)   # sanity
        jax.tree.map(lambda leaf, a: None, st, ax)  # raises on mismatch


def test_grad_accum_equivalence():
    """M microbatches must match a single full-batch step (linear loss)."""
    from repro.configs import get_config
    from repro.train.train_step import make_train_step
    cfg = get_config("qwen2-1.5b", smoke=True)
    opt = O.sgd(1e-2, momentum=0.0)

    def loss_fn(p, b):
        # mean-squared toy loss over the embedding row sums (linear in data)
        emb = p["embed"]["tok"]
        idx = b["tokens"].reshape(-1)
        return jnp.mean(jnp.square(emb[idx].sum(-1))), {}

    from repro.models import model_zoo as zoo
    params = zoo.init_params(cfg, 0)
    state = opt.init(params)
    rngtok = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 16)),
        jnp.int32)
    batch = {"tokens": rngtok}
    s1 = make_train_step(cfg, opt, loss_fn=loss_fn, microbatches=1)
    s4 = make_train_step(cfg, opt, loss_fn=loss_fn, microbatches=4)
    p1, *_ = s1(params, state, jnp.asarray(0), batch)
    p4, *_ = s4(params, state, jnp.asarray(0), batch)
    np.testing.assert_allclose(np.asarray(p1["embed"]["tok"], np.float32),
                               np.asarray(p4["embed"]["tok"], np.float32),
                               rtol=2e-4, atol=2e-5)
