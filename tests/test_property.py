"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (optional dep)")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import pipeline as pl
from repro.core.costmodel import CLOUD_POD, EDGE_NODE, OperatorCost
from repro.core.placement import (Objective, place_frontier,
                                  place_graph_exhaustive)
from repro.dist.api import logical_to_spec
from repro.dist.compression import dequantize_int8, quantize_int8
from repro.streams import sketches as sk
from repro.streams import preprocess as prep


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


@settings(max_examples=60, deadline=None)
@given(
    dim=st.integers(1, 4096),
    sizes=st.tuples(st.sampled_from([2, 4, 8, 16]),
                    st.sampled_from([2, 4, 8, 16])),
)
def test_logical_to_spec_always_divides(dim, sizes):
    """Whatever the dim, the chosen mesh axes always divide it exactly."""
    mesh = _FakeMesh({"data": sizes[0], "model": sizes[1]})
    spec = logical_to_spec(("batch",), {"batch": ("data", "model")},
                           mesh, (dim,))
    part = spec[0] if len(spec) else None
    axes = (part,) if isinstance(part, str) else (part or ())
    prod = 1
    for a in axes:
        prod *= mesh.shape[a]
    assert dim % prod == 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=256))
def test_int8_quantization_error_bound(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    # symmetric quantization error is bounded by scale/2 per element
    bound = float(scale) * 0.5 + 1e-6
    assert float(jnp.max(jnp.abs(deq - x))) <= bound + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 1000), min_size=1, max_size=500),
       st.integers(0, 2**14 - 1))
def test_countmin_overestimates_only(ids, seed):
    rng = np.random.default_rng(seed)
    depth, width = 3, 64
    seeds = np.asarray(rng.integers(1, 2**14, (depth, 2)) * 2 + 1, np.int32)
    from repro.kernels.ref import countmin_ref
    table = np.asarray(countmin_ref(jnp.asarray(ids, jnp.int32), depth,
                                    width, seeds))
    true = np.bincount(ids, minlength=1001)
    P = 2_147_483_647
    for item in set(ids):
        est = min(table[d, ((item * int(seeds[d, 0]) + int(seeds[d, 1]))
                            % P) % width] for d in range(depth))
        assert est >= true[item]


@settings(max_examples=25, deadline=None, database=None)
@given(st.integers(1, 5), st.integers(0, 1000))
def test_welford_matches_two_pass(nbatches, seed):
    rng = np.random.default_rng(seed)
    dim = 3
    st_ = prep.norm_init(dim)
    allx = []
    for _ in range(nbatches):
        # keep |mean| ~ std so fp32 single-pass variance stays well-posed
        x = rng.normal(loc=rng.normal(), scale=2.0,
                       size=(rng.integers(4, 64), dim)).astype(np.float32)
        allx.append(x)
        st_, _ = prep.norm_update_apply(st_, jnp.asarray(x))
    cat = np.concatenate(allx)
    np.testing.assert_allclose(np.asarray(st_.mean), cat.mean(0),
                               rtol=1e-3, atol=5e-3)
    var = np.asarray(st_.m2) / max(len(cat) - 1, 1)
    # fp32 single-pass vs float64 two-pass: loose but meaningful bound
    np.testing.assert_allclose(var, cat.var(0, ddof=1), rtol=6e-2, atol=6e-2)


def _property_pipeline(kind, dim):
    if kind == "standard":
        return pl.standard_stream_pipeline(dim, sample_rate=0.5,
                                           reservoir_k=16)
    if kind == "hash_pca":
        return pl.Pipeline([pl.hash_op(dim), pl.pca_op(dim, 2),
                            pl.sketch_op(2)])
    return pl.Pipeline([pl.normalize_op(dim), pl.anomaly_op(dim, m=4),
                        pl.sketch_op(dim)])


def _property_batches(kind, dim, nbatches, seed):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(nbatches):
        if kind == "hash_pca":
            out.append({"ids": jnp.asarray(
                rng.integers(0, 1000, (16, 4)).astype(np.int32)),
                "vals": jnp.asarray(
                    rng.normal(size=(16, 4)).astype(np.float32))})
        else:
            out.append({"x": jnp.asarray(
                rng.normal(size=(16, dim)).astype(np.float32)),
                "y": jnp.asarray(
                    (rng.random(16) > 0.5).astype(np.int32))})
    return out


@settings(max_examples=8, deadline=None, database=None)
@given(kind=st.sampled_from(["standard", "hash_pca", "anomaly"]),
       dim=st.sampled_from([4, 8]),
       nbatches=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_pipeline_every_cut_bitwise_matches_reference(kind, dim, nbatches,
                                                      seed):
    """Partitioning a pipeline at ANY prefix cut — the edge/cloud split the
    orchestrator migrates at runtime — must reproduce the unpartitioned
    reference execution bitwise: final states, metrics, and every batch
    output."""
    pipe = _property_pipeline(kind, dim)
    data = _property_batches(kind, dim, nbatches, seed)

    def run(cut):
        states = pipe.init_states()
        rng = jax.random.PRNGKey(seed)
        outs = []
        for bd in data:
            bd = dict(bd)
            bd["rng"] = rng
            states, out = pipe.run(states, bd, cut)
            rng = out["rng"]
            outs.append(out)
        return states, outs

    ref_states, ref_outs = run(0)
    for cut in range(1, pipe.n_cuts):
        states, outs = run(cut)
        for a, b in zip(jax.tree.leaves((ref_states, ref_outs)),
                        jax.tree.leaves((states, outs))):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"kind={kind} cut={cut} diverged from reference")


@settings(max_examples=6, deadline=None, database=None)
@given(dim=st.sampled_from([4, 8]),
       sample_rate=st.sampled_from([0.3, 0.7]),
       nbatches=st.integers(1, 2),
       seed=st.integers(0, 1000))
def test_fanout_graph_every_frontier_bitwise_matches_reference(
        dim, sample_rate, nbatches, seed):
    """Partitioning the fan-out/rejoin DAG at ANY downward-closed cut —
    including cuts that keep parallel branches on different sides — must
    reproduce the unpartitioned reference execution bitwise."""
    g = pl.fanout_stream_graph(dim, sample_rate=sample_rate)
    data = _property_batches("standard", dim, nbatches, seed)

    def run(frontier):
        states = g.init_states()
        rng = jax.random.PRNGKey(seed)
        outs = []
        for bd in data:
            bd = dict(bd)
            bd["rng"] = rng
            states, out = g.run(states, bd, frontier)
            rng = out["rng"]
            outs.append(out)
        return states, outs

    ref = run(frozenset())
    for frontier in g.frontiers():
        got = run(frontier)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"frontier={sorted(frontier)} diverged")


def _ident(state, batch):
    return state, batch


@st.composite
def _random_dag(draw):
    """A random small operator DAG (<=6 ops) with random channel wiring
    and random cost profiles, plus a random event rate."""
    n = draw(st.integers(2, 6))
    n_src = draw(st.integers(1, 2))
    sources = [f"s{i}" for i in range(n_src)]
    ops = []
    for j in range(n):
        avail = sources + [f"k{i}" for i in range(j)]
        reads = tuple(sorted(draw(st.sets(st.sampled_from(avail),
                                          max_size=min(3, len(avail))))))
        cost = OperatorCost(
            f"op{j}",
            flops_per_event=draw(st.floats(10.0, 1e7)),
            bytes_per_event=draw(st.floats(8.0, 4096.0)),
            out_bytes_per_event=draw(st.floats(1.0, 2048.0)),
            edge_capable=draw(st.booleans()))
        ops.append(pl.Op(f"op{j}", _ident, cost,
                         reads=reads, writes=(f"k{j}",)))
    rate = draw(st.floats(1e2, 1e7))
    return pl.OpGraph(ops), rate


@settings(max_examples=60, deadline=None, database=None)
@given(case=_random_dag())
def test_frontier_search_matches_exhaustive_oracle_on_random_dags(case):
    """Frontier-cut (downward-closed) placement search must find the same
    best score as the exhaustive all-assignments oracle on random small
    DAGs — backhaul-free assignments ARE the frontier cuts, so searching
    only antichain cuts loses nothing."""
    graph, rate = case
    obj = Objective()
    res = {"edge": EDGE_NODE, "cloud": CLOUD_POD}
    best, frontier = place_frontier(graph, res, rate, obj)
    oracle = place_graph_exhaustive(graph, res, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001, (
        f"frontier search lost to the oracle: frontier={sorted(frontier)} "
        f"score={obj.score(best)} oracle={obj.score(oracle)} "
        f"oracle_assign={oracle.assignment}")


def _multipool_spec(codec: str):
    from repro.core.costmodel import ClusterSpec, Link, Resource
    edge_b = Resource("edge_b", "edge", chips=1, flops=1e12, mem_bw=40e9,
                      mem_cap=2e9, net_bw=0.5e9, net_latency=35e-3,
                      energy_w=10.0)
    cloud_b = Resource("cloud_b", "cloud", chips=64, net_latency=0.5e-3,
                       energy_w=220.0)
    return ClusterSpec(
        pools=[EDGE_NODE, edge_b, CLOUD_POD, cloud_b],
        links=[Link("edge", "cloud", bw=1e9, latency=20e-3, codec=codec),
               Link("edge_b", "cloud_b", bw=0.5e9, latency=40e-3,
                    codec=codec),
               Link("edge", "edge_b", bw=2e9, latency=5e-3)])


@settings(max_examples=40, deadline=None, database=None)
@given(case=_random_dag(),
       codec=st.sampled_from(["identity", "int8_ef", "topk_int8_ef"]))
def test_multipool_frontier_search_matches_oracle_on_random_dags(case, codec):
    """The multi-pool generalization of the invariant above: over a
    2-edge-pool/2-cloud-pod ClusterSpec with codec-carrying links, the
    frontier search (frontiers x within-kind pool assignments) must match
    the exhaustive every-op-to-every-pool oracle — cloud->edge backhaul
    stays infeasible, so the edge-resident set of any feasible assignment
    is downward-closed and the search covers it."""
    graph, rate = case
    obj = Objective()
    spec = _multipool_spec(codec)
    best, frontier = place_frontier(graph, spec, rate, obj)
    oracle = place_graph_exhaustive(graph, spec, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001, (
        f"multi-pool frontier search lost to the oracle: "
        f"frontier={sorted(frontier)} score={obj.score(best)} "
        f"oracle={obj.score(oracle)} oracle_assign={oracle.assignment}")
    edge_pools = {r.name for r in spec.edge_pools}
    assert frontier == frozenset(
        n for n, r in best.assignment.items() if r in edge_pools)


@settings(max_examples=40, deadline=None, database=None)
@given(case=_random_dag())
def test_dp_placement_matches_oracle_on_random_dags(case):
    """The polynomial label DP must equal the exhaustive all-assignments
    oracle on random small DAGs — same invariant the enumeration engine
    carries, now for the engine real problem sizes run on."""
    from repro.core.placement import place_frontier_dp
    graph, rate = case
    obj = Objective()
    res = {"edge": EDGE_NODE, "cloud": CLOUD_POD}
    best, frontier = place_frontier_dp(graph, res, rate, obj)
    oracle = place_graph_exhaustive(graph, res, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001, (
        f"DP lost to the oracle: frontier={sorted(frontier)} "
        f"score={obj.score(best)} oracle={obj.score(oracle)} "
        f"oracle_assign={oracle.assignment}")


@settings(max_examples=30, deadline=None, database=None)
@given(case=_random_dag(),
       codec=st.sampled_from(["identity", "int8_ef", "topk_int8_ef"]))
def test_dp_multipool_codec_ladder_matches_enumeration(case, codec):
    """Multi-pool + codec-candidate generalization: the DP must return
    the exact plan (assignment, frontier, codec) the enumeration engine
    returns — not just the score — so the two engines are
    interchangeable inside the offload controller."""
    from repro.core.placement import place_frontier_dp
    graph, rate = case
    obj = Objective()
    spec = _multipool_spec(codec)
    codecs = ["topk_int8_ef", codec, "identity"]
    best_dp, frontier_dp = place_frontier_dp(graph, spec, rate, obj,
                                             codecs=codecs)
    best_en, frontier_en = place_frontier(graph, spec, rate, obj,
                                          codecs=codecs, method="enumerate")
    assert best_dp.assignment == best_en.assignment, (
        f"DP/enumeration diverged: dp={best_dp.assignment} "
        f"en={best_en.assignment}")
    assert frontier_dp == frontier_en
    assert best_dp.uplink_codec == best_en.uplink_codec


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 100))
def test_moments_min_max_invariants(seed):
    rng = np.random.default_rng(seed)
    m = sk.moments_init(4)
    xs = rng.normal(size=(100, 4)).astype(np.float32)
    for i in range(0, 100, 25):
        m = sk.moments_update(m, jnp.asarray(xs[i:i + 25]))
    np.testing.assert_allclose(np.asarray(m.min), xs.min(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m.max), xs.max(0), rtol=1e-5)
    assert int(m.n) == 100
