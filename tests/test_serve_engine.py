"""ServeEngine behavior on CPU: wave batching over more requests than
slots, the int8 KV-cache path, deterministic latency metrics under an
injected sim clock, and the sampling primitives the decode loop uses."""

from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import model_zoo as zoo
from repro.serve.engine import Request, ServeEngine
from repro.serve.sampling import SamplingParams, sample

CFG = get_config("qwen2-1.5b", smoke=True)
PARAMS = zoo.init_params(CFG, 0)


def make_requests(n, new_tokens):
    return [Request(i, np.arange(1, 6, dtype=np.int32) + i,
                    max_new_tokens=new_tokens[i]) for i in range(n)]


# ---------------------------------------------------------------------------
# wave batching
# ---------------------------------------------------------------------------

def test_wave_batching_serves_all_requests_to_their_own_lengths():
    eng = ServeEngine(CFG, PARAMS, batch_size=2, max_len=32)
    new_tokens = [3, 5, 4, 2, 6]
    reqs = make_requests(5, new_tokens)
    done = eng.run(reqs)
    assert len(done) == 5
    for r, want in zip(done, new_tokens):
        assert r.done
        assert len(r.out_tokens) == want
        assert all(isinstance(t, int) for t in r.out_tokens)
    # 3 waves of prompts (2+2+1), left-padded to the wave max S=5
    assert eng.metrics["prefill_tokens"] == 5 * 5


def test_wave_batching_matches_single_request_runs_greedy():
    """Greedy decoding is batch-invariant here: serving a request in a
    shared wave must emit the same tokens as serving it alone (waves are
    padded to a uniform stride, so the cache layout is identical)."""
    reqs = make_requests(2, [4, 4])
    eng = ServeEngine(CFG, PARAMS, batch_size=2, max_len=32)
    eng.run(reqs)
    for i in range(2):
        solo = make_requests(2, [4, 4])[i]
        solo_eng = ServeEngine(CFG, PARAMS, batch_size=2, max_len=32)
        solo_eng.run([solo])
        assert solo.out_tokens == reqs[i].out_tokens


# ---------------------------------------------------------------------------
# int8 KV cache
# ---------------------------------------------------------------------------

def test_int8_kv_cache_path_serves_and_stays_close_to_bf16():
    cfg8 = replace(CFG, kv_cache_dtype="int8")
    reqs8 = make_requests(2, [4, 4])
    ServeEngine(cfg8, PARAMS, batch_size=2, max_len=32).run(reqs8)
    for r in reqs8:
        assert r.done and len(r.out_tokens) == 4
    caches = zoo.init_caches(cfg8, 2, 32)
    dtypes = {l.dtype for l in jax.tree_util.tree_leaves(caches)
              if l.ndim >= 3}
    assert jnp.dtype(jnp.int8) in dtypes


# ---------------------------------------------------------------------------
# injected clock -> deterministic metrics
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, tick=0.5):
        self.t, self.tick = 0.0, tick

    def __call__(self):
        self.t += self.tick
        return self.t


def test_injected_clock_makes_latency_metrics_deterministic():
    eng = ServeEngine(CFG, PARAMS, batch_size=2, max_len=32,
                      clock=FakeClock(tick=0.5))
    eng.run(make_requests(2, [4, 4]))
    # each wave reads the clock twice per phase: both spans are one tick
    assert eng.metrics["prefill_s"] == pytest.approx(0.5)
    assert eng.metrics["decode_s"] == pytest.approx(0.5)
    tp = eng.throughput()
    assert tp["prefill_tok_per_s"] == pytest.approx(2 * 5 / 0.5)
    assert tp["decode_tok_per_s"] == pytest.approx(2 * 3 / 0.5)


def test_throughput_is_safe_before_any_traffic():
    eng = ServeEngine(CFG, PARAMS, batch_size=2, max_len=32)
    tp = eng.throughput()
    assert tp["prefill_tok_per_s"] == 0.0
    assert tp["decode_tok_per_s"] == 0.0


# ---------------------------------------------------------------------------
# sampling primitives
# ---------------------------------------------------------------------------

def test_sample_greedy_is_argmax():
    logits = jnp.asarray([[0.1, 2.0, -1.0], [3.0, 0.0, 2.9]])
    tok = sample(logits, jax.random.PRNGKey(0), SamplingParams(greedy=True))
    assert tok.tolist() == [1, 0]


def test_sample_top_k_masks_outside_top_k():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    p = SamplingParams(temperature=1.0, top_k=3)
    topk = set()
    for row in np.asarray(logits):
        topk.update((tuple(np.argsort(row)[-3:])))
    for seed in range(8):
        tok = sample(logits, jax.random.PRNGKey(seed), p)
        for b in range(4):
            top3 = np.argsort(np.asarray(logits)[b])[-3:]
            assert int(tok[b]) in top3


def test_sample_top_p_keeps_nucleus_only():
    # one dominant logit -> tiny nucleus -> always that token
    logits = jnp.asarray([[10.0, 0.0, 0.0, 0.0]])
    p = SamplingParams(temperature=1.0, top_p=0.5)
    for seed in range(8):
        tok = sample(logits, jax.random.PRNGKey(seed), p)
        assert int(tok[0]) == 0


def test_sample_temperature_sharpens():
    logits = jnp.asarray([[1.0, 0.0, -1.0]])
    cold = SamplingParams(temperature=1e-3)
    for seed in range(8):
        tok = sample(logits, jax.random.PRNGKey(seed), cold)
        assert int(tok[0]) == 0
