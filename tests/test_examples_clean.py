"""The shipped example set is the public face of the API: every script
must run green, and none may route through the deprecated
``edge_cloud_pools`` shim (its DeprecationWarning would land in every
new user's first session). Scripts run as real subprocesses with
warnings forced on, so a regression anywhere in the import graph — not
just in the example text — trips this."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
EXAMPLES = sorted((REPO / "examples").glob("*.py"))


def test_example_set_is_complete():
    names = {p.name for p in EXAMPLES}
    assert {"quickstart.py", "edge_cloud_pipeline.py", "edge_serving.py",
            "train_stream_lm.py"} <= names


@pytest.mark.slow
def test_examples_run_clean_of_deprecated_shims():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    procs = {
        p.name: subprocess.Popen(
            [sys.executable, "-W", "always::DeprecationWarning", str(p)],
            cwd=REPO, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)
        for p in EXAMPLES}
    failures = []
    for name, proc in procs.items():
        out, _ = proc.communicate(timeout=600)
        if proc.returncode != 0:
            failures.append(f"{name} exited {proc.returncode}:\n{out}")
        if "edge_cloud_pools" in out:
            failures.append(f"{name} touched the deprecated "
                            f"edge_cloud_pools shim:\n{out}")
    assert not failures, "\n\n".join(failures)
