"""Pipeline IR: partitioned execution, compile cache, placement-driven
re-partitioning, and scenario-diverse pipelines through the orchestrator."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import _first_edge_cloud, place
from repro.streams.events import StreamBatch
from repro.streams.fusion import WindowJoin
from repro.streams.generators import DriftSpec, HyperplaneStream

RES = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}


def _batches(n, dim=8, n_per=32, seed=0, **gen_kw):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per, **gen_kw)
    return [gen.batch(i, n_per) for i in range(n)]


def _run_cut(pipe, data, cut):
    states = pipe.init_states()
    rng = jax.random.PRNGKey(0)
    outs = []
    for b in data:
        bd = {k: jnp.asarray(v) for k, v in b.data.items()}
        bd["rng"] = rng
        states, out = pipe.run(states, bd, cut)
        rng = out["rng"]
        outs.append({k: np.asarray(v) for k, v in out.items() if k != "rng"})
    return states, outs


def _assert_trees_bitwise(a, b, what):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


# ---------------------------------------------------------------------------
# IR basics
# ---------------------------------------------------------------------------

def test_pipeline_rejects_bad_construction():
    op = pl.sketch_op(4)
    with pytest.raises(ValueError):
        pl.Pipeline([])
    with pytest.raises(ValueError):
        pl.Pipeline([op, op])
    with pytest.raises(ValueError):
        pl.Pipeline([op], fuse="welded")
    with pytest.raises(ValueError):
        pl.Pipeline([op]).run({}, {}, cut=5)


def test_costs_and_executor_share_the_op_list():
    pipe = pl.standard_stream_pipeline(dim=8)
    assert [c.name for c in pipe.costs()] == pipe.names
    assert pipe.names == ["normalize", "sketch", "sample", "train", "drift"]


def test_every_cut_matches_unpartitioned_reference():
    pipe = pl.standard_stream_pipeline(dim=8, sample_rate=0.7)
    data = _batches(4)
    ref_states, ref_outs = _run_cut(pipe, data, 0)
    for cut in range(1, pipe.n_cuts):
        states, outs = _run_cut(pipe, data, cut)
        for a, b in zip(ref_outs, outs):
            assert set(a) == set(b)
            for k in a:
                np.testing.assert_array_equal(a[k], b[k],
                                              err_msg=f"cut={cut} out[{k}]")
        for name in pipe.names:
            _assert_trees_bitwise(ref_states[name], states[name],
                                  f"cut={cut} state[{name}]")


def test_compile_cache_hit_on_cut_revisit():
    pipe = pl.standard_stream_pipeline(dim=8)
    data = _batches(3)
    states = pipe.init_states()
    rng = jax.random.PRNGKey(0)
    for b, cut in zip(data, (3, 2, 3)):       # migrate away and back
        bd = {k: jnp.asarray(v) for k, v in b.data.items()}
        bd["rng"] = rng
        states, out = pipe.run(states, bd, cut)
        rng = out["rng"]
    compiles_after_first_visit = pipe.compiles
    assert pipe.cache_hits >= 2                # cut=3 revisit was free
    bd = {k: jnp.asarray(v) for k, v in data[0].data.items()}
    bd["rng"] = rng
    pipe.run(states, bd, 3)
    assert pipe.compiles == compiles_after_first_visit


# ---------------------------------------------------------------------------
# placement pools (satellite: clear errors instead of StopIteration)
# ---------------------------------------------------------------------------

def test_placement_requires_both_pool_kinds():
    ops = pl.standard_stream_pipeline(dim=8).costs()
    for bad in ({}, {"edge": cm.EDGE_NODE}, {"cloud": cm.CLOUD_POD}):
        with pytest.raises(ValueError, match="edge.*cloud|cloud.*edge"):
            place(ops, bad, 1e4)


def test_placement_takes_first_pool_of_each_kind():
    edge2 = cm.Resource("edge2", "edge", chips=2)
    cloud2 = cm.Resource("cloud2", "cloud", chips=2)
    res = {"edge": cm.EDGE_NODE, "edge2": edge2,
           "cloud": cm.CLOUD_POD, "cloud2": cloud2}
    # the warning-free collapse rule behind the deprecated shim
    e, c = _first_edge_cloud(res)
    assert (e.name, c.name) == ("edge", "cloud")
    plan, _ = place(pl.standard_stream_pipeline(dim=8).costs(), res, 1e4)
    assert set(plan.assignment.values()) <= {"edge", "cloud"}


# ---------------------------------------------------------------------------
# acceptance: migration decisions observably change execution
# ---------------------------------------------------------------------------

def test_rate_spike_moves_cut_and_execution_matches_reference():
    """A 300x rate spike makes the offload controller move the cut; the
    orchestrator re-fuses segments mid-stream, and every per-batch result
    is bitwise-identical to a fixed-cut reference run."""
    def rate_fn(step):
        return 1e4 if step < 10 else 3e6

    data = _batches(30, dim=16, n_per=64)
    orch = Orchestrator(StreamJob("mig", dim=16))
    m = orch.run(data, rate_fn=rate_fn, record_outputs=True)

    assert m.migrations >= 1, "spike must migrate the cut"
    assert len(set(m.cuts)) >= 2, "cut must actually change what runs where"
    assert m.cuts[0] > m.cuts[-1], "spike pushes work off the edge"
    assert any("repartition" in d for d in m.decisions)

    ref = Orchestrator(StreamJob("ref", dim=16))
    mr = ref.run(data, rate_fn=rate_fn, fixed_cut=0, record_outputs=True)
    assert len(m.outputs) == len(mr.outputs)
    for a, b in zip(m.outputs, mr.outputs):
        for k in a:
            np.testing.assert_array_equal(
                a[k], b[k], err_msg=f"migrated run diverged on {k}")
    assert m.preq == mr.preq


# ---------------------------------------------------------------------------
# scenario diversity: non-default pipelines through Orchestrator.run
# ---------------------------------------------------------------------------

def test_hashing_pca_sketch_pipeline_runs_and_migrates_cleanly():
    """Sparse events -> feature hashing -> streaming PCA -> moments sketch:
    an unsupervised volume-reduction job (no labels, no learner)."""
    dim, k = 32, 4
    pipe = pl.Pipeline([pl.hash_op(dim), pl.pca_op(dim, k), pl.sketch_op(k)])
    rng = np.random.default_rng(0)
    data = []
    for i in range(12):
        ids = rng.integers(0, 10_000, (64, 8)).astype(np.int32)
        vals = rng.normal(size=(64, 8)).astype(np.float32)
        data.append(StreamBatch(data={"ids": ids, "vals": vals},
                                ts=np.arange(64) + 64.0 * i))
    job = StreamJob("hash-pca", dim=dim, pipeline=pipe)
    orch = Orchestrator(job)
    m = orch.run(data, rate_fn=lambda s: 1e4)
    assert m.events == 12 * 64
    assert m.preq is None                      # no learner op -> no preq
    assert int(orch.states["sketch"].n) == 12 * 64   # sketch accumulated
    assert orch.states["pca"].w.shape == (dim, k)


def test_fusion_fed_pipeline_runs_through_orchestrator():
    """WindowJoin-fused side channel -> concat -> normalize -> train: the
    multi-stream S2CE input interface feeding a supervised job."""
    dim, side = 8, 3
    join = WindowJoin(tolerance=5.0)
    rng = np.random.default_rng(1)
    base = _batches(15, dim=dim, n_per=32, seed=2)
    data = []
    for b in base:
        right = StreamBatch(
            data={"x": rng.normal(size=(32, side)).astype(np.float32)},
            ts=np.asarray(b.ts))
        join.push_right(right)
        joined, matched = join.join_left(b)
        assert matched.all()
        data.append(joined)

    pipe = pl.Pipeline([
        pl.concat_op("joined", dim + side),
        pl.normalize_op(dim + side),
        pl.logreg_train_op(dim + side),
    ])
    job = StreamJob("fusion-fed", dim=dim + side, pipeline=pipe)
    m = Orchestrator(job).run(data, rate_fn=lambda s: 1e4)
    assert m.events == 15 * 32
    assert m.preq is not None and m.preq["accuracy"] > 0.6
    assert m.preq["n"] == 15 * 32
