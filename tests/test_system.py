"""End-to-end behaviour tests for the S2CE system: the orchestrated
pipeline, multi-device distribution (subprocess with 8 host devices),
elastic recovery, and compressed gradient sync."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_end_to_end_stream_job():
    from repro.core.orchestrator import Orchestrator, StreamJob
    from repro.streams.generators import DriftSpec, HyperplaneStream
    job = StreamJob("sys", dim=8, drift_detector="ph")
    orch = Orchestrator(job)
    gen = HyperplaneStream(dim=8, seed=1,
                           drift=DriftSpec("gradual", at=0.5, width=0.2),
                           horizon=40 * 64.0)
    m = orch.run([gen.batch(i, 64) for i in range(40)])
    assert m.events == 40 * 64
    assert m.preq["accuracy"] > 0.6


def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,4) mesh must produce (numerically) the
    same params as unsharded execution."""
    out = _run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.dist import use_mesh
        from repro.dist.sharding import build_rules
        from repro.models import model_zoo as zoo
        from repro.train.optim import make_optimizer
        from repro.train.train_step import make_train_step

        cfg = get_config("qwen2-1.5b", smoke=True).with_overrides(recipe="tp_fsdp")
        params = zoo.init_params(cfg, 0)
        opt = make_optimizer(cfg, "sgd", lr=1e-2)
        state = opt.init(params)
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (8, 32)),
            jnp.int32)}
        step_fn = make_train_step(cfg, opt, microbatches=1)
        p1, *_ = jax.jit(step_fn)(params, state, jnp.asarray(0), batch)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = build_rules(cfg)
        with use_mesh(mesh, rules):
            p2, *_ = jax.jit(step_fn)(params, state, jnp.asarray(0), batch)
        a = np.asarray(jax.tree.leaves(p1)[0], np.float32)
        b = np.asarray(jax.tree.leaves(p2)[0], np.float32)
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)
        print("MATCH")
    """)
    assert "MATCH" in out


def test_elastic_recovery_after_failure():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import elastic
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        new = elastic.rebuild_mesh(list(mesh.devices.flat), failed=[3, 5],
                                   prefer_model=2)
        assert new.devices.size == 4, new.devices.size
        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        axes = {"w": ("embed", "ff")}
        rules = {"param": {"embed": "data", "ff": "model"}, "act": {}}
        out = elastic.reshard_tree(tree, axes, rules, new)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        print("RECOVERED")
    """)
    assert "RECOVERED" in out


def test_compressed_allreduce_matches_mean():
    out = _run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.compression import compressed_allreduce_mean
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 64)).astype(np.float32))

        @partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=(P("data"), P("data")))
        def f(xs):
            m, err = compressed_allreduce_mean(xs[0], "data")
            return m[None], err[None]

        mean, err = f(x)
        want = x.mean(0)
        got = np.asarray(mean[0])
        np.testing.assert_allclose(got, np.asarray(want), atol=2e-2)
        assert np.isfinite(np.asarray(err)).all()
        print("COMPRESSED_OK", float(np.abs(got - np.asarray(want)).max()))
    """)
    assert "COMPRESSED_OK" in out


def test_elastic_train_rescales_through_checkpoint_cycle(tmp_path):
    """`--elastic` drives an ElasticController grow through the real
    save -> rebuild_mesh -> reshard_tree -> resume cycle mid-training."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-1.5b",
         "--smoke", "--steps", "6", "--batch", "2", "--seq", "16",
         "--data-mesh", "2", "--elastic", "--elastic-demand", "8",
         "--max-workers", "4", "--ckpt-every", "50",
         "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "elastic grow -> 4 workers" in out.stdout, out.stdout
    assert "resumed from checkpoint cycle" in out.stdout
    assert "rescales=1" in out.stdout
    # the cycle left a published checkpoint behind
    from repro.dist import checkpoint as ckpt
    assert ckpt.latest_step(tmp_path) is not None


def test_elastic_without_demand_grows_on_queue_backlog(tmp_path):
    """`--elastic` WITHOUT `--elastic-demand` used to be a silent no-op
    (offered = achieved x workers -> utilization exactly 1.0, never
    crossing a threshold). Offered load now derives from the stream
    feeder's queue depth: the generator outpaces the smoke-config train
    step on CPU, the backlog builds, and the controller must emit a grow
    plan driven through the checkpoint rescale cycle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-1.5b",
         "--smoke", "--steps", "8", "--batch", "2", "--seq", "16",
         "--data-mesh", "1", "--elastic", "--max-workers", "2",
         "--ckpt-every", "50", "--ckpt-dir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "elastic grow -> 2 workers" in out.stdout, out.stdout
    assert "resumed from checkpoint cycle" in out.stdout
    from repro.dist import checkpoint as ckpt
    assert ckpt.latest_step(tmp_path) is not None


def test_dryrun_single_cell_small_mesh():
    """The dry-run machinery on a small in-test mesh: lower+compile a
    reduced arch over (2,4) and extract scan-aware roofline terms."""
    out = _run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.configs.base import InputShape
        from repro.dist import use_mesh
        from repro.dist.sharding import build_rules
        from repro.launch import hlo_analysis as ha
        from repro.models import model_zoo as zoo
        from repro.train.optim import make_optimizer
        from repro.train.train_step import make_train_step

        cfg = get_config("granite-moe-1b-a400m", smoke=True).with_overrides(
            recipe="ep_fsdp")
        shape = InputShape("tiny_train", 32, 8, "train")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = build_rules(cfg, shape=shape)
        opt = make_optimizer(cfg, "adamw")
        ts = make_train_step(cfg, opt, microbatches=1)
        params = zoo.init_params(cfg, 0)
        state = opt.init(params)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32)}
        with use_mesh(mesh, rules):
            compiled = jax.jit(ts).lower(params, state, jnp.asarray(0),
                                         batch).compile()
        t = ha.analyze(compiled.as_text())
        assert t["flops"] > 0
        assert compiled.memory_analysis().temp_size_in_bytes > 0
        print("CELL_OK flops=%.3e coll=%.3e" % (
            t["flops"], t["collective_bytes_total"]))
    """)
    assert "CELL_OK" in out
