"""Operator-DAG IR: channel-inferred dependencies, frontier (downward-
closed) cuts, per-crossing-edge pricing, frontier placement vs the
exhaustive oracle, linear-parity with the prefix-cut path, and the
orchestrator running a fan-out/rejoin graph end to end."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import (Objective, frontier_plans, place,
                                  place_frontier, place_graph_exhaustive,
                                  prefix_cut_plans)
from repro.streams.generators import HyperplaneStream

RES = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}


def _batches(n, dim=8, n_per=32, seed=0, **gen_kw):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per, **gen_kw)
    return [gen.batch(i, n_per) for i in range(n)]


def _run_graph(graph, data, frontier, seed=0):
    states = graph.init_states()
    rng = jax.random.PRNGKey(seed)
    outs = []
    for b in data:
        bd = {k: jnp.asarray(v) for k, v in b.data.items()}
        bd["rng"] = rng
        states, out = graph.run(states, bd, frontier)
        rng = out["rng"]
        outs.append({k: np.asarray(v) for k, v in out.items() if k != "rng"})
    return states, outs


# ---------------------------------------------------------------------------
# construction + dependency inference
# ---------------------------------------------------------------------------

def test_opgraph_requires_channel_declarations():
    undeclared = pl.Op("mystery", lambda s, b: (s, b),
                       cm.OperatorCost("mystery", 1.0, 1.0, 1.0))
    with pytest.raises(ValueError, match="declare reads/writes"):
        pl.OpGraph([pl.normalize_op(4), undeclared])
    # the same op is fine in a linear Pipeline (conservative chain deps)
    pl.Pipeline([pl.normalize_op(4), undeclared])


def test_opgraph_rejects_non_topological_order():
    with pytest.raises(ValueError, match="order ops topologically"):
        pl.OpGraph([pl.drift_op(), pl.normalize_op(4),
                    pl.logreg_train_op(4)])


def test_fanout_dependency_structure():
    g = pl.fanout_stream_graph(dim=8)
    assert g.names == ["normalize", "sketch", "anomaly", "sample", "train",
                       "drift", "alert"]
    assert g.parents_of("sketch") == {"normalize"}
    assert g.parents_of("anomaly") == {"normalize"}
    assert g.parents_of("train") == {"normalize", "sample"}
    assert g.parents_of("alert") == {"anomaly", "drift"}
    assert ("normalize", "anomaly") in g.flow_edges
    assert ("train", "drift") in g.flow_edges
    # raw-stream channels: x into normalize, y/rng into sample+train
    assert "x" in g.source_reads and "y" in g.source_reads
    assert g.source_consumers[0] == "normalize"


def test_frontier_validation():
    g = pl.fanout_stream_graph(dim=8)
    # parallel branches can be cut independently: anomaly without sketch
    assert g.check_frontier({"normalize", "anomaly"})
    with pytest.raises(ValueError, match="downward-closed"):
        g.check_frontier({"anomaly"})          # missing ancestor normalize
    with pytest.raises(ValueError, match="unknown"):
        g.check_frontier({"normalize", "nope"})


def test_frontier_enumeration_matches_bruteforce():
    g = pl.fanout_stream_graph(dim=8)
    fronts = set(g.frontiers())
    assert frozenset() in fronts and frozenset(g.names) in fronts
    # brute force over all subsets, keeping the downward-closed ones
    import itertools
    expect = set()
    for r in range(len(g.names) + 1):
        for combo in itertools.combinations(g.names, r):
            f = set(combo)
            if all(g.parents_of(n) <= f for n in f):
                expect.add(frozenset(f))
    assert fronts == expect
    # strictly richer than any single linear ordering's n+1 prefixes
    assert len(fronts) > len(g.names) + 1


def test_pipeline_frontiers_are_exactly_the_prefixes():
    pipe = pl.standard_stream_pipeline(dim=8)
    fronts = list(pipe.frontiers())
    assert len(fronts) == pipe.n_cuts
    assert set(fronts) == {frozenset(pipe.names[:k])
                           for k in range(pipe.n_cuts)}


# ---------------------------------------------------------------------------
# execution: every downward-closed cut is bitwise the reference
# ---------------------------------------------------------------------------

def test_every_frontier_matches_unpartitioned_reference():
    g = pl.fanout_stream_graph(dim=8, sample_rate=0.7)
    data = _batches(3)
    ref_states, ref_outs = _run_graph(g, data, frozenset())
    n_checked = 0
    for frontier in g.frontiers():
        states, outs = _run_graph(g, data, frontier)
        for a, b in zip(ref_outs, outs):
            assert set(a) == set(b)
            for k in a:
                np.testing.assert_array_equal(
                    a[k], b[k], err_msg=f"frontier={sorted(frontier)} [{k}]")
        for a, b in zip(jax.tree.leaves(ref_states),
                        jax.tree.leaves(states)):
            np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b),
                err_msg=f"frontier={sorted(frontier)} state")
        n_checked += 1
    assert n_checked > 8            # the fan-out graph has many frontiers


def test_non_prefix_frontier_executes():
    """A cut no linear pipeline can express: anomaly stays on the edge
    while the sampler/learner branch (listed between them) offloads."""
    g = pl.fanout_stream_graph(dim=8)
    f = frozenset({"normalize", "anomaly"})
    names = g.names
    picked = sorted(names.index(n) for n in f)
    assert picked != list(range(len(picked)))   # not a prefix of the order
    _, outs = _run_graph(g, _batches(2), f)
    assert "alert" in outs[-1] and "score" in outs[-1]


def test_graph_compile_cache_hit_on_frontier_revisit():
    g = pl.fanout_stream_graph(dim=8)
    data = _batches(3)
    f1 = frozenset({"normalize", "sketch", "anomaly", "sample", "train"})
    f2 = frozenset({"normalize", "anomaly"})
    states = g.init_states()
    rng = jax.random.PRNGKey(0)
    for b, f in zip(data, (f1, f2, f1)):       # migrate away and back
        bd = {k: jnp.asarray(v) for k, v in b.data.items()}
        bd["rng"] = rng
        states, out = g.run(states, bd, f)
        rng = out["rng"]
    compiles_after_first_visit = g.compiles
    assert g.cache_hits >= 2                   # f1 revisit was free
    bd = {k: jnp.asarray(v) for k, v in data[0].data.items()}
    bd["rng"] = rng
    g.run(states, bd, f1)
    assert g.compiles == compiles_after_first_visit


@pytest.mark.parametrize("linear", [False, True])
def test_fuse_xla_segments_match_op_mode_allclose(linear):
    """Whole-segment jit (`fuse="xla"`) keeps op semantics — allclose to
    the per-op composition, though not bitwise across fusion contexts."""
    if linear:
        ref = pl.standard_stream_pipeline(dim=8)
        xla = pl.Pipeline(ref.ops, fuse="xla")
        cuts = (0, 2, len(ref.ops))
    else:
        ref = pl.fanout_stream_graph(dim=8)
        xla = pl.OpGraph(ref.ops, fuse="xla")
        cuts = (frozenset(), frozenset({"normalize", "anomaly"}))
    data = _batches(2)
    for cut in cuts:
        (sa, oa), (sb, ob) = (_run_pipe_or_graph(p, data, cut)
                              for p in (ref, xla))
        for a, b in zip(jax.tree.leaves((sa, oa)), jax.tree.leaves((sb, ob))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"cut={cut}")


def _run_pipe_or_graph(p, data, cut):
    states = p.init_states()
    rng = jax.random.PRNGKey(0)
    outs = []
    for b in data:
        bd = {k: jnp.asarray(v) for k, v in b.data.items()}
        bd["rng"] = rng
        states, out = p.run(states, bd, cut)
        rng = out["rng"]
        outs.append({k: np.asarray(v) for k, v in out.items() if k != "rng"})
    return states, outs


# ---------------------------------------------------------------------------
# placement: frontier search vs exhaustive oracle, linear parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate", [1e2, 1e4, 1e6])
def test_frontier_search_matches_graph_oracle(rate):
    g = pl.fanout_stream_graph(dim=16)
    obj = Objective()
    best, frontier = place_frontier(g, RES, rate, obj)
    oracle = place_graph_exhaustive(g, RES, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001
    assert g.check_frontier(frontier) == frontier


def test_frontier_plans_price_each_crossing_edge():
    """Cutting between normalize and its three consumers pays three
    crossing charges (normalize multicasts once per remote pool, plus the
    raw-stream channels sample/train still read), not one cut-point."""
    g = pl.fanout_stream_graph(dim=16)
    plans = dict(frontier_plans(g, RES, 1e4))
    only_norm = plans[frozenset({"normalize"})]
    all_edge_capable = plans[frozenset(
        {"normalize", "sketch", "anomaly", "sample", "train"})]
    # cutting right after normalize crosses normalize->out once plus the
    # raw stream (y labels for sample/train); cutting after train crosses
    # train->drift (8 bytes) + anomaly->alert (4 bytes) + sample's thinned
    # stream is consumed on-edge, so the uplink is far cheaper
    assert all_edge_capable.uplink_utilization < only_norm.uplink_utilization


def test_linear_pipeline_plans_unchanged_vs_prefix_cut():
    """PR 2 parity: a linear Pipeline priced/partitioned through the new
    frontier machinery must produce exactly the prefix-cut plans, and
    place() must keep returning the same chosen plan and cost."""
    pipe = pl.standard_stream_pipeline(dim=16)
    ops = pipe.costs()
    for rate in (1e2, 1e4, 3e6):
        by_cut = {k: plan for k, plan in prefix_cut_plans(ops, RES, rate)}
        for frontier, plan in frontier_plans(pipe, RES, rate):
            ref = by_cut[len(frontier)]
            assert frontier == frozenset(pipe.names[:len(frontier)])
            assert plan.assignment == ref.assignment
            assert plan.latency_s == pytest.approx(ref.latency_s)
            assert plan.uplink_utilization == pytest.approx(
                ref.uplink_utilization)
            assert plan.energy_w == pytest.approx(ref.energy_w)
            assert plan.feasible == ref.feasible
        lin_plan, lin_cut = place(ops, RES, rate)
        g_plan, g_frontier = place_frontier(pipe, RES, rate)
        assert len(g_frontier) == lin_cut
        assert g_plan.assignment == lin_plan.assignment
        obj = Objective()
        assert obj.score(g_plan) == pytest.approx(obj.score(lin_plan))


def test_backhaul_assignments_are_infeasible():
    g = pl.fanout_stream_graph(dim=8)
    assign = {n: "cloud" for n in g.names}
    assign["alert"] = "edge"                   # consumes cloud-made drifted
    plan = cm.evaluate_graph_plan(
        g.costs(), g.flow_edges, assign, RES, 1e3,
        source_consumers=g.source_consumers,
        source_bytes=g.source_bytes_per_event)
    assert not plan.feasible
    assert any("backhaul" in n for n in plan.notes)


# ---------------------------------------------------------------------------
# offload controller over a graph: hysteresis on plan identity
# ---------------------------------------------------------------------------

def test_graph_offload_migrates_frontier_on_burst():
    g = pl.fanout_stream_graph(dim=16)
    ctl = OffloadController(g.costs(), RES, graph=g, cooldown=1)
    d0 = ctl.initial_plan(1e3)
    assert d0.frontier == ctl.frontier and d0.cut == len(d0.frontier)
    assert len(d0.frontier) > 0, "cheap rate keeps work on the edge"
    d1 = ctl.observe(1, 5e6)                   # big burst
    assert d1.reason == "rate_up"
    assert d1.frontier < d0.frontier, "burst must shrink the edge set"
    assert ctl.migrations() == 1


def test_graph_offload_hysteresis_holds_inside_band():
    g = pl.fanout_stream_graph(dim=16)
    ctl = OffloadController(g.costs(), RES, graph=g, cooldown=3)
    ctl.initial_plan(1e4)
    for step in range(1, 30):
        d = ctl.observe(step, 1e4 * (1.1 if step % 2 else 0.9))
        assert d.reason == "hold"
    assert ctl.migrations() == 0


# ---------------------------------------------------------------------------
# orchestrator: graph jobs end to end
# ---------------------------------------------------------------------------

def test_orchestrator_runs_fanout_graph_and_migrates():
    """The orchestrator plans, executes, and migrates a fan-out graph; a
    spike moves work off the edge and every per-batch output matches the
    pinned all-cloud reference bitwise."""
    def rate_fn(step):
        return 1e3 if step < 8 else 5e6

    dim = 16
    data = _batches(24, dim=dim, n_per=64)
    job = StreamJob("fan", dim=dim, pipeline=pl.fanout_stream_graph(dim))
    orch = Orchestrator(job)
    m = orch.run(data, rate_fn=rate_fn, record_outputs=True)

    assert m.events == 24 * 64
    assert m.migrations >= 1, "spike must migrate the frontier"
    assert len(set(m.assignments)) >= 2
    assert len(m.assignments[0]) > len(m.assignments[-1]), \
        "spike pushes work off the edge"
    assert any("repartition" in d for d in m.decisions)
    assert m.preq is not None                  # train op metrics surfaced

    ref = Orchestrator(StreamJob("ref", dim=dim,
                                 pipeline=pl.fanout_stream_graph(dim)))
    mr = ref.run(data, rate_fn=rate_fn, fixed_frontier=frozenset(),
                 record_outputs=True)
    assert len(m.outputs) == len(mr.outputs)
    for a, b in zip(m.outputs, mr.outputs):
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(
                a[k], b[k], err_msg=f"migrated run diverged on {k}")
    assert m.preq == mr.preq


def test_orchestrator_elastic_rescale_through_checkpoint_cycle(tmp_path):
    """A sustained overload makes the ElasticController grow, and the
    orchestrator now drives the plan through elastic.rescale_cycle:
    states round-trip a published checkpoint bitwise and land on the
    rebuilt mesh."""
    dim = 8
    data = _batches(16, dim=dim, n_per=32)
    job = StreamJob("grow", dim=dim, workers=1, max_workers=4,
                    ckpt_dir=str(tmp_path))
    orch = Orchestrator(job)
    m = orch.run(data, rate_fn=lambda s: 5e7, record_outputs=True)
    assert m.rescales >= 1
    assert m.workers > 1
    assert any("elastic-grow" in d for d in m.decisions)
    from repro.dist import checkpoint as ckpt
    assert ckpt.latest_step(tmp_path) is not None, \
        "rescale must publish a checkpoint"
    # the rescale cycle must not perturb learner state: bitwise vs a angry
    # reference run whose elastic controller is capped at 1 worker
    ref = Orchestrator(StreamJob("ref", dim=dim, workers=1, max_workers=1))
    mr = ref.run(data, rate_fn=lambda s: 5e7, record_outputs=True)
    assert mr.rescales == 0
    for a, b in zip(m.outputs, mr.outputs):
        for k in a:
            np.testing.assert_array_equal(
                a[k], b[k], err_msg=f"rescale cycle perturbed {k}")
    assert m.preq == mr.preq


def test_orchestrator_advances_rng_without_threading_op():
    """Stale-RNG regression: a pipeline with no op that threads `rng`
    must still see fresh randomness every step (the orchestrator now
    splits the key per step instead of reusing the initial one)."""
    dim = 4

    def fn(state, batch):
        noise = jax.random.normal(batch["rng"], (dim,))
        return state, {**batch, "noise": noise}

    noise = pl.Op("noise", fn,
                  cm.OperatorCost("noise", 10.0, 16.0, 4.0 * dim),
                  reads=("rng",), writes=("noise",))
    pipe = pl.Pipeline([noise])
    job = StreamJob("noisy", dim=dim, pipeline=pipe)
    m = Orchestrator(job).run(_batches(3, dim=dim), rate_fn=lambda s: 1e3,
                              record_outputs=True)
    n0, n1 = m.outputs[0]["noise"], m.outputs[1]["noise"]
    assert not np.array_equal(n0, n1), \
        "consecutive steps must not reuse the same PRNG key"
