"""Multi-tenant fleet scheduling (core/fleet): residual-capacity
pricing, admission control with loud rejection, the per-tenant
reservation ledger and its capacity invariants (property-tested),
fleet-batched replan arbitration with priority tiers and cooldowns,
mid-run join/leave with queued re-admission, and the single-tenant
differential — a fleet of one must be indistinguishable from a
standalone StreamJob on the same spec."""

import random

import pytest

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.fleet import (AdmissionResult, FleetLedger,
                              FleetOrchestrator, FleetScheduler, TenantSpec)
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.sla import SLA, pick_codec
from repro.streams.generators import HyperplaneStream

LOOSE = SLA(max_latency_s=1e3, error_budget=11.0)


def two_pool_spec(**link_kw) -> cm.ClusterSpec:
    links = [cm.Link("edge", "cloud", **link_kw)] if link_kw else []
    return cm.ClusterSpec(pools=[cm.EDGE_NODE, cm.CLOUD_POD], links=links)


def make_controller(spec, sla=LOOSE, dim=8, **kw) -> OffloadController:
    # start from the codec static admission picks, exactly like the
    # Orchestrator does — calibrated link sizes then transfer between
    # scheduler-level and orchestrator-level tests
    kw.setdefault("codec", pick_codec(sla).name)
    return OffloadController(pl.standard_stream_pipeline(dim=dim).costs(),
                             spec, sla_spec=sla, **kw)


def _batches(n, dim=8, n_per=32, seed=0):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per)
    return [gen.batch(i, n_per) for i in range(n)]


# ---------------------------------------------------------------------------
# residual-capacity pricing (ClusterSpec.residual)
# ---------------------------------------------------------------------------

def test_residual_zero_load_returns_identical_objects():
    """The single-tenant bitwise-parity path: no foreign load means the
    residual spec carries the very same pool and link objects."""
    spec = two_pool_spec(bw=1e9, latency=20e-3)
    r = spec.residual()
    assert r["edge"] is spec["edge"] and r["cloud"] is spec["cloud"]
    assert r.link("edge", "cloud") is spec.link("edge", "cloud")


def test_residual_scales_pool_rates_and_link_bw():
    spec = two_pool_spec(bw=1e9, latency=20e-3)
    r = spec.residual(pool_load={"edge": 0.75},
                      link_load={("edge", "cloud"): 4e8},
                      pool_state_bytes={"cloud": 256e9})
    assert r["edge"].flops == pytest.approx(cm.EDGE_NODE.flops * 0.25)
    assert r["edge"].mem_bw == pytest.approx(cm.EDGE_NODE.mem_bw * 0.25)
    assert r.link("edge", "cloud").bw == pytest.approx(6e8)
    # state shrinks per-chip mem_cap
    assert r["cloud"].mem_cap == pytest.approx(
        cm.CLOUD_POD.mem_cap - 256e9 / cm.CLOUD_POD.chips)
    # untouched dimensions pass through
    assert r["cloud"].flops == cm.CLOUD_POD.flops
    assert r.link("edge", "cloud").latency == 20e-3


def test_residual_fully_reserved_pool_prices_infeasible_not_div0():
    spec = two_pool_spec()
    r = spec.residual(pool_load={"edge": 1.0})
    # epsilon share, not zero: no div-by-zero, but hopelessly slow
    assert 0.0 < r["edge"].flops <= cm.EDGE_NODE.flops * 1e-6
    plan = cm.evaluate_plan(pl.standard_stream_pipeline(dim=8).costs(),
                            {op.name: "edge" for op in
                             pl.standard_stream_pipeline(dim=8).costs()
                             if True},
                            r, rate=1e4)
    assert not plan.feasible


def test_residual_validates_inputs():
    spec = two_pool_spec()
    with pytest.raises(ValueError, match="unknown pool"):
        spec.residual(pool_load={"nope": 0.5})
    with pytest.raises(ValueError, match="not in"):
        spec.residual(pool_load={"edge": 1.5})
    with pytest.raises(ValueError, match="unknown link"):
        spec.residual(link_load={("edge", "nope"): 1.0})


def test_second_tenant_prices_against_residual_not_whole_link():
    """The same demand rate costs MORE uplink utilization once another
    tenant holds part of the link — evaluate_graph_plan via the residual
    spec sees only what is left."""
    spec = two_pool_spec(bw=1e9, latency=20e-3)
    sched = FleetScheduler(spec)
    c0 = make_controller(spec)
    r0 = sched.submit(TenantSpec("t0", sla=LOOSE, demand_rate=2e4), c0)
    assert r0.admitted
    alone_util = r0.decision.plan.uplink_utilization
    booked = sum(sched.ledger.link_load().values())
    assert booked > 0.0
    c1 = make_controller(spec)
    r1 = sched.submit(TenantSpec("t1", sla=LOOSE, demand_rate=2e4), c1)
    assert r1.admitted
    # identical demand, but priced on (bw - t0's bytes): utilization up
    assert r1.decision.plan.uplink_utilization > alone_util
    resid_bw = sched.ledger.spec.link("edge", "cloud").bw - booked
    assert c1.resources.link("edge", "cloud").bw == pytest.approx(resid_bw)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_overdemand_tenant_rejected_with_loud_reason():
    sched = FleetScheduler(two_pool_spec())
    res = sched.submit(TenantSpec("hog", sla=LOOSE, demand_rate=1e9),
                       make_controller(two_pool_spec()), queue=False)
    assert not res.admitted and not res.queued
    assert "hog" in res.reason and "cannot be admitted" in res.reason
    assert "infeasible" in res.reason
    assert "1e+09" in res.reason  # the demand it failed at
    assert "hog" not in sched.admitted and "hog" not in sched.queued
    # the rejection is also in the audit log
    assert any("hog" in line for line in sched.log)


def test_latency_sla_rejection_names_the_clause():
    tight = SLA(max_latency_s=1e-9, error_budget=11.0)
    sched = FleetScheduler(two_pool_spec())
    res = sched.submit(TenantSpec("t", sla=tight, demand_rate=1e4),
                       make_controller(two_pool_spec(), sla=tight),
                       queue=False)
    assert not res.admitted
    assert "exceeds SLA" in res.reason and "latency" in res.reason


def test_duplicate_submit_rejected():
    sched = FleetScheduler(two_pool_spec())
    sched.submit(TenantSpec("a", sla=LOOSE), make_controller(two_pool_spec()))
    with pytest.raises(ValueError, match="already submitted"):
        sched.submit(TenantSpec("a", sla=LOOSE),
                     make_controller(two_pool_spec()))


def test_departure_readmits_queued_tenant_within_one_pass():
    """A link sized for ONE tenant: the second queues at admission; the
    first tenant's departure must re-admit it in the same pass."""
    spec, rate = _one_tenant_link_spec()
    sched = FleetScheduler(spec)
    a = sched.submit(TenantSpec("a", sla=LOOSE, demand_rate=rate),
                     make_controller(spec))
    assert a.admitted
    b = sched.submit(TenantSpec("b", sla=LOOSE, demand_rate=rate),
                     make_controller(spec))
    assert not b.admitted and b.queued
    assert sched.queued == ["b"]
    out = sched.leave("a")
    assert [(r.name, r.admitted) for r in out] == [("b", True)]
    assert sched.admitted == ["b"] and sched.queued == []
    assert sched.ledger.check() == []


def _one_tenant_link_spec():
    """A spec whose uplink fits one standard-pipeline tenant at the
    returned rate but not two (calibrated from the actual booking)."""
    probe_spec = two_pool_spec(bw=1e9, latency=20e-3)
    sched = FleetScheduler(probe_spec)
    rate = 1e4
    res = sched.submit(TenantSpec("probe", sla=LOOSE, demand_rate=rate),
                       make_controller(probe_spec))
    assert res.admitted
    need = sum(sched.ledger.link_load().values())
    assert need > 0.0
    return two_pool_spec(bw=need * 1.5, latency=20e-3), rate


# ---------------------------------------------------------------------------
# fleet-batched arbitration
# ---------------------------------------------------------------------------

def test_one_tenants_trigger_does_not_stampede_the_other():
    spec = two_pool_spec()
    sched = FleetScheduler(spec)
    ca = make_controller(spec, cooldown=0, codec_cooldown=0)
    cb = make_controller(spec, cooldown=0, codec_cooldown=0)
    sched.submit(TenantSpec("a", sla=LOOSE, demand_rate=1e4), ca)
    sched.submit(TenantSpec("b", sla=LOOSE, demand_rate=1e4), cb)
    # steady state: everyone holds, no history growth
    d = sched.arbitrate(1, {"a": 1e4, "b": 1e4})
    assert d["a"].reason == "hold" and d["b"].reason == "hold"
    assert len(ca.history) == 1 and len(cb.history) == 1
    # only a's rate leaves its band -> only a replans
    d = sched.arbitrate(2, {"a": 5e4, "b": 1e4})
    assert d["a"].reason == "rate_up" and d["b"].reason == "hold"
    assert len(ca.history) == 2 and len(cb.history) == 1
    assert any("grant a" in line for line in sched.log)
    assert not any("grant b" in line for line in sched.log)


def test_fleet_cooldown_holds_back_to_back_grants():
    spec = two_pool_spec()
    sched = FleetScheduler(spec)
    c = make_controller(spec, cooldown=0, codec_cooldown=0)
    sched.submit(TenantSpec("a", sla=LOOSE, demand_rate=1e4,
                            replan_cooldown=5), c)
    d = sched.arbitrate(1, {"a": 5e4})
    assert d["a"].reason == "rate_up"
    # wants another replan immediately, but the FLEET cooldown holds it
    d = sched.arbitrate(2, {"a": 1e4})
    assert d["a"].reason == "hold"
    assert any("cooldown holds" in line for line in sched.log)
    # past the cooldown the replan goes through
    d = sched.arbitrate(6, {"a": 1e4})
    assert d["a"].reason == "rate_down"


def test_priority_tier_order_in_one_pass():
    """When several tenants trigger in one pass, grants run lower-tier
    first (tier 0 re-prices before tier 1 eats its residual)."""
    spec = two_pool_spec()
    sched = FleetScheduler(spec)
    c_lo = make_controller(spec, cooldown=0, codec_cooldown=0)
    c_hi = make_controller(spec, cooldown=0, codec_cooldown=0)
    sched.submit(TenantSpec("cheap", sla=LOOSE, demand_rate=1e4,
                            priority=5), c_lo)
    sched.submit(TenantSpec("prem", sla=LOOSE, demand_rate=1e4,
                            priority=0), c_hi)
    sched.arbitrate(1, {"cheap": 5e4, "prem": 5e4})
    grants = [line for line in sched.log if "grant" in line]
    assert len(grants) == 2
    assert "prem" in grants[0] and "cheap" in grants[1]


# ---------------------------------------------------------------------------
# capacity invariants (property-tested)
# ---------------------------------------------------------------------------

def test_ledger_capacity_invariant_under_random_churn():
    """Randomized admit/leave/arbitrate churn: at every point, summed
    per-tenant reserved link bytes stay within each link's capacity and
    pool fractions within 1.0 (FleetLedger.check)."""
    rng = random.Random(7)
    spec = two_pool_spec(bw=3e5, latency=20e-3)  # tight: rejections happen
    sched = FleetScheduler(spec)
    live, nxt, admitted_ever, rejected_ever = {}, 0, 0, 0
    for step in range(60):
        op = rng.random()
        if op < 0.35 and len(live) < 6:
            name = f"t{nxt}"
            nxt += 1
            rate = rng.choice([5e3, 1e4, 3e4, 8e4])
            res = sched.submit(
                TenantSpec(name, sla=LOOSE, demand_rate=rate,
                           priority=rng.randint(0, 2)),
                make_controller(spec, cooldown=rng.choice([0, 2])),
                queue=False)
            if res.admitted:
                live[name] = rate
                admitted_ever += 1
            else:
                rejected_ever += 1
        elif op < 0.5 and live:
            gone = rng.choice(sorted(live))
            del live[gone]
            for r in sched.leave(gone):
                if r.admitted:
                    live[r.name] = 0.0
        elif live:
            offered = {n: rng.choice([5e3, 1e4, 3e4, 8e4]) for n in live}
            sched.arbitrate(step, offered)
        bad = sched.ledger.check()
        assert bad == [], f"step {step}: {bad}\nlog tail: {sched.log[-4:]}"
        assert set(sched.ledger.reservations) == set(live)
    # the churn actually exercised both admission outcomes
    assert admitted_ever >= 3 and rejected_ever >= 3


# ---------------------------------------------------------------------------
# single-tenant differential vs standalone StreamJob
# ---------------------------------------------------------------------------

def test_fleet_of_one_matches_standalone_run():
    """Plans, codec trajectory, and migration history of a 1-tenant
    fleet must be IDENTICAL to a standalone run on the same spec — the
    fleet layer is a no-op until a second tenant shows up."""
    def rate_fn(s):
        return 1e4 * (4.0 if s >= 6 else 1.0)

    n = 12
    solo = Orchestrator(StreamJob("solo", dim=8, sla=LOOSE))
    m_solo = solo.run(_batches(n), rate_fn=rate_fn, seed=0)

    fleet = FleetOrchestrator(two_pool_spec())
    res = fleet.add_tenant(
        TenantSpec("solo", sla=LOOSE, demand_rate=rate_fn(0)),
        StreamJob("solo", dim=8, sla=LOOSE), seed=0)
    assert res.admitted
    for i, b in enumerate(_batches(n)):
        fleet.step_round({"solo": b}, rates={"solo": rate_fn(i)})
    m_fleet = fleet.finish()["solo"]

    assert m_fleet.plan_identities == m_solo.plan_identities
    assert m_fleet.codecs == m_solo.codecs
    assert m_fleet.cuts == m_solo.cuts
    assert m_fleet.assignments == m_solo.assignments
    assert m_fleet.migrations == m_solo.migrations
    assert m_fleet.events == m_solo.events

    def control_lines(m):
        # elastic lines embed measured wall-clock rates; the CONTROL
        # trajectory (init/replan/codec/repartition) must match exactly
        return [d for d in m.decisions if "elastic" not in d]

    assert control_lines(m_fleet) == control_lines(m_solo)


# ---------------------------------------------------------------------------
# FleetOrchestrator: multi-tenant rounds + churn
# ---------------------------------------------------------------------------

def test_three_tenant_round_robin_with_mid_run_churn():
    spec = two_pool_spec()
    fleet = FleetOrchestrator(spec)
    for i in range(3):
        res = fleet.add_tenant(
            TenantSpec(f"t{i}", sla=LOOSE, demand_rate=1e4,
                       priority=i % 2),
            StreamJob(f"t{i}", dim=8, sla=LOOSE), seed=i)
        assert res.admitted, res.reason
    assert fleet.scheduler.admitted == ["t0", "t1", "t2"]

    feeds = {f"t{i}": _batches(6, seed=10 + i) for i in range(3)}
    for step in range(3):
        measured = fleet.step_round(
            {n: feeds[n][step] for n in fleet.orchestrators})
        assert set(measured) == {"t0", "t1", "t2"}
        assert fleet.scheduler.ledger.check() == []

    # t1 departs mid-run; its metrics close out, capacity returns
    m1, readmits = fleet.leave("t1")
    assert m1.events == 3 * 32
    assert readmits == []
    assert "t1" not in fleet.scheduler.ledger.reservations

    for step in range(3, 5):
        fleet.step_round({n: feeds[n][step] for n in fleet.orchestrators})
        assert fleet.scheduler.ledger.check() == []
    out = fleet.finish()
    assert set(out) == {"t0", "t2"}
    for m in out.values():
        assert m.events == 5 * 32
        assert m.sla is not None and m.preq is not None
    # per-tenant trackers stayed independent (each fed only its own run)
    assert all(m.sla["window_checks"] == 5.0 for m in out.values())


def test_fleet_orchestrator_queued_tenant_activates_on_leave():
    spec, rate = _one_tenant_link_spec()
    fleet = FleetOrchestrator(spec)
    ra = fleet.add_tenant(TenantSpec("a", sla=LOOSE, demand_rate=rate),
                          StreamJob("a", dim=8, sla=LOOSE))
    rb = fleet.add_tenant(TenantSpec("b", sla=LOOSE, demand_rate=rate),
                          StreamJob("b", dim=8, sla=LOOSE))
    assert ra.admitted and not rb.admitted and rb.queued
    assert list(fleet.orchestrators) == ["a"]
    fa = _batches(2, seed=1)
    fleet.step_round({"a": fa[0]})
    m_a, readmits = fleet.leave("a")
    assert m_a.events == 32
    assert [(r.name, r.admitted) for r in readmits] == [("b", True)]
    # b is live and steps immediately
    assert list(fleet.orchestrators) == ["b"]
    fleet.step_round({"b": _batches(1, seed=2)[0]})
    m_b = fleet.finish()["b"]
    assert m_b.events == 32
    assert fleet.scheduler.ledger.check() == []


def test_fleet_rejects_mismatched_job_cluster():
    fleet = FleetOrchestrator(two_pool_spec())
    other = cm.ClusterSpec(pools=[
        cm.Resource("edge2", "edge"), cm.Resource("cloud2", "cloud")])
    with pytest.raises(ValueError, match="different cluster"):
        fleet.add_tenant(TenantSpec("x", sla=LOOSE),
                         StreamJob("x", dim=8, sla=LOOSE, cluster=other))


# ---------------------------------------------------------------------------
# queue re-admission ordering (drain_queue)
# ---------------------------------------------------------------------------

def _queue_three(sched, spec, rate):
    """Queue three tenants — a premium one submitted LAST and two
    standard ones in FIFO order — behind a full link."""
    for name, prio in [("std1", 1), ("std2", 1), ("prem", 0)]:
        res = sched.submit(TenantSpec(name, priority=prio, sla=LOOSE,
                                      demand_rate=rate),
                           make_controller(spec))
        assert not res.admitted and res.queued
    assert sched.queued == ["std1", "std2", "prem"]


def test_drain_queue_priority_then_fifo_after_departure():
    """drain_queue re-admits in priority order, FIFO within a tier: the
    late-arriving premium tenant jumps the queue, and among equal-tier
    tenants arrival order decides."""
    spec, rate = _one_tenant_link_spec()
    sched = FleetScheduler(spec)
    a = sched.submit(TenantSpec("a", sla=LOOSE, demand_rate=rate),
                     make_controller(spec))
    assert a.admitted
    _queue_three(sched, spec, rate)
    # one slot frees; exactly one re-admission — the premium tier wins
    out = sched.leave("a")
    assert [(r.name, r.admitted) for r in out] == [("prem", True)]
    assert sched.queued == ["std1", "std2"]  # FIFO order preserved
    # next slot goes to the older standard tenant
    out = sched.leave("prem")
    assert [r.name for r in out] == ["std1"]
    assert sched.queued == ["std2"]
    assert sched.ledger.check() == []


def test_drain_queue_priority_then_fifo_after_membership_join():
    """The same ordering contract when the capacity arrives as a
    membership POOL_JOINED event: the round's event drain re-admits
    the premium tenant before the standard ones, FIFO within a tier.
    Queued tenants are DAG jobs — linear pipelines collapse to the
    first edge pool and could never use a joiner."""
    from repro.core.membership import MembershipDirectory

    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3))
    fleet = FleetOrchestrator(membership=d)
    a = fleet.add_tenant(TenantSpec("a", sla=LOOSE, demand_rate=1e4),
                         StreamJob("a", dim=8, sla=LOOSE), seed=0)
    assert a.admitted
    for i, (name, prio) in enumerate([("std1", 1), ("std2", 1),
                                      ("prem", 0)]):
        res = fleet.add_tenant(
            TenantSpec(name, priority=prio, sla=LOOSE, demand_rate=1e6),
            StreamJob(name, dim=8, sla=LOOSE,
                      pipeline=pl.fanout_stream_graph(8)), seed=i + 1)
        assert not res.admitted and res.queued
    assert fleet.scheduler.queued == ["std1", "std2", "prem"]
    # a fat pool joins; next round's drain re-attempts the queue in
    # tier-then-FIFO order (admissions land in that order)
    d.register(cm.Resource("edge_big", "edge", chips=4, flops=8e12,
                           mem_bw=200e9, mem_cap=16e9, net_bw=10e9,
                           net_latency=2e-3),
               links=[cm.Link("edge_big", "cloud", bw=1e9, latency=2e-3)],
               now=1, monitored=False)
    gen = HyperplaneStream(dim=8, seed=9, horizon=2 * 32.0)
    fleet.step_round({"a": gen.batch(0, 32)}, rates={"a": 1e4})
    re_admitted = [n for n in fleet.scheduler.admitted if n != "a"]
    assert re_admitted and re_admitted[0] == "prem"
    assert re_admitted == sorted(
        re_admitted, key=lambda n: (0 if n == "prem" else 1, n))
    # anyone still waiting kept FIFO order
    assert fleet.scheduler.queued == [
        n for n in ["std1", "std2"] if n not in re_admitted]
    assert fleet.scheduler.ledger.check() == []
