"""Dynamic topology (core/membership): the versioned membership
directory (register/deregister, heartbeat leases over the deterministic
sim clock, EWMA latency probes), its typed event flow into the
orchestrator (pool loss -> involuntary checkpoint-rescale -> forced
replan excluding the dead pool) and the fleet (ledger scrub, forced
replans, queue re-admission on joins) — plus the differential contract:
a directory nobody mutates runs bitwise identically to a static
ClusterSpec."""

import warnings

import pytest

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.fleet import FleetOrchestrator, FleetScheduler, TenantSpec
from repro.core.membership import (LINK_UPDATE, POOL_FAILED, POOL_JOINED,
                                   POOL_LEFT, Locality, MembershipDirectory)
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import (edge_cloud_pools, place_frontier,
                                  stale_pools)
from repro.core.sla import SLA, pick_codec
from repro.streams.generators import HyperplaneStream

LOOSE = SLA(max_latency_s=1e3, error_budget=11.0)


def two_pool_spec(**link_kw) -> cm.ClusterSpec:
    links = [cm.Link("edge", "cloud", **link_kw)] if link_kw else []
    return cm.ClusterSpec(pools=[cm.EDGE_NODE, cm.CLOUD_POD], links=links)


def edge_b(name="edge_b", **kw) -> cm.Resource:
    """A strictly better second edge pool, so the frontier search
    prefers it over the seed edge the moment it joins."""
    kw = {"chips": 2, "flops": 4e12, "mem_bw": 100e9, "mem_cap": 8e9,
          "net_bw": 1e9, "net_latency": 5e-3, **kw}
    return cm.Resource(name, "edge", **kw)


def _batches(n, dim=8, n_per=32, seed=0):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per)
    return [gen.batch(i, n_per) for i in range(n)]


def make_controller(spec, sla=LOOSE, dim=8, **kw) -> OffloadController:
    kw.setdefault("codec", pick_codec(sla).name)
    return OffloadController(pl.standard_stream_pipeline(dim=dim).costs(),
                             spec, sla_spec=sla, **kw)


# ---------------------------------------------------------------------------
# directory: versioning, events, subscriptions
# ---------------------------------------------------------------------------

def test_directory_versioning_and_event_flow():
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3))
    assert d.version == 0 and d.spec.version == 0
    assert d.pool_names == ["cloud", "edge"]
    sub = d.subscribe()

    ev = d.register(edge_b(), links=[cm.Link("edge_b", "cloud",
                                             bw=5e6, latency=5e-3)], now=1)
    assert ev.kind == POOL_JOINED and ev.subject == "edge_b"
    assert ev.version == 1 and ev.clock == 1
    assert d.spec.version == 1 and "edge_b" in d.spec.pools
    assert d.spec.link("edge_b", "cloud").bw == 5e6

    ev = d.deregister("edge_b", now=3)
    assert ev.kind == POOL_LEFT and ev.version == 2
    assert "edge_b" not in d.spec.pools
    # links touching the departed pool vanish with it
    assert all("edge_b" not in (ln.src, ln.dst) for ln in d.spec.links)

    # the cursor drains exactly once; a late subscriber sees nothing old
    kinds = [e.kind for e in sub.poll()]
    assert kinds == [POOL_JOINED, POOL_LEFT]
    assert sub.poll() == []
    assert d.subscribe().poll() == []


def test_register_validations():
    d = MembershipDirectory(two_pool_spec())
    with pytest.raises(ValueError, match="already a member"):
        d.register(cm.EDGE_NODE)
    with pytest.raises(ValueError, match="does not touch"):
        d.register(edge_b(), links=[cm.Link("edge", "cloud", bw=1e6,
                                            latency=1e-3)])
    with pytest.raises(ValueError, match="not a member"):
        d.register(edge_b(), links=[cm.Link("edge_b", "nope", bw=1e6,
                                            latency=1e-3)])
    with pytest.raises(ValueError, match="unknown pool"):
        d.deregister("nope")
    with pytest.raises(ValueError, match="unknown pool"):
        d.heartbeat("nope")


def test_lease_expiry_declares_silent_pool_dead():
    d = MembershipDirectory(two_pool_spec(), lease_ticks=3)
    d.register(edge_b(), now=0)
    sub = d.subscribe()
    # heartbeats keep the lease alive
    for t in range(1, 6):
        d.heartbeat("edge_b", now=t)
        assert d.tick(t) == []
    # silence: expires when now - last_seen > lease_ticks
    assert d.tick(8) == []          # 8 - 5 == 3, not yet
    assert d.tick(9) == ["edge_b"]  # 9 - 5 > 3
    assert "edge_b" not in d.spec.pools
    (ev,) = sub.poll()
    assert ev.kind == POOL_FAILED and "lease expired" in ev.detail
    # idempotent: re-ticking expires nothing new
    assert d.tick(9) == [] and d.tick(10) == []


def test_seed_pools_are_not_lease_monitored():
    """A static core topology never expires for want of heartbeats it
    was never promised — only registered (or heartbeating) pools carry
    a lease."""
    d = MembershipDirectory(two_pool_spec(), lease_ticks=2)
    assert not d.monitored("edge") and not d.monitored("cloud")
    assert d.tick(1000) == []
    assert d.pool_names == ["cloud", "edge"]
    # a heartbeat enrolls a seed pool into monitoring
    d.heartbeat("edge", now=1000)
    assert d.monitored("edge")
    assert d.tick(1003) == ["edge"]


# ---------------------------------------------------------------------------
# latency probes (EWMA) + locality
# ---------------------------------------------------------------------------

def test_latency_probe_ewma_rewrites_spec_link():
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3),
                            ewma_alpha=0.5, latency_tol=0.2)
    sub = d.subscribe()
    # one big sample: EWMA moves halfway, beyond the 20% dead band
    ev = d.observe_latency("edge", "cloud", 60e-3, now=1)
    assert ev is not None and ev.kind == LINK_UPDATE
    assert ev.subject == "edge->cloud"
    assert d.spec.link("edge", "cloud").latency == pytest.approx(40e-3)
    assert d.probe_estimate("edge", "cloud") == pytest.approx(40e-3)
    # samples at the current estimate: spec stays fresh, no announcement
    v = d.version
    assert d.observe_latency("edge", "cloud", 40e-3, now=2) is None
    assert d.version > v            # the estimate still versions the spec
    assert [e.kind for e in sub.poll()] == [LINK_UPDATE]
    # probing an unknown pool is loud
    with pytest.raises(ValueError, match="unknown pool"):
        d.observe_latency("edge", "nope", 1e-3)


def test_probes_converge_and_placement_follows_them():
    """Two identical edge pools; probes reveal one uplink is slow
    (80ms) and one fast (1ms). The frontier DP must route the cloud
    hop over the probed-fast link — and swap its choice when the
    probes swap."""
    def probed_spec(far_lat, near_lat):
        d = MembershipDirectory(cm.ClusterSpec(pools=[cm.CLOUD_POD]))
        d.register(edge_b("edge_far"),
                   links=[cm.Link("edge_far", "cloud", bw=5e6,
                                  latency=5e-3)], monitored=False)
        d.register(edge_b("edge_near"),
                   links=[cm.Link("edge_near", "cloud", bw=5e6,
                                  latency=5e-3)], monitored=False)
        for t in range(20):
            d.observe_latency("edge_far", "cloud", far_lat, now=t)
            d.observe_latency("edge_near", "cloud", near_lat, now=t)
        return d.spec

    spec = probed_spec(80e-3, 1e-3)
    assert spec.link("edge_far", "cloud").latency > \
        10 * spec.link("edge_near", "cloud").latency
    graph = pl.fanout_stream_graph(8)
    plan, _ = place_frontier(graph, spec, rate=1e4)
    # the plan dodges the 80ms probed link: the near pool carries the
    # cloud hop and the end-to-end latency stays an order below it
    assert "edge_near" in set(plan.assignment.values())
    assert plan.latency_s < 10e-3
    # swapped probes flip the routing — the DP is probe-driven, not
    # name-driven
    swapped, _ = place_frontier(graph, probed_spec(1e-3, 80e-3), rate=1e4)
    assert swapped.assignment != plan.assignment
    assert "edge_near" not in set(swapped.assignment.values())
    assert swapped.latency_s < 10e-3


def test_locality_derives_distance_latency():
    d = MembershipDirectory(cm.ClusterSpec(pools=[cm.CLOUD_POD]),
                            base_latency=1e-3, latency_per_km=0.05e-3)
    d.register(edge_b("edge_a"), locality=Locality(0.0, 0.0),
               monitored=False)
    d.register(edge_b("edge_c"), locality=Locality(30.0, 40.0),
               monitored=False)
    # derived both ways from the 50km separation: 1ms + 50*0.05ms
    want = 1e-3 + 50.0 * 0.05e-3
    assert d.spec.link("edge_a", "edge_c").latency == pytest.approx(want)
    assert d.spec.link("edge_c", "edge_a").latency == pytest.approx(want)
    # a declared link is never overwritten by the geometric prior
    d.register(edge_b("edge_d"), locality=Locality(3.0, 4.0),
               links=[cm.Link("edge_d", "edge_a", bw=1e9, latency=9e-3)],
               monitored=False)
    assert d.spec.link("edge_d", "edge_a").latency == 9e-3
    assert d.spec.link("edge_a", "edge_d").latency == pytest.approx(
        1e-3 + 5.0 * 0.05e-3)


# ---------------------------------------------------------------------------
# ClusterSpec churn support (satellites)
# ---------------------------------------------------------------------------

def test_without_pool_removes_pool_links_and_bumps_version():
    spec = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, edge_b(), cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=2e6, latency=20e-3),
               cm.Link("edge_b", "cloud", bw=5e6, latency=5e-3)])
    out = spec.without_pool("edge_b")
    assert sorted(out.pools) == ["cloud", "edge"]
    assert all("edge_b" not in (ln.src, ln.dst) for ln in out.links)
    assert out.version == spec.version + 1
    # the original is untouched (specs are snapshots)
    assert "edge_b" in spec.pools
    with pytest.raises(ValueError, match=r"unknown pool 'nope'.*edge_b"):
        spec.without_pool("nope")


def test_link_unknown_pool_raises_valueerror_naming_pools():
    """Satellite: under churn a stale plan's pool name must fail loudly
    in link(), naming the missing pool AND the known set — not as an
    ambiguous KeyError or a bogus derived default."""
    spec = two_pool_spec()
    with pytest.raises(ValueError) as ei:
        spec.link("edge", "gone")
    msg = str(ei.value)
    assert "'gone'" in msg and "edge" in msg and "cloud" in msg
    with pytest.raises(ValueError, match="unknown pool 'gone'"):
        spec.link("gone", "cloud")


def test_edge_cloud_pools_shim_warns_once():
    """Satellite: the two-pool shim emits a real DeprecationWarning."""
    with pytest.warns(DeprecationWarning, match="two-pool shim"):
        e, c = edge_cloud_pools(two_pool_spec())
    assert e.name == "edge" and c.name == "cloud"
    # the default "once per location" filter dedups repeat calls
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("default")
        for _ in range(3):
            edge_cloud_pools(two_pool_spec())
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1


def test_prefix_cut_engine_does_not_warn():
    """place() IS the two-pool engine: its internal collapse must not
    spam a deprecation warning on every replan."""
    from repro.core.placement import place
    ops = pl.standard_stream_pipeline(dim=8).costs()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        place(ops, two_pool_spec(), rate=1e4)


# ---------------------------------------------------------------------------
# stale-plan guard (placement.stale_pools + controller)
# ---------------------------------------------------------------------------

def test_stale_pools_reports_departed_assignment_pools():
    spec = two_pool_spec()
    assert stale_pools({"a": "edge", "b": "cloud"}, spec) == []
    assert stale_pools({"a": "edge_b", "b": "cloud", "c": "edge_b"},
                       spec) == ["edge_b"]


def test_controller_cannot_hold_a_stale_plan():
    """After churn removes a pool the incumbent plan uses, wants_replan
    fires pool_lost straight through the cooldown gate and
    hold_decision refuses outright."""
    big = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, edge_b(), cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=2e6, latency=20e-3),
               cm.Link("edge_b", "cloud", bw=8e6, latency=5e-3)])
    c = OffloadController(pl.fanout_stream_graph(8).costs(), big,
                          graph=pl.fanout_stream_graph(8), sla_spec=LOOSE,
                          codec=pick_codec(LOOSE).name, cooldown=10**6)
    c.initial_plan(1e4, step=0)
    assert "edge_b" in set(c.assignment.values())
    # in-band rate + gigantic cooldown: a healthy topology would hold
    assert c.wants_replan(1, 1e4) is None
    c.set_resources(big.without_pool("edge_b"))
    assert c.wants_replan(1, 1e4) == "pool_lost"
    with pytest.raises(ValueError, match="departed pool"):
        c.hold_decision(1, 1e4)
    d = c.replan(1, 1e4, reason="pool_lost")
    assert "edge_b" not in set(d.assignment.values())
    assert c.wants_replan(2, 1e4) is None  # healthy again


# ---------------------------------------------------------------------------
# orchestrator integration: the headline scenarios
# ---------------------------------------------------------------------------

def _seeded_directory():
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3))
    d.register(edge_b(), links=[cm.Link("edge_b", "cloud", bw=8e6,
                                        latency=5e-3)], now=0)
    return d


def test_pool_loss_recovery_scenario():
    """THE headline: a pool carrying the plan goes silent mid-stream ->
    lease expiry -> checkpoint rescale_cycle -> forced replan with the
    dead pool excluded from the candidate set -> state migrates -> the
    SLA recovers within the telemetry window."""
    d = _seeded_directory()
    job = StreamJob("dyn", dim=8, sla=LOOSE, membership=d,
                    pipeline=pl.fanout_stream_graph(8), sla_window=5)
    orch = Orchestrator(job)
    batches = _batches(14)

    def stream():
        for i, b in enumerate(batches):
            if i < 6:   # heartbeats stop after step 5: silent death
                d.heartbeat("edge_b", now=i)
            yield b

    m = orch.run(stream(), rate_fn=lambda s: 1e4)
    # the plan actually used the pool that died
    assert any("pool_failed edge_b" in ln and "[in plan]" in ln
               for ln in m.decisions)
    # recovery rode the involuntary checkpoint-rescale path
    assert any("elastic-recover" in ln for ln in m.decisions)
    assert m.rescales >= 1
    # the forced replan executed (a real migration), excluding the dead
    # pool from the surviving assignment
    assert any(":pool_lost" in ln for ln in m.decisions)
    assert m.migrations >= 1
    assert "edge_b" not in set(orch._exec_assignment.values())
    assert "edge_b" not in orch.controller.resources.pools
    # the job kept running and its (windowed) SLA recovered
    assert m.events == sum(b.n for b in batches)
    assert orch.sla.ok()


def test_zero_event_parity_with_static_spec():
    """Differential contract: a membership-backed run with ZERO topology
    events is plan/codec/migration-identical to the static-spec run
    (the PR 6-8 discipline: new subsystems are bitwise no-ops when
    unused)."""
    spec = two_pool_spec(bw=2e6, latency=20e-3)

    def run(**kw):
        job = StreamJob("p", dim=8, sla=LOOSE,
                        pipeline=pl.fanout_stream_graph(8), **kw)
        orch = Orchestrator(job)
        # a deterministic rate ramp drives real replan traffic
        return orch.run(_batches(10),
                        rate_fn=lambda s: 1e4 * (1.0 + 2.0 * (s >= 5)))

    a = run(cluster=spec)
    b = run(membership=MembershipDirectory(spec))
    assert a.plan_identities == b.plan_identities
    assert a.codecs == b.codecs
    assert a.cuts == b.cuts
    assert a.assignments == b.assignments
    assert a.migrations == b.migrations
    da = [ln for ln in a.decisions if "elastic" not in ln]
    db = [ln for ln in b.decisions if "elastic" not in ln]
    assert da == db


def test_join_mid_run_triggers_replan_onto_new_pool():
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3))
    job = StreamJob("dyn", dim=8, sla=LOOSE, membership=d,
                    pipeline=pl.fanout_stream_graph(8))
    orch = Orchestrator(job)
    batches = _batches(8)

    def stream():
        for i, b in enumerate(batches):
            if i == 3:   # a strictly better edge pool joins mid-ramp
                d.register(edge_b(), links=[cm.Link("edge_b", "cloud",
                                                    bw=8e6, latency=5e-3)],
                           now=i, monitored=False)
            yield b

    m = orch.run(stream(), rate_fn=lambda s: 1e4)
    assert any("topology pool_joined edge_b" in ln for ln in m.decisions)
    assert any(":pool_joined" in ln for ln in m.decisions)
    assert "edge_b" in set(orch._exec_assignment.values())
    assert m.migrations >= 1


def test_cluster_and_membership_are_mutually_exclusive():
    d = MembershipDirectory(two_pool_spec())
    with pytest.raises(ValueError, match="not both"):
        Orchestrator(StreamJob("x", cluster=two_pool_spec(), membership=d))


# ---------------------------------------------------------------------------
# fleet integration
# ---------------------------------------------------------------------------

def test_fleet_pool_loss_scrubs_ledger_and_forces_replans():
    """A fleet tenant planned onto the dying pool: its ledger bookings
    are scrubbed, it is forcibly replanned onto survivors (priority
    order), and the capacity invariants stay clean."""
    d = _seeded_directory()
    fleet = FleetOrchestrator(membership=d)
    res = fleet.add_tenant(
        TenantSpec("a", priority=0, demand_rate=1e4, sla=LOOSE),
        StreamJob("a", dim=8, pipeline=pl.fanout_stream_graph(8)), seed=0)
    assert res.admitted
    orch = fleet.orchestrators["a"]
    assert "edge_b" in set(orch._exec_assignment.values())
    booked = fleet.scheduler.ledger.reservations["a"]
    assert "edge_b" in booked.pool_frac

    gen = HyperplaneStream(dim=8, seed=0, horizon=6 * 32.0)
    d.heartbeat("edge_b", now=0)
    fleet.step_round({"a": gen.batch(0, 32)}, rates={"a": 1e4})
    # heartbeats stop; the lease expires inside a later round's drain
    for step in range(1, 6):
        fleet.step_round({"a": gen.batch(step, 32)}, rates={"a": 1e4})
    assert "edge_b" not in fleet.cluster.pools
    r = fleet.scheduler.ledger.reservations["a"]
    assert "edge_b" not in r.pool_frac and "edge_b" not in r.state_bytes
    assert all("edge_b" not in key for key in r.link_bytes)
    assert "edge_b" not in set(orch._exec_assignment.values())
    assert any("forced replan a" in ln for ln in fleet.scheduler.log)
    assert any("elastic-recover" in ln for ln in orch.metrics.decisions)
    assert fleet.scheduler.ledger.check() == []


def test_fleet_join_readmits_queued_tenant():
    """Capacity joining mid-run re-attempts admission for the queue
    within the same round's event drain."""
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3))
    fleet = FleetOrchestrator(membership=d)
    a = fleet.add_tenant(TenantSpec("a", demand_rate=1e4, sla=LOOSE),
                         StreamJob("a", dim=8), seed=0)
    assert a.admitted
    # a DAG tenant sized past the seed topology (linear jobs collapse
    # to the first edge pool and could never use a joiner): queues
    b = fleet.add_tenant(TenantSpec("b", demand_rate=1e6, sla=LOOSE),
                         StreamJob("b", dim=8,
                                   pipeline=pl.fanout_stream_graph(8)),
                         seed=1)
    assert not b.admitted and b.queued
    gens = {n: HyperplaneStream(dim=8, seed=i, horizon=4 * 32.0)
            for i, n in enumerate(["a", "b"])}
    fleet.step_round({"a": gens["a"].batch(0, 32)}, rates={"a": 1e4})
    assert fleet.scheduler.queued == ["b"]
    # a pool with a fat uplink joins; the next round's drain re-admits
    d.register(edge_b("edge_big", net_bw=10e9),
               links=[cm.Link("edge_big", "cloud", bw=1e9, latency=2e-3)],
               now=1, monitored=False)
    fleet.step_round({n: gens[n].batch(1, 32) for n in fleet.orchestrators},
                     rates={"a": 1e4, "b": 1e6})
    assert fleet.scheduler.queued == []
    assert "b" in fleet.orchestrators
    assert fleet.scheduler.ledger.check() == []
    # the re-admitted tenant runs in subsequent rounds
    fleet.step_round({n: gens[n].batch(2, 32) for n in fleet.orchestrators},
                     rates={"a": 1e4, "b": 1e6})
    assert fleet.orchestrators["b"].metrics.events > 0


def test_ledger_drop_pool_scrubs_only_touching_bookings():
    spec = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, edge_b(), cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=2e6, latency=20e-3),
               cm.Link("edge_b", "cloud", bw=8e6, latency=5e-3)])
    from repro.core.fleet import FleetLedger, Reservation
    led = FleetLedger(spec)
    led.reservations["t0"] = Reservation(
        pool_frac={"edge_b": 0.5, "cloud": 0.1},
        link_bytes={("edge_b", "cloud"): 1e6},
        state_bytes={"edge_b": 1e6})
    led.reservations["t1"] = Reservation(
        pool_frac={"edge": 0.2}, link_bytes={("edge", "cloud"): 5e5})
    assert led.drop_pool("edge_b") == ["t0"]
    assert led.reservations["t0"].pool_frac == {"cloud": 0.1}
    assert led.reservations["t0"].link_bytes == {}
    assert led.reservations["t0"].state_bytes == {}
    # the untouched tenant keeps its booking bit-for-bit
    assert led.reservations["t1"].pool_frac == {"edge": 0.2}
    assert "edge_b" not in led.spec.pools
    assert led.check() == []
    # set_spec refuses to paper over a departure
    led.reservations["t2"] = Reservation(pool_frac={"edge": 0.1})
    with pytest.raises(ValueError, match="drop_pool"):
        led.set_spec(two_pool_spec().without_pool("edge"))


def test_bandwidth_probe_ewma_rewrites_spec_link():
    """``observe_bandwidth`` mirrors the latency probe: EWMA over
    samples, ``Link.bw`` rewritten in the versioned spec, LINK_UPDATE
    announced only beyond the shared dead band."""
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3),
                            ewma_alpha=0.5, latency_tol=0.2)
    sub = d.subscribe()
    # one big sample: EWMA moves halfway, beyond the 20% dead band
    ev = d.observe_bandwidth("edge", "cloud", 6e6, now=1)
    assert ev is not None and ev.kind == LINK_UPDATE
    assert ev.subject == "edge->cloud"
    assert "bw" in ev.detail
    assert d.spec.link("edge", "cloud").bw == pytest.approx(4e6)
    assert d.bandwidth_estimate("edge", "cloud") == pytest.approx(4e6)
    # the latency declared on the link is untouched by bandwidth probes
    assert d.spec.link("edge", "cloud").latency == pytest.approx(20e-3)
    # samples at the current estimate: spec stays fresh, no announcement
    v = d.version
    assert d.observe_bandwidth("edge", "cloud", 4e6, now=2) is None
    assert d.version > v            # the estimate still versions the spec
    assert [e.kind for e in sub.poll()] == [LINK_UPDATE]
    with pytest.raises(ValueError, match="unknown pool"):
        d.observe_bandwidth("edge", "nope", 1e6)
    with pytest.raises(ValueError, match="non-positive sample"):
        d.observe_bandwidth("edge", "cloud", 0.0)


def test_bandwidth_and_latency_probes_share_a_link_independently():
    d = MembershipDirectory(two_pool_spec(bw=2e6, latency=20e-3),
                            ewma_alpha=0.5, latency_tol=0.2)
    d.observe_bandwidth("edge", "cloud", 6e6, now=1)
    d.observe_latency("edge", "cloud", 60e-3, now=2)
    ln = d.spec.link("edge", "cloud")
    assert ln.bw == pytest.approx(4e6)
    assert ln.latency == pytest.approx(40e-3)
    # a dead-banded bandwidth wiggle never clobbers the latency estimate
    assert d.observe_bandwidth("edge", "cloud", 4.1e6, now=3) is None
    assert d.spec.link("edge", "cloud").latency == pytest.approx(40e-3)
