"""ClusterSpec topology API: multi-pool placement vs the exhaustive
oracle, link-attached uplink codecs (pricing + SLA admission + tested
error bounds under composition), critical-path DAG latency, and parity
of two-pool plans through the deprecated flat-dict shim."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import codecs as cd
from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import (Objective, frontier_plans, place_frontier,
                                  place_graph_exhaustive, prefix_cut_plans)
from repro.core.sla import SLA, pick_codec
from repro.streams.generators import HyperplaneStream

EDGE_B = cm.Resource("edge_b", "edge", chips=1, flops=1e12, mem_bw=40e9,
                     mem_cap=2e9, net_bw=0.5e9, net_latency=35e-3,
                     energy_w=10.0)
CLOUD_B = cm.Resource("cloud_b", "cloud", chips=64, flops=cm.CLOUD_POD.flops,
                      mem_bw=cm.CLOUD_POD.mem_bw, mem_cap=16e9,
                      net_bw=cm.CLOUD_POD.net_bw, net_latency=0.5e-3,
                      energy_w=220.0)


def multipool_spec(codec: str = "identity") -> cm.ClusterSpec:
    """2 edge pools + 2 cloud pods with explicit, codec-carrying uplinks."""
    return cm.ClusterSpec(
        pools=[cm.EDGE_NODE, EDGE_B, cm.CLOUD_POD, CLOUD_B],
        links=[cm.Link("edge", "cloud", bw=1e9, latency=20e-3, codec=codec),
               cm.Link("edge", "cloud_b", bw=0.8e9, latency=25e-3,
                       codec=codec),
               cm.Link("edge_b", "cloud", bw=0.5e9, latency=35e-3,
                       codec=codec),
               cm.Link("edge_b", "cloud_b", bw=0.5e9, latency=40e-3,
                       codec=codec),
               cm.Link("edge", "edge_b", bw=2e9, latency=5e-3)])


# ---------------------------------------------------------------------------
# ClusterSpec construction + topology views
# ---------------------------------------------------------------------------

def test_cluster_spec_is_a_resource_mapping():
    spec = multipool_spec()
    assert set(spec) == {"edge", "edge_b", "cloud", "cloud_b"}
    assert spec["edge_b"] is EDGE_B
    assert len(spec) == 4
    assert [r.name for r in spec.edge_pools] == ["edge", "edge_b"]
    assert [r.name for r in spec.cloud_pools] == ["cloud", "cloud_b"]
    assert spec.default_source() == "edge"
    # legacy flat dicts coerce; an existing spec passes through untouched
    assert cm.ClusterSpec.of(spec) is spec
    coerced = cm.ClusterSpec.of({"edge": cm.EDGE_NODE,
                                 "cloud": cm.CLOUD_POD})
    assert list(coerced) == ["edge", "cloud"]


def test_cluster_spec_rejects_links_to_unknown_pools():
    with pytest.raises(ValueError, match="unknown pool"):
        cm.ClusterSpec(pools=[cm.EDGE_NODE],
                       links=[cm.Link("edge", "nope", bw=1e9, latency=1e-3)])


def test_declared_and_default_links():
    spec = multipool_spec("int8_ef")
    ln = spec.link("edge", "cloud_b")
    assert (ln.bw, ln.latency, ln.codec) == (0.8e9, 25e-3, "int8_ef")
    # an undeclared pair derives the historical charge-the-slow-side link
    d = spec.link("cloud", "edge")
    assert d.bw == cm.EDGE_NODE.net_bw
    assert d.latency == cm.EDGE_NODE.net_latency
    assert d.codec == "identity"
    # equal net_bw ties break toward the DESTINATION, matching the old
    # `prev if prev.net_bw < res.net_bw else res` rule exactly
    a = cm.Resource("a", "edge", net_bw=1e9, net_latency=30e-3)
    b = cm.Resource("b", "cloud", net_bw=1e9, net_latency=0.2e-3)
    tie = cm.ClusterSpec(pools=[a, b])
    assert tie.link("a", "b").latency == 0.2e-3
    assert tie.link("b", "a").latency == 30e-3


def test_with_uplink_codec_rewrites_every_uplink():
    spec = multipool_spec().with_uplink_codec("topk_int8_ef")
    for e in spec.edge_pools:
        for c in spec.cloud_pools:
            assert spec.link(e.name, c.name).codec == "topk_int8_ef"
    # non-uplink links keep their codec
    assert spec.link("edge", "edge_b").codec == "identity"
    # bw/latency of declared uplinks survive the rewrite
    assert spec.link("edge", "cloud_b").bw == 0.8e9


def test_with_uplink_codec_preserves_declared_per_link_codecs():
    """A user-declared per-link codec wins over the blanket fill; only
    override=True replaces it."""
    spec = multipool_spec("int8_ef")
    filled = spec.with_uplink_codec("topk_int8_ef")
    assert filled.link("edge", "cloud").codec == "int8_ef"
    forced = spec.with_uplink_codec("topk_int8_ef", override=True)
    assert forced.link("edge", "cloud").codec == "topk_int8_ef"


def test_cluster_spec_rejects_unknown_codec_names_at_construction():
    with pytest.raises(ValueError, match="unknown uplink codec"):
        cm.ClusterSpec(pools=[cm.EDGE_NODE, cm.CLOUD_POD],
                       links=[cm.Link("edge", "cloud", bw=1e9,
                                      latency=1e-3, codec="gzip")])


# ---------------------------------------------------------------------------
# two-pool parity: the deprecated flat dict and the explicit spec price
# identically (PR 3 plans unchanged through the shim)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate", [1e2, 1e4, 3e6])
def test_flat_dict_and_edge_cloud_spec_price_identically(rate):
    g = pl.fanout_stream_graph(dim=16)
    legacy = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    spec = cm.ClusterSpec.edge_cloud()
    for (f1, p1), (f2, p2) in zip(frontier_plans(g, legacy, rate),
                                  frontier_plans(g, spec, rate)):
        assert f1 == f2
        assert p1.assignment == p2.assignment
        assert p1.latency_s == pytest.approx(p2.latency_s)
        assert p1.uplink_utilization == pytest.approx(p2.uplink_utilization)
        assert p1.energy_w == pytest.approx(p2.energy_w)
        assert p1.feasible == p2.feasible


# ---------------------------------------------------------------------------
# multi-pool placement vs the exhaustive oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate", [1e2, 1e4, 1e6])
def test_multipool_frontier_search_matches_oracle(rate):
    g = pl.fanout_stream_graph(dim=16)
    spec = multipool_spec()
    obj = Objective()
    best, frontier = place_frontier(g, spec, rate, obj)
    oracle = place_graph_exhaustive(g, spec, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001
    assert g.check_frontier(frontier) == frontier
    # the frontier view is exactly the edge-pool-resident ops
    edge_names = {r.name for r in spec.edge_pools}
    assert frontier == frozenset(n for n, r in best.assignment.items()
                                 if r in edge_names)


def test_multipool_assignment_splits_frontier_across_edge_pools():
    """A plan the two-pool API could not express: the raw stream is too
    fat for any uplink (all-cloud infeasible) and the two heavy thinning
    branches together exceed ONE edge pool's compute — the only feasible
    placement splits the frontier across both edge pools."""
    def op(name, flops, in_bytes, out_bytes, reads, writes,
           edge_capable=True):
        return pl.Op(name, lambda s, b: (s, b),
                     cm.OperatorCost(name, flops, in_bytes, out_bytes,
                                     edge_capable=edge_capable),
                     reads=reads, writes=writes)

    rate = 1e4
    heavy = 1.4e8          # 0.7 utilization per branch on a 2e12 pool
    g = pl.OpGraph([
        op("h1", heavy, 1e6, 4.0, ("x",), ("a",)),
        op("h2", heavy, 1e6, 4.0, ("x",), ("b",)),
        op("agg", 1e3, 16.0, 8.0, ("a", "b"), ("out",),
           edge_capable=False),      # model management stays in the cloud
    ])
    edge_a = cm.Resource("edge_a", "edge", chips=1, flops=2e12,
                         net_bw=1e9, net_latency=20e-3, energy_w=15.0)
    edge_b = cm.Resource("edge_b", "edge", chips=1, flops=2e12,
                         net_bw=1e9, net_latency=20e-3, energy_w=15.0)
    spec = cm.ClusterSpec(
        pools=[edge_a, edge_b, cm.CLOUD_POD],
        links=[cm.Link("edge_a", "edge_b", bw=1e11, latency=1e-3)])
    obj = Objective()
    plan, frontier = place_frontier(g, spec, rate, obj)
    oracle = place_graph_exhaustive(g, spec, rate, obj)
    assert plan.feasible
    assert obj.score(plan) <= obj.score(oracle) * 1.0001
    assert frontier == frozenset({"h1", "h2"})
    assert {plan.assignment["h1"], plan.assignment["h2"]} == \
        {"edge_a", "edge_b"}, "heavy branches must split across edge pools"
    assert plan.assignment["agg"] == "cloud"


def _random_dag(rng):
    """A random small operator DAG (<=5 ops) with random channel wiring
    and cost profiles (the numpy twin of test_property's hypothesis
    strategy, so the multi-pool oracle match runs even when hypothesis
    is absent)."""
    n = int(rng.integers(2, 6))
    n_src = int(rng.integers(1, 3))
    sources = [f"s{i}" for i in range(n_src)]
    ops = []
    for j in range(n):
        avail = sources + [f"k{i}" for i in range(j)]
        n_reads = int(rng.integers(0, min(3, len(avail)) + 1))
        reads = tuple(sorted(rng.choice(avail, size=n_reads, replace=False)))
        cost = cm.OperatorCost(
            f"op{j}",
            flops_per_event=float(rng.uniform(10.0, 1e7)),
            bytes_per_event=float(rng.uniform(8.0, 4096.0)),
            out_bytes_per_event=float(rng.uniform(1.0, 2048.0)),
            edge_capable=bool(rng.integers(0, 2)))
        ops.append(pl.Op(f"op{j}", lambda s, b: (s, b), cost,
                         reads=reads, writes=(f"k{j}",)))
    rate = float(10 ** rng.uniform(2, 7))
    return pl.OpGraph(ops), rate


@pytest.mark.parametrize("seed", range(12))
def test_multipool_search_matches_oracle_on_random_dags(seed):
    """Acceptance: over a 2-edge/2-cloud spec the frontier search
    (frontiers x within-kind pool assignments) matches the exhaustive
    every-op-to-every-pool oracle on random small DAGs."""
    rng = np.random.default_rng(seed)
    graph, rate = _random_dag(rng)
    spec = multipool_spec(("identity", "int8_ef", "topk_int8_ef")[seed % 3])
    obj = Objective()
    best, frontier = place_frontier(graph, spec, rate, obj)
    oracle = place_graph_exhaustive(graph, spec, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001, (
        f"seed={seed}: frontier={sorted(frontier)} "
        f"score={obj.score(best)} oracle={obj.score(oracle)} "
        f"oracle_assign={oracle.assignment}")


def test_backhaul_still_infeasible_multipool():
    g = pl.fanout_stream_graph(dim=8)
    spec = multipool_spec()
    assign = {n: "cloud" for n in g.names}
    assign["alert"] = "edge_b"               # cloud-made 'drifted' flows down
    plan = cm.evaluate_graph_plan(
        g.costs(), g.flow_edges, assign, spec, 1e3,
        source_consumers=g.source_consumers,
        source_bytes=g.source_bytes_per_event)
    assert not plan.feasible
    assert any("backhaul" in n for n in plan.notes)


# ---------------------------------------------------------------------------
# critical-path latency
# ---------------------------------------------------------------------------

def test_chain_latency_is_the_per_op_sum():
    """A chain has one path, so critical-path pricing reproduces the
    historical per-op sum exactly (the PR 2/3 parity anchor)."""
    pipe = pl.standard_stream_pipeline(dim=16)
    res = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    for k, lin in prefix_cut_plans(pipe.costs(), res, 1e4):
        frontier = frozenset(pipe.names[:k])
        g = cm.evaluate_graph_plan(
            pipe.costs(), pipe.flow_edges,
            {n: ("edge" if n in frontier else "cloud") for n in pipe.names},
            res, 1e4, source_consumers=pipe.source_consumers,
            source_bytes=pipe.source_bytes_per_event)
        assert g.latency_s == pytest.approx(lin.latency_s)


def test_parallel_branches_overlap_on_the_critical_path():
    """Two equally-assigned parallel branches must cost the max of their
    latencies, not the sum (the DAG improvement over the linear model)."""
    def op(name, flops, reads, writes):
        return pl.Op(name, lambda s, b: (s, b),
                     cm.OperatorCost(name, flops, 8.0, 8.0),
                     reads=reads, writes=writes)

    g = pl.OpGraph([
        op("src", 1e6, ("x",), ("a",)),
        op("slow", 8e6, ("a",), ("s",)),      # parallel branch 1
        op("fast", 2e6, ("a",), ("f",)),      # parallel branch 2
        op("join", 1e6, ("s", "f"), ("out",)),
    ])
    res = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    assign = {n: "edge" for n in g.names}
    plan = cm.evaluate_graph_plan(g.costs(), g.flow_edges, assign, res, 1e2,
                                  source_consumers=g.source_consumers)
    t = lambda f: f / cm.EDGE_NODE.total_flops
    want = t(1e6) + max(t(8e6), t(2e6)) + t(1e6)
    assert plan.latency_s == pytest.approx(want)
    # and strictly less than the old per-op sum
    assert plan.latency_s < t(1e6 + 8e6 + 2e6 + 1e6)


def test_crossing_edges_add_link_latency_on_the_path():
    """A frontier cut between producer and consumers pays the crossing
    link's latency on the path (once per hop the path takes)."""
    g = pl.fanout_stream_graph(dim=16)
    res = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    plans = dict(frontier_plans(g, res, 1e2))
    all_cloud = plans[frozenset()]
    norm_edge = plans[frozenset({"normalize"})]
    # both plans have exactly one uplink hop on their critical path
    assert all_cloud.latency_s >= cm.EDGE_NODE.net_latency
    assert norm_edge.latency_s >= cm.EDGE_NODE.net_latency
    assert norm_edge.latency_s < 2 * cm.EDGE_NODE.net_latency


# ---------------------------------------------------------------------------
# codec pricing + SLA admission
# ---------------------------------------------------------------------------

def test_codec_wire_bytes_ratios():
    assert cd.get_codec("identity").wire_bytes(4096) == 4096
    assert cd.get_codec("int8_ef").wire_bytes(4096) == 1024
    assert cd.get_codec("topk_ef").wire_bytes(4096) == pytest.approx(819.2)
    assert cd.get_codec("topk_int8_ef").wire_bytes(4096) == 512
    with pytest.raises(KeyError, match="unknown uplink codec"):
        cd.get_codec("gzip")


def test_parameterized_codecs_register_distinct_names():
    """Link stores only the codec NAME, so a non-default k_frac must get
    its own registry entry — otherwise plans would price the default
    parameterization while execution runs the custom one."""
    c = cd.topk_ef_codec(0.25)
    assert c.name == "topk_ef_k0.25"
    assert cd.get_codec(c.name).ratio == pytest.approx(0.5)
    assert cd.get_codec("topk_ef").ratio == pytest.approx(0.2)  # default
    both = cd.topk_int8_ef_codec(0.5)
    assert cd.get_codec(both.name).ratio == pytest.approx(0.625)
    # a parameterized name resolves even if no constructor ran for it in
    # this process (config/serialization path): built on demand
    assert cd.get_codec("topk_int8_ef_k0.05").ratio == pytest.approx(0.0625)
    spec = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=1e9, latency=1e-3,
                       codec="topk_ef_k0.02")])
    assert spec.link("edge", "cloud").wire_bytes(4096) == pytest.approx(
        4096 * 0.04)


def test_codec_compressed_links_cut_uplink_utilization():
    g = pl.fanout_stream_graph(dim=16)
    rate = 1e4
    f = frozenset({"normalize"})
    plain = dict(frontier_plans(g, cm.ClusterSpec.edge_cloud(), rate))[f]
    coded = dict(frontier_plans(
        g, cm.ClusterSpec.edge_cloud().with_uplink_codec("topk_int8_ef"),
        rate))[f]
    assert coded.uplink_utilization == pytest.approx(
        plain.uplink_utilization * 0.125)


@pytest.mark.parametrize("budget,want", [
    (0.0, "identity"),
    (0.01, "identity"),          # below int8's tested bound -> lossless
    (0.1, "int8_ef"),
    (10.0, "topk_ef"),
    (11.0, "topk_int8_ef"),
])
def test_sla_picks_cheapest_admissible_codec(budget, want):
    c = pick_codec(SLA(error_budget=budget))
    assert c.name == want
    # the acceptance invariant: an admitted codec NEVER exceeds the budget
    assert c.error_bound <= budget + 1e-12


def test_sla_never_admits_codec_over_budget():
    for budget in np.linspace(0.0, 12.0, 97):
        c = pick_codec(SLA(error_budget=float(budget)))
        assert c.error_bound <= budget + 1e-12, (budget, c.name)


def test_pick_codec_defaults_to_identity_without_admissible_candidate():
    c = pick_codec(SLA(error_budget=0.001),
                   candidates=[cd.topk_ef_codec()])
    assert c.name == "identity"


# ---------------------------------------------------------------------------
# codec error bounds under composition (satellite): accumulated error of
# the wire round-trip stays within the bound sla.pick_codec admits by,
# mirroring the ef_roundtrip / ef_topk_roundtrip bounded-error tests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["int8_ef", "topk_ef", "topk_int8_ef"])
@pytest.mark.parametrize("constant", [True, False])
def test_codec_accumulated_error_within_admitted_bound(name, constant):
    codec = cd.get_codec(name)
    for seed in range(3):
        rng = np.random.default_rng(seed)
        d, steps = 256, 50
        g = jnp.asarray(rng.normal(scale=1e-2, size=d).astype(np.float32))
        residual = codec.init_residual(g)
        cum_true = np.zeros(d, np.float64)
        cum_dec = np.zeros(d, np.float64)
        amax = 0.0
        for t in range(steps):
            x = (g if constant else jnp.asarray(
                rng.normal(scale=1e-2, size=d).astype(np.float32)))
            amax = max(amax, float(jnp.max(jnp.abs(x))))
            dec, residual = codec.roundtrip(residual, x)
            cum_true += np.asarray(x, np.float64)
            cum_dec += np.asarray(dec, np.float64)
        err = float(np.max(np.abs(cum_dec - cum_true)))
        # telescoping EF identity: the accumulated error IS the residual
        np.testing.assert_allclose(cum_dec + np.asarray(residual, np.float64),
                                   cum_true, rtol=1e-4, atol=1e-5)
        assert err <= codec.error_bound * amax + 1e-6, (
            f"{name} constant={constant} seed={seed}: accumulated error "
            f"{err:.3g} exceeds admitted bound "
            f"{codec.error_bound * amax:.3g}")


def test_composed_codec_beats_its_parts_on_wire_bytes():
    topk, int8, both = (cd.get_codec(n)
                        for n in ("topk_ef", "int8_ef", "topk_int8_ef"))
    assert both.ratio < min(topk.ratio, int8.ratio)
    # and its bound is the sum of its parts' bounds (shared residual)
    assert both.error_bound == pytest.approx(
        topk.error_bound + int8.error_bound)


# ---------------------------------------------------------------------------
# offload + orchestrator over a ClusterSpec
# ---------------------------------------------------------------------------

def test_offload_controller_plan_identity_includes_pools_and_codec():
    g = pl.fanout_stream_graph(dim=16)
    ctl = OffloadController(g.costs(), multipool_spec(), graph=g,
                            codec="int8_ef", cooldown=1)
    d0 = ctl.initial_plan(1e3)
    assert d0.codec == "int8_ef"
    assert set(d0.assignment) == set(g.names)
    assert d0.frontier == frozenset(
        n for n, r in d0.assignment.items() if r in {"edge", "edge_b"})
    d1 = ctl.observe(1, 5e6)
    assert d1.reason == "rate_up"
    assert len(d1.frontier) < len(d0.frontier)
    assert ctl.migrations() == 1


def _batches(n, dim=8, n_per=32, seed=0):
    gen = HyperplaneStream(dim=dim, seed=seed, horizon=n * n_per)
    return [gen.batch(i, n_per) for i in range(n)]


def test_orchestrator_runs_multipool_cluster_with_lossy_codec():
    """End to end over a 2-edge/2-cloud ClusterSpec with a lossy uplink
    budget: the SLA picks the composed codec, the job completes, and the
    learner still learns through the compressed uplink."""
    dim = 8
    job = StreamJob("multi", dim=dim, cluster=multipool_spec(),
                    sla=SLA(error_budget=11.0))
    orch = Orchestrator(job)
    assert orch.codec.name == "topk_int8_ef"
    for e in orch.cluster.edge_pools:
        for c in orch.cluster.cloud_pools:
            assert orch.cluster.link(e.name, c.name).codec == "topk_int8_ef"
    m = orch.run(_batches(20, dim=dim, n_per=64), rate_fn=lambda s: 1e4)
    assert m.events == 20 * 64
    assert m.codec == "topk_int8_ef"
    assert any("codec=topk_int8_ef" in d for d in m.decisions)
    assert m.preq is not None and m.preq["accuracy"] > 0.6


def test_uplink_applied_on_empty_frontier_too():
    """The all-cloud plan is priced with the raw-event crossing codec-
    compressed, so execution must apply the codec there as well — the
    empty edge segment must not skip the uplink hook."""
    g = pl.fanout_stream_graph(dim=4)
    calls = []

    def uplink(env):
        calls.append(sorted(env))
        return env

    states = g.init_states()
    import jax
    bd = {"x": jnp.ones((8, 4), jnp.float32),
          "y": jnp.zeros((8,), jnp.int32),
          "rng": jax.random.PRNGKey(0)}
    g.run(states, dict(bd), frozenset(), uplink=uplink)       # all-cloud
    assert len(calls) == 1, "raw stream must cross the uplink once"
    g.run(states, dict(bd), frozenset({"normalize"}), uplink=uplink)
    assert len(calls) == 2
    g.run(states, dict(bd), frozenset(g.names), uplink=uplink)  # all-edge
    assert len(calls) == 2, "an all-edge plan has no uplink crossing"


def test_orchestrator_rejects_lossy_topology_under_lossless_sla():
    """A declared lossy uplink codec under a zero error budget is a
    configuration conflict the orchestrator must surface, not silently
    overwrite or silently run."""
    with pytest.raises(ValueError, match="error budget"):
        Orchestrator(StreamJob("conflict", dim=8,
                               cluster=multipool_spec("int8_ef")))
    # the same topology is fine once the budget admits the codec
    orch = Orchestrator(StreamJob("ok", dim=8,
                                  cluster=multipool_spec("int8_ef"),
                                  sla=SLA(error_budget=0.1)))
    assert orch.cluster.link("edge", "cloud").codec == "int8_ef"


def test_orchestrator_identity_codec_stays_bitwise_with_default_sla():
    """The default (zero) error budget must leave the uplink lossless:
    a lossy-budget run may diverge, but the default must stay bitwise
    with the pinned all-cloud reference (the PR 3 invariant)."""
    dim = 8
    data = _batches(6, dim=dim, n_per=32)
    a = Orchestrator(StreamJob("a", dim=dim)).run(
        data, rate_fn=lambda s: 1e4, record_outputs=True)
    assert a.codec == "identity"
    b = Orchestrator(StreamJob("b", dim=dim)).run(
        data, rate_fn=lambda s: 1e4, fixed_cut=0, record_outputs=True)
    for x, y in zip(a.outputs, b.outputs):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])


# ---------------------------------------------------------------------------
# per-link energy model (Link.energy_per_byte)
# ---------------------------------------------------------------------------

def _energy_spec(epb: float, codec: str = "identity") -> cm.ClusterSpec:
    return cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=1e9, latency=20e-3, codec=codec,
                       energy_per_byte=epb)])


def test_link_energy_per_byte_priced_into_energy_aggregate():
    """Every crossing adds wire_bytes * rate * energy_per_byte watts:
    the delta vs an energy-free link is exactly the summed link-byte
    rate times the joules-per-byte, in both evaluators."""
    pipe = pl.standard_stream_pipeline(dim=8)
    rate, epb = 1e4, 3e-7
    assign = {n: ("edge" if i < 3 else "cloud")
              for i, n in enumerate(pipe.names)}
    for codec in ("identity", "int8_ef"):
        zero, priced = _energy_spec(0.0, codec), _energy_spec(epb, codec)
        g0 = cm.evaluate_graph_plan(
            pipe.costs(), pipe.flow_edges, assign, zero, rate,
            source_consumers=pipe.source_consumers,
            source_bytes=pipe.source_bytes_per_event)
        g1 = cm.evaluate_graph_plan(
            pipe.costs(), pipe.flow_edges, assign, priced, rate,
            source_consumers=pipe.source_consumers,
            source_bytes=pipe.source_bytes_per_event)
        # bytes/s on each link = utilization * bw (codec-compressed wire)
        want = sum(u * priced.link(*k).bw * epb
                   for k, u in g1.link_utilization.items())
        assert want > 0.0
        assert g1.energy_w - g0.energy_w == pytest.approx(want)
        # linear evaluator prices the same crossings identically
        l0 = cm.evaluate_plan(pipe.costs(), assign, zero, rate)
        l1 = cm.evaluate_plan(pipe.costs(), assign, priced, rate)
        assert l1.energy_w - l0.energy_w == pytest.approx(want)
        # everything else is untouched by the energy term
        assert g1.latency_s == g0.latency_s
        assert g1.link_utilization == g0.link_utilization


def test_link_energy_default_zero_is_bitwise_neutral():
    """Links that don't declare energy_per_byte price exactly as before
    (the default 0.0 adds literal zero to the aggregate)."""
    pipe = pl.standard_stream_pipeline(dim=8)
    assign = {n: ("edge" if i < 2 else "cloud")
              for i, n in enumerate(pipe.names)}
    bare = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=1e9, latency=20e-3)])
    explicit = _energy_spec(0.0)
    for spec in (bare, explicit):
        assert spec.link("edge", "cloud").energy_per_byte == 0.0
    g_bare = cm.evaluate_graph_plan(
        pipe.costs(), pipe.flow_edges, assign, bare, 1e4,
        source_consumers=pipe.source_consumers,
        source_bytes=pipe.source_bytes_per_event)
    g_expl = cm.evaluate_graph_plan(
        pipe.costs(), pipe.flow_edges, assign, explicit, 1e4,
        source_consumers=pipe.source_consumers,
        source_bytes=pipe.source_bytes_per_event)
    assert g_bare.energy_w == g_expl.energy_w


def test_energy_weighted_placement_reacts_to_link_energy():
    """With an energy-weighted objective, a sufficiently expensive
    uplink pulls the frontier toward keeping bytes off the wire — the
    chosen plan under a huge energy_per_byte must not ship MORE link
    bytes than the energy-free choice."""
    g = pl.fanout_stream_graph(dim=8)
    obj = Objective(latency_weight=1.0, energy_weight=50.0)
    free, _ = place_frontier(g, _energy_spec(0.0), 1e4, obj)
    costly, _ = place_frontier(g, _energy_spec(1e-2), 1e4, obj)
    bytes_of = lambda p, s: sum(u * s.link(*k).bw
                                for k, u in p.link_utilization.items())
    assert bytes_of(costly, _energy_spec(1e-2)) <= \
        bytes_of(free, _energy_spec(0.0)) + 1e-9
