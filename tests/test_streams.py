"""Streams substrate: generators, drift detectors, preprocessing, sampling,
sketches, fusion, feeder (incl. straggler rescue)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.streams import drift as dd
from repro.streams import preprocess as prep
from repro.streams import sampling as samp
from repro.streams import sketches as sk
from repro.streams.events import StreamBatch
from repro.streams.feeder import StreamFeeder
from repro.streams.fusion import DelayedLabelAligner, WindowJoin
from repro.streams.generators import (DriftSpec, FittedGaussianGenerator,
                                      HyperplaneStream, TokenStream)


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def test_generator_replayable():
    g = HyperplaneStream(dim=8, seed=3)
    a = g.batch(7, 64)
    b = g.batch(7, 64)
    np.testing.assert_array_equal(np.asarray(a.data["x"]),
                                  np.asarray(b.data["x"]))
    assert a.watermark == b.watermark


def test_generator_drift_changes_concept():
    g = HyperplaneStream(dim=8, seed=0,
                         drift=DriftSpec("abrupt", at=0.5), horizon=1000.0)
    early = g.batch(0, 100)
    late = g.batch(9, 100)
    # same x distribution, different labeling rule: a linear model fit on
    # early should do poorly late
    from repro.ml import online
    st = online.logreg_init(8)
    for _ in range(50):
        st = online.logreg_update(st, jnp.asarray(early.data["x"]),
                                  jnp.asarray(early.data["y"]))
    acc_early = float(((online.logreg_predict(st, jnp.asarray(early.data["x"]))
                        > .5).astype(np.int32) == early.data["y"]).mean())
    acc_late = float(((online.logreg_predict(st, jnp.asarray(late.data["x"]))
                       > .5).astype(np.int32) == late.data["y"]).mean())
    assert acc_early > 0.85
    assert acc_late < acc_early - 0.2


def test_token_stream_shapes_and_drift():
    g = TokenStream(vocab_size=128, seq_len=32,
                    drift=DriftSpec("abrupt", at=0.5), horizon=32 * 32 * 10)
    b0 = g.batch(0, 16)
    b9 = g.batch(9, 16)
    assert b0.data["tokens"].shape == (16, 32)
    assert b0.data["tokens"].max() < 128
    # drifted domain uses permuted vocab -> different unigram histogram
    h0 = np.bincount(b0.data["tokens"].ravel(), minlength=128)
    h9 = np.bincount(b9.data["tokens"].ravel(), minlength=128)
    assert np.abs(h0 - h9).sum() > 0


def test_fitted_generator_matches_moments():
    rng = np.random.default_rng(0)
    x = np.concatenate([rng.normal(-2, 1, (500, 4)),
                        rng.normal(3, 0.5, (500, 4))]).astype(np.float32)
    y = np.concatenate([np.zeros(500, np.int32), np.ones(500, np.int32)])
    gen = FittedGaussianGenerator.fit(x, y, seed=1)
    b = gen.batch(0, 4000)
    xs, ys = np.asarray(b.data["x"]), np.asarray(b.data["y"])
    for c, mu in [(0, -2.0), (1, 3.0)]:
        assert abs(xs[ys == c].mean() - mu) < 0.2
    # privacy: generator object stores only moments, never the data
    assert gen.means.shape == (2, 4)


# ---------------------------------------------------------------------------
# Drift detectors
# ---------------------------------------------------------------------------

def _error_stream(n0=800, n1=800, p0=0.1, p1=0.5, seed=0):
    rng = np.random.default_rng(seed)
    e = np.concatenate([(rng.random(n0) < p0), (rng.random(n1) < p1)])
    return jnp.asarray(e.astype(np.float32))


@pytest.mark.parametrize("name,init,step", [
    ("ddm", dd.ddm_init, dd.ddm_step),
    ("eddm", dd.eddm_init, dd.eddm_step),
    ("ph", dd.ph_init, dd.ph_step),
    ("adwin", dd.adwin_init, dd.adwin_step),
])
def test_detector_fires_after_shift_not_before(name, init, step):
    errs = _error_stream()
    _, levels = dd.run_detector(jax.jit(step), init(), errs)
    levels = np.asarray(levels)
    pre = levels[:700]
    post = levels[800:]
    assert (pre == dd.DRIFT).sum() == 0, f"{name}: false alarm before shift"
    assert (post == dd.DRIFT).sum() >= 1, f"{name}: missed drift"


def test_detector_stable_stream_low_false_positive():
    rng = np.random.default_rng(1)
    errs = jnp.asarray((rng.random(4000) < 0.15).astype(np.float32))
    for init, step in [(dd.ddm_init, dd.ddm_step), (dd.ph_init, dd.ph_step)]:
        _, levels = dd.run_detector(jax.jit(step), init(), errs)
        assert (np.asarray(levels) == dd.DRIFT).mean() < 0.01


# ---------------------------------------------------------------------------
# Preprocess / sampling / sketches
# ---------------------------------------------------------------------------

def test_norm_update_apply_standardizes():
    rng = np.random.default_rng(0)
    st = prep.norm_init(4)
    y = None
    for i in range(20):
        x = jnp.asarray(rng.normal(5.0, 3.0, (128, 4)).astype(np.float32))
        st, y = prep.norm_update_apply(st, x)
    assert abs(float(y.mean())) < 0.2
    assert abs(float(y.std()) - 1.0) < 0.2


def test_impute_uses_running_mean():
    st = prep.NormState(jnp.asarray(10.0), jnp.asarray([2.0, 3.0]),
                        jnp.ones((2,)))
    x = jnp.asarray([[np.nan, 1.0], [4.0, np.nan]], jnp.float32)
    y = prep.impute_with_mean(st, x)
    np.testing.assert_allclose(np.asarray(y), [[2.0, 1.0], [4.0, 3.0]])


def test_reservoir_uniformity():
    st = samp.reservoir_init(64, 1, seed=0)
    xs = jnp.arange(2048, dtype=jnp.float32)[:, None]
    ys = jnp.zeros(2048, jnp.int32)
    st = jax.jit(samp.reservoir_update)(st, xs, ys)
    vals = np.asarray(st.buf[:, 0])
    assert int(st.seen) == 2048
    assert len(np.unique(vals)) == 64
    # uniform over history: mean of sample ~ mean of stream
    assert abs(vals.mean() - 1023.5) < 200


def test_misra_gries_finds_heavy_hitter():
    rng = np.random.default_rng(0)
    ids = np.where(rng.random(2000) < 0.3, 7, rng.integers(100, 10_000, 2000))
    mg = sk.mg_init(16)
    mg = jax.jit(sk.mg_update)(mg, jnp.asarray(ids, jnp.int32))
    keys = np.asarray(mg.keys)
    counts = np.asarray(mg.counts)
    assert 7 in keys[counts > 0]
    top = keys[np.argmax(counts)]
    assert top == 7


def test_countmin_streaming_estimates():
    cm = sk.countmin_init(4, 512, seed=0)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 100, 5000), jnp.int32)
    cm = sk.countmin_add(cm, ids)
    true = np.bincount(np.asarray(ids), minlength=100)
    est = np.asarray(sk.countmin_query(cm, jnp.arange(100, dtype=jnp.int32)))
    assert (est >= true).all()
    assert (est - true).mean() < 40


def test_countmin_edge_cloud_path_parity(monkeypatch):
    """The sketch an edge node builds on the reference path and the one a
    cloud/TPU node builds through the Pallas kernel must be the SAME
    sketch — counts merge across tiers, so any divergence corrupts the
    global summary. (Kernel path runs in interpret mode here.)"""
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    rng = np.random.default_rng(42)
    ids = jnp.asarray(rng.integers(0, 3000, 901), jnp.int32)
    cm0 = sk.countmin_init(depth=3, width=257, seed=5)
    edge = sk.countmin_add(cm0, ids, use_kernel=False)
    cloud = sk.countmin_add(cm0, ids, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(edge.table),
                                  np.asarray(cloud.table))
    edge_cm, edge_est = sk.countmin_add_query(cm0, ids, use_kernel=False)
    cloud_cm, cloud_est = sk.countmin_add_query(cm0, ids, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(edge_cm.table),
                                  np.asarray(cloud_cm.table))
    np.testing.assert_array_equal(np.asarray(edge_est), np.asarray(cloud_est))


def test_countmin_dispatch_is_recorded_and_loud(monkeypatch):
    """Regression for the silent-fallback bug: a kernel request that
    cannot run must (a) warn, (b) fall back correctly, and (c) be
    visible in the dispatch counter — it used to vanish without trace."""
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    monkeypatch.delenv("JAX_PALLAS_INTERPRET", raising=False)
    if jax.default_backend() == "tpu":
        pytest.skip("fallback path requires a no-Pallas backend")
    rng = np.random.default_rng(6)
    ids = jnp.asarray(rng.integers(0, 500, 123), jnp.int32)
    cm = sk.countmin_init(depth=2, width=64)
    sk.reset_dispatch_counts()
    sk.countmin_add(cm, ids)                       # auto -> reference on CPU
    with pytest.warns(RuntimeWarning, match="falling back"):
        fell_back = sk.countmin_add(cm, ids, use_kernel=True)
    np.testing.assert_array_equal(
        np.asarray(fell_back.table),
        np.asarray(sk.countmin_add(cm, ids, use_kernel=False).table))
    counts = sk.dispatch_counts()
    assert counts == {"pallas": 0, "reference": 3}
    # and the kernel path is counted as pallas when it actually runs
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    sk.reset_dispatch_counts()
    sk.countmin_add(cm, ids, use_kernel=True)
    assert sk.dispatch_counts() == {"pallas": 1, "reference": 0}
    sk.reset_dispatch_counts()


# ---------------------------------------------------------------------------
# Fusion
# ---------------------------------------------------------------------------

def test_window_join_matches_within_tolerance():
    right = StreamBatch(data={"x": np.arange(10, dtype=np.float32)[:, None]},
                        ts=np.arange(10, dtype=np.float64))
    left = StreamBatch(data={"x": np.zeros((3, 1), np.float32)},
                       ts=np.asarray([2.05, 5.4, 30.0]))
    j = WindowJoin(tolerance=0.5)
    j.push_right(right)
    joined, matched = j.join_left(left)
    assert matched.tolist() == [True, True, False]
    assert joined.data["joined"][0, 0] == 2.0
    assert joined.data["joined"][1, 0] == 5.0


def test_window_join_circular_buffer_reuses_storage():
    """Regression: push_right must write into the preallocated ring in
    place (head/tail indices, amortized O(1) eviction) instead of
    reallocating the whole buffer per push."""
    j = WindowJoin(tolerance=0.5, max_buffer=100)
    mk = lambda lo: StreamBatch(
        data={"x": np.full((40, 2), float(lo), np.float32)},
        ts=np.arange(lo, lo + 40, dtype=np.float64))
    j.push_right(mk(0))
    buf_t, buf_v = j._buf_t, j._buf_v
    assert len(buf_t) >= 2 * j.max_buffer     # preallocated capacity
    for lo in range(40, 40 * 5, 40):
        j.push_right(mk(lo))
        assert j._buf_t is buf_t and j._buf_v is buf_v, \
            "push reallocated the ring buffer"
    # eviction keeps only the newest max_buffer rows, oldest first
    assert len(j._rt) == 100
    np.testing.assert_array_equal(j._rt, np.arange(100, 200, dtype=np.float64))
    # wrap-around compaction keeps join results identical
    for lo in range(200, 1200, 40):
        j.push_right(mk(lo))
    assert j._buf_t is buf_t, "compaction must reuse the same storage"
    left = StreamBatch(data={"x": np.zeros((3, 1), np.float32)},
                       ts=np.asarray([1100.2, 1150.0, 10.0]))
    joined, matched = j.join_left(left)
    assert matched.tolist() == [True, True, False]
    assert joined.data["joined"][0, 0] == 1080.0   # batch holding ts=1100
    assert joined.data["joined"][1, 0] == 1120.0


def test_window_join_promotes_value_dtype_mid_stream():
    """A wider dtype arriving after the ring is allocated must widen the
    buffer (as the old concatenate path did), not silently truncate."""
    j = WindowJoin(tolerance=0.5, max_buffer=16)
    j.push_right(StreamBatch(
        data={"x": np.arange(4)[:, None]},          # int64 values
        ts=np.arange(4, dtype=np.float64)))
    j.push_right(StreamBatch(
        data={"x": np.full((4, 1), 7.5, np.float64)},
        ts=np.arange(4, 8, dtype=np.float64)))
    left = StreamBatch(data={"x": np.zeros((1, 1), np.float32)},
                       ts=np.asarray([5.0]))
    joined, matched = j.join_left(left)
    assert matched.all()
    assert joined.data["joined"][0, 0] == 7.5       # not truncated to 7


def test_window_join_oversized_push_keeps_newest():
    j = WindowJoin(tolerance=0.5, max_buffer=10)
    j.push_right(StreamBatch(
        data={"x": np.arange(25, dtype=np.float32)[:, None]},
        ts=np.arange(25, dtype=np.float64)))
    assert len(j._rt) == 10
    np.testing.assert_array_equal(j._rt, np.arange(15, 25, dtype=np.float64))


def test_delayed_label_aligner():
    al = DelayedLabelAligner()
    al.push_features(np.arange(5), np.arange(5, dtype=np.float64),
                     np.random.default_rng(0).normal(size=(5, 3)).astype(np.float32))
    assert al.backlog == 5
    out = al.push_labels(np.asarray([1, 3]), np.asarray([0, 1], np.int32))
    assert out is not None and out.n == 2
    assert al.backlog == 3
    assert out.data["y"].tolist() == [0, 1]


# ---------------------------------------------------------------------------
# Feeder + straggler rescue
# ---------------------------------------------------------------------------

def test_feeder_straggler_rescue_preserves_data():
    gen = HyperplaneStream(dim=4, seed=0)

    def make(shard, idx, n):
        g = HyperplaneStream(dim=4, seed=shard)
        return g.batch(idx, n)

    slow = StreamFeeder(make, n_shards=2, batch_per_shard=32,
                        deadline_s=0.05,
                        inject_straggle=lambda s, i: 0.3 if (s == 1 and i == 1) else 0.0)
    slow.start()
    batches = [slow.next() for _ in range(3)]
    slow.stop()
    assert slow.stats.straggler_rescues >= 1
    # rescued batch identical to what the straggler would have produced
    want = HyperplaneStream(dim=4, seed=1).batch(1, 32)
    got = batches[1]
    np.testing.assert_array_equal(
        np.asarray(got.data["x"][32:]), np.asarray(want.data["x"]))
