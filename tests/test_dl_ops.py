"""The DL stack on the pipeline substrate (serve/ops, train/ops): the
split serving graph and the wrapped train step must be bitwise-identical
to the standalone engine/train-step calls under the identity codec; the
placement DP must price KV-cache ``state_bytes`` against ``mem_cap``
(provable edge exclusion) and select cloud-prefill/edge-decode when the
pod saturates; the KV codecs must honor their tested error bounds; and
replans must carry the priced migration of resident op state."""

from dataclasses import replace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import costmodel as cm
from repro.core.codecs import (DEFAULT_CODECS, KV_CODECS, get_codec,
                               kv_latent_codec)
from repro.core.offload import OffloadController
from repro.core.pipeline import OpGraph
from repro.core.placement import Objective, _graph_plan, place_frontier
from repro.launch.roofline import dl_operator_cost
from repro.models import model_zoo as zoo
from repro.serve.engine import Request, ServeEngine
from repro.serve.ops import (decode_op, kv_cache_bytes, param_bytes,
                             prefill_op, serve_wave_batch, serving_graph)
from repro.serve.sampling import SamplingParams
from repro.train.ops import dl_train_op, train_state_bytes
from repro.train.optim import adamw
from repro.train.train_step import make_train_step

CFG = get_config("qwen2-1.5b", smoke=True)
PARAMS = zoo.init_params(CFG, 0)
PROMPTS = [np.arange(1, 7, dtype=np.int32), np.arange(3, 11, dtype=np.int32)]


def make_engine(**kw):
    kw = {"batch_size": 2, "max_len": 32, "seed": 0, **kw}
    return ServeEngine(CFG, PARAMS, **kw)


def engine_reference(sampling=SamplingParams(greedy=True), new_tokens=5):
    eng = make_engine(sampling=sampling)
    reqs = [Request(i, p, max_new_tokens=new_tokens)
            for i, p in enumerate(PROMPTS)]
    eng.run(reqs)
    return np.array([r.out_tokens for r in reqs])


def graph_run(frontier, sampling=SamplingParams(greedy=True), new_tokens=5,
              uplink=None):
    eng = make_engine(sampling=sampling)
    g = serving_graph(eng, prompt_len=8, max_new_tokens=new_tokens)
    states = g.init_states()
    batch = serve_wave_batch(eng, PROMPTS, seed=0)
    states, out = g.run(states, batch, frozenset(frontier), uplink=uplink)
    return g, np.asarray(out["out_tokens"])


# ---------------------------------------------------------------------------
# differential contract: the graph path IS the engine
# ---------------------------------------------------------------------------

def test_serving_graph_bitwise_vs_engine_greedy():
    ref = engine_reference()
    for frontier in ((), ("prefill",), ("decode",), ("prefill", "decode")):
        _, got = graph_run(frontier)
        assert np.array_equal(ref, got), (frontier, ref, got)


def test_serving_graph_bitwise_vs_engine_sampled():
    """Non-greedy sampling pins the rng threading: the prefill op must
    split the wave key exactly like ``_serve_wave`` and the decode loop
    must hand the engine's jitted step the same keys in the same order."""
    sp = SamplingParams(temperature=0.8, top_k=8)
    ref = engine_reference(sampling=sp)
    _, got = graph_run(("decode",), sampling=sp)
    assert np.array_equal(ref, got)


def test_prefill_op_emits_the_engine_cache_pytree():
    eng = make_engine()
    op = prefill_op(eng, prompt_len=8)
    batch = serve_wave_batch(eng, PROMPTS, seed=0)
    _, out = op.fn(None, batch)
    want = jax.eval_shape(lambda: zoo.init_caches(CFG, 2, 32))
    got_td = jax.tree_util.tree_structure(out["kv"])
    assert got_td == jax.tree_util.tree_structure(want)
    assert out["tok"].shape == (2,)


def test_interleaved_run_applies_wire_on_every_side_change():
    """A non-strict frontier executes as same-side runs in list order,
    with the wire transform applied at each crossing: ``{decode}`` is
    source(edge) -> prefill(cloud) -> decode(edge), two crossings, while
    the strictly-closed ``{prefill}`` keeps the single legacy uplink."""
    calls = []

    def wire(env):
        calls.append(sorted(env))
        return env

    _, got = graph_run(("decode",), uplink=wire)
    assert len(calls) == 2
    # the second crossing carries the KV cache down to the edge decode
    assert "kv" in calls[1]
    calls.clear()
    _, got2 = graph_run(("prefill",), uplink=wire)
    assert len(calls) == 1
    assert np.array_equal(got, got2)


def test_train_op_bitwise_vs_standalone_jitted():
    opt = adamw(1e-3)
    tokens = np.random.RandomState(0).randint(
        1, CFG.vocab_size, (2, 16)).astype(np.int32)
    step_fn = jax.jit(make_train_step(CFG, opt, impl="chunked",
                                      clip_norm=1.0))
    p, o, s = PARAMS, opt.init(PARAMS), jnp.zeros((), jnp.int32)
    ref_losses = []
    for _ in range(2):
        p, o, s, m = step_fn(p, o, s, {"tokens": jnp.asarray(tokens)})
        ref_losses.append(np.asarray(m["loss"]))

    op = dl_train_op(CFG, opt, batch_size=2, seq_len=16)
    g = OpGraph([op])
    states = g.init_states()
    batch = {"tokens": jnp.asarray(tokens), "rng": jax.random.PRNGKey(0)}
    for i in range(2):
        states, out = g.run(states, batch, frozenset())
        assert np.array_equal(ref_losses[i], np.asarray(out["loss"]))
    pw, ow, sw = states[op.name]
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(pw)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(sw) == 2


# ---------------------------------------------------------------------------
# roofline-declared costs
# ---------------------------------------------------------------------------

def test_dl_operator_cost_roofline_rules():
    n = CFG.param_counts()["active"]
    pb = param_bytes(CFG)
    tr = dl_operator_cost("t", CFG, phase="train", batch=4, seq_len=64,
                          param_bytes=pb)
    assert tr.flops_per_event == pytest.approx(6.0 * n * 64)
    assert tr.bytes_per_event == pytest.approx(3.0 * pb / 4)
    pf = dl_operator_cost("p", CFG, phase="prefill", batch=2, seq_len=24,
                          param_bytes=pb)
    assert pf.flops_per_event == pytest.approx(2.0 * n * 24)
    de = dl_operator_cost("d", CFG, phase="decode", batch=2, seq_len=0,
                          new_tokens=4, param_bytes=pb, downlink_ok=True)
    assert de.flops_per_event == pytest.approx(2.0 * n * 4)
    # decode re-streams the weights once per generated token
    assert de.bytes_per_event == pytest.approx(pb * 4 / 2)
    assert de.downlink_ok and not tr.downlink_ok
    with pytest.raises(ValueError):
        dl_operator_cost("x", CFG, phase="nope", batch=1, seq_len=1)


def test_train_state_bytes_counts_params_and_moments():
    opt = adamw(1e-3)
    pb = param_bytes(CFG)
    sb = train_state_bytes(CFG, opt)
    assert sb >= 2 * pb          # params + at least adam's m/v


def test_set_measured_costs_preserves_downlink_ok():
    eng = make_engine()
    g = serving_graph(eng, prompt_len=8, max_new_tokens=4)
    flat = replace(g.op("decode").cost, downlink_ok=False,
                   flops_per_event=123.0)
    g.set_measured_costs({"decode": flat})
    c = {x.name: x for x in g.costs()}["decode"]
    assert c.flops_per_event == 123.0 and c.downlink_ok


# ---------------------------------------------------------------------------
# placement: KV state priced against mem_cap, downlink split selected
# ---------------------------------------------------------------------------

def serving_spec(edge_mem=4e9, edge_flops=4e9, cloud_membw=2.5e9,
                 down_bw=1e9):
    edge = cm.Resource("edge0", "edge", chips=1, flops=edge_flops,
                       mem_bw=5e9, mem_cap=edge_mem, net_bw=1e9)
    cloud = cm.Resource("cloud0", "cloud", chips=1, flops=1e13,
                        mem_bw=cloud_membw, mem_cap=64e9, net_bw=100e9)
    return cm.ClusterSpec(
        pools=[edge, cloud],
        links=[cm.Link("edge0", "cloud0", bw=1e9, latency=5e-3),
               cm.Link("cloud0", "edge0", bw=down_bw, latency=5e-3)])


def serving_graph_for_placement():
    eng = make_engine()
    return serving_graph(eng, prompt_len=24, max_new_tokens=4)


def test_dp_excludes_edge_pool_with_insufficient_mem_cap():
    g = serving_graph_for_placement()
    kv_state = g.op("decode").cost.state_bytes
    tiny = serving_spec(edge_mem=kv_state / 2)
    assert kv_state > tiny.pools["edge0"].mem_cap
    plan, frontier = place_frontier(g, tiny, 1e3, Objective(), method="dp")
    assert plan.feasible
    assert plan.assignment == {"prefill": "cloud0", "decode": "cloud0"}
    assert frontier == frozenset()
    # the exclusion is the evaluator's, not a DP artifact
    p = _graph_plan(g, {"prefill": "cloud0", "decode": "edge0"}, tiny, 1e3)
    assert not p.feasible and any("memory" in n for n in p.notes)


def test_dp_selects_cloud_prefill_edge_decode_under_pod_saturation():
    """At 3k waves/s the narrow pod cannot hold both phases and the weak
    edge cannot hold prefill: the only feasible plan ships the KV cache
    down the priced link — and the DP finds it (enumeration agrees)."""
    g = serving_graph_for_placement()
    spec = serving_spec()
    obj = Objective()
    for method in ("dp", "enumerate"):
        plan, frontier = place_frontier(g, spec, 3e3, obj, method=method)
        assert plan.feasible, method
        assert plan.assignment == {"prefill": "cloud0", "decode": "edge0"}
        assert frontier == frozenset({"decode"})
    # the KV crossing is priced on the downlink, not free
    assert plan.link_utilization[("cloud0", "edge0")] > 0.0


def test_downlink_requires_the_consumer_flag():
    """Without ``downlink_ok`` the same cloud->edge crossing is backhaul:
    the relaxation is per-consumer, not a blanket rule change."""
    g = serving_graph_for_placement()
    spec = serving_spec()
    split = {"prefill": "cloud0", "decode": "edge0"}
    assert _graph_plan(g, split, spec, 1e3).feasible
    stripped = OpGraph([
        replace(g.op("prefill"), cost=g.op("prefill").cost),
        replace(g.op("decode"),
                cost=replace(g.op("decode").cost, downlink_ok=False)),
    ])
    p = _graph_plan(stripped, split, spec, 1e3)
    assert not p.feasible
    assert any("backhaul" in n for n in p.notes)
    # and {decode} is no longer a frontier of the stripped graph
    fs = {frozenset(f) for f in stripped.frontiers()}
    assert frozenset({"decode"}) not in fs
    assert frozenset({"decode"}) in {frozenset(f) for f in g.frontiers()}


# ---------------------------------------------------------------------------
# KV codecs: tested error bounds, parametrized ladder
# ---------------------------------------------------------------------------

def _kv_leaves():
    caches = zoo.init_caches(CFG, 2, 32)
    eng = make_engine()
    batch = serve_wave_batch(eng, PROMPTS, seed=0)
    _, caches = eng._prefill(eng.params, {"tokens": batch["tokens"]})
    return [l for l in jax.tree_util.tree_leaves(caches)
            if jnp.issubdtype(jnp.result_type(l), jnp.floating)
            and l.ndim > 0]


def test_kv_int8_bound_on_gaussian_and_real_kv():
    codec = get_codec("kv_int8")
    rng = np.random.default_rng(0)
    payloads = [jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))]
    payloads += _kv_leaves()
    assert payloads[-1].size > 0
    for x in payloads:
        dec, _ = codec.roundtrip(codec.init_residual(x), x)
        scale = max(float(jnp.max(jnp.abs(x))), 1e-30)
        err = float(jnp.max(jnp.abs(dec - x))) / scale
        assert err <= codec.error_bound * 1.001, err


def test_kv_latent_bound_on_gaussian():
    """The latent codec's bound is distributional (energy outside the
    retained subspace): relative L2 error on generic payloads must stay
    within sqrt(1 - r_frac) + int8 quantum, with margin."""
    rng = np.random.default_rng(1)
    for r_frac in (0.5, 0.25):
        codec = kv_latent_codec(r_frac)
        x = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
        dec, _ = codec.roundtrip(codec.init_residual(x), x)
        rel = float(jnp.linalg.norm(dec - x) / jnp.linalg.norm(x))
        assert rel <= codec.error_bound * 1.05, (r_frac, rel)
        # identity subspace: r_frac=1 keeps everything but the quantum
    full = kv_latent_codec(1.0)
    x = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    dec, _ = full.roundtrip(full.init_residual(x), x)
    rel = float(jnp.linalg.norm(dec - x) / jnp.linalg.norm(x))
    assert rel <= 0.02


def test_kv_latent_roundtrip_on_real_kv_leaves():
    codec = get_codec("kv_latent")
    for x in _kv_leaves():
        dec, _ = codec.roundtrip(codec.init_residual(x), x)
        assert dec.shape == x.shape
        nx = float(jnp.linalg.norm(x))
        if nx > 0:
            rel = float(jnp.linalg.norm(dec - x)) / nx
            assert rel <= codec.error_bound * 1.05


def test_kv_codec_registry_and_ladder():
    assert [c.name for c in KV_CODECS] == ["identity", "kv_int8",
                                           "kv_latent"]
    # the serving ladder does NOT leak into the gradient default ladder
    assert not any(c.name.startswith("kv_") for c in DEFAULT_CODECS)
    c = get_codec("kv_latent_r0.25")
    assert c.ratio == pytest.approx(0.25 * 0.25)
    assert c.error_bound == pytest.approx((1 - 0.25) ** 0.5 + 1 / 127)
    with pytest.raises(ValueError):
        kv_latent_codec(0.0)
    with pytest.raises(KeyError):
        get_codec("kv_nope")


# ---------------------------------------------------------------------------
# migration pricing
# ---------------------------------------------------------------------------

def test_migration_cost_prices_moved_state_per_link():
    def oc(name, state):
        return cm.OperatorCost(name=name, flops_per_event=1.0,
                               bytes_per_event=1.0, out_bytes_per_event=1.0,
                               state_bytes=state)

    ops = [oc("a", 1e6), oc("b", 2e6), oc("c", 4e6)]
    spec = serving_spec()
    old = {"a": "edge0", "b": "edge0", "c": "cloud0"}
    new = {"a": "edge0", "b": "cloud0"}         # b moves, c dropped
    mig = cm.migration_cost(ops, old, new, spec)
    ln = spec.link("edge0", "cloud0")
    assert mig.moves == (("b", "edge0", "cloud0"),)
    assert mig.bytes == 2e6
    assert mig.seconds == pytest.approx(2e6 / ln.bw + ln.latency)
    none = cm.migration_cost(ops, old, dict(old), spec)
    assert none.moves == () and none.bytes == 0.0 and none.seconds == 0.0
    # a move off a pool that already left the spec (crash replan) is
    # recorded but ships nothing — the op restarts from checkpoint
    lost = cm.migration_cost(ops, {"a": "gone0"}, {"a": "cloud0"}, spec)
    assert lost.moves == (("a", "gone0", "cloud0"),)
    assert lost.bytes == 0.0 and lost.seconds == 0.0


def test_replan_decision_carries_priced_migration():
    g = serving_graph_for_placement()
    spec = serving_spec()
    ctl = OffloadController(g.costs(), spec, Objective(), graph=g,
                            cooldown=0)
    ctl.initial_plan(1e3)
    assert ctl.history[-1].migration.moves == ()
    d = ctl.replan(1, 3e3)
    assert d.assignment == {"prefill": "cloud0", "decode": "edge0"}
    (move,) = d.migration.moves
    assert move[0] == "decode" and move[2] == "edge0"
    assert d.migration.bytes == g.op("decode").cost.state_bytes
    assert d.migration.seconds > 0.0
    # hold decisions carry no migration
    d2 = ctl.observe(2, 3e3)
    assert d2.reason == "hold" and d2.migration.moves == ()


# ---------------------------------------------------------------------------
# orchestrator: explicit KV ladder + pytree-aware uplink wire
# ---------------------------------------------------------------------------

def test_stream_job_kv_ladder_governs_admission():
    from repro.core.orchestrator import Orchestrator, StreamJob
    from repro.core.sla import SLA
    job = StreamJob("kv", dim=8, sla=SLA(error_budget=0.5),
                    uplink_codecs=[c.name for c in KV_CODECS])
    orch = Orchestrator(job)
    # kv_latent (bound 0.715) is outside the 0.5 budget; kv_int8 is the
    # cheapest admissible wire and wins the initial pick
    assert orch.codec.name == "kv_int8"
    assert orch.codec_candidates == ["identity", "kv_int8"]
    # every edge<->cloud wire in the priced spec carries the pick
    for e in orch.cluster.edge_pools:
        for c in orch.cluster.cloud_pools:
            assert orch.cluster.link(e.name, c.name).codec == "kv_int8"
            assert orch.cluster.link(c.name, e.name).codec == "kv_int8"
    tight = StreamJob("tight", dim=8, sla=SLA(error_budget=0.0),
                      uplink_codecs=[c.name for c in KV_CODECS])
    assert Orchestrator(tight).codec.name == "identity"


def test_uplink_wire_roundtrips_pytree_channels():
    from repro.core.orchestrator import Orchestrator, StreamJob
    from repro.core.sla import SLA
    job = StreamJob("kv", dim=8, sla=SLA(error_budget=0.5),
                    uplink_codecs=[c.name for c in KV_CODECS])
    orch = Orchestrator(job)
    wire = orch._uplink_fn()
    rng = np.random.default_rng(0)
    kv = {"k": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
          "idx": jnp.arange(4, dtype=jnp.int32)}
    x = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    key = jax.random.PRNGKey(7)
    out = wire({"kv": kv, "x": x, "rng": key})
    # structure survives; int leaves and the rng key pass through raw
    assert set(out) == {"kv", "x", "rng"}
    assert np.array_equal(out["kv"]["idx"], kv["idx"])
    assert np.array_equal(out["rng"], key)
    # float leaves take the int8 wire: close within the codec bound
    for a, b in ((out["kv"]["k"], kv["k"]), (out["x"], x)):
        bound = orch.codec.error_bound * float(jnp.max(jnp.abs(b)))
        assert float(jnp.max(jnp.abs(a - b))) <= bound * 1.001
        assert not np.array_equal(np.asarray(a), np.asarray(b))
    # residuals are keyed per (channel, leaf), so a second wave with the
    # same shapes reuses them instead of re-initializing
    keys = set(orch._uplink_residuals)
    assert all(isinstance(k, tuple) and len(k) == 2 for k in keys)
    assert {"kv", "x"} == {k[0] for k in keys}
    wire({"kv": kv, "x": x, "rng": key})
    assert set(orch._uplink_residuals) == keys
    # codec swaps flush pytree residuals like flat ones
    orch._swap_codec("identity", step=1)
    assert orch._uplink_residuals == {}
