"""Unit tests for the repro.dist subsystem: mesh context, logical->spec
mapping, int8 compression, async checkpointing, and elastic policy."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.dist import (
    axis_size, mesh_active, pin_params, shard, shard_param, use_mesh,
)
from repro.dist import checkpoint as ckpt
from repro.dist import elastic
from repro.dist.api import logical_to_spec
from repro.dist.compression import (
    compressed_allreduce_mean, dequantize_int8, ef_init, ef_roundtrip,
    ef_topk_roundtrip, int8_roundtrip, quantize_int8, topk_densify,
    topk_roundtrip, topk_sparsify,
)
from repro.dist.sharding import build_rules


# ---------------------------------------------------------------------------
# shard / axis_size / use_mesh
# ---------------------------------------------------------------------------

def test_shard_is_noop_outside_mesh():
    x = jnp.ones((4, 8))
    assert not mesh_active()
    assert shard(x, "batch", "embed") is x
    assert shard_param(x, ("embed", "ff")) is x
    assert pin_params({"w": x}, {"w": ("embed", "ff")})["w"] is x


def test_axis_size_defaults_to_one():
    assert axis_size("heads") == 1          # no mesh at all
    with use_mesh({"data": 2, "model": 2},
                  {"param": {}, "act": {"heads": ("model",)}}):
        assert axis_size("heads") == 2      # mapped logical axis
        assert axis_size("data") == 2       # physical axis by name
        assert axis_size("no_such_axis") == 1


def test_use_mesh_degrades_to_single_device():
    with use_mesh() as mesh:                # no mesh given at all
        assert mesh.devices.size == 1
        assert mesh_active()
        x = shard(jnp.ones((4, 4)), "batch", None)
        assert x.shape == (4, 4)
    assert not mesh_active()


def test_shard_applies_constraint_in_jit():
    rules = build_rules(recipe="tp_fsdp")
    with use_mesh({"data": 2, "model": 4}, rules):
        y = jax.jit(lambda x: shard(x, "batch", None, "ff"))(
            jnp.ones((4, 3, 8)))
        spec = y.sharding.spec
        assert spec[0] == "data" and spec[2] == "model"
        # non-dividing dim (3 % 4 != 0) must stay replicated, not crash
        z = jax.jit(lambda x: shard(x, "batch", None, "ff"))(
            jnp.ones((4, 3, 6)))
        # jax may trim trailing Nones from the spec; just require that the
        # ff dim landed on no mesh axis
        assert "model" not in tuple(z.sharding.spec)


# ---------------------------------------------------------------------------
# logical_to_spec
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_logical_to_spec_divisibility():
    mesh = _FakeMesh({"data": 2, "model": 4})
    rules = {"batch": ("data", "model")}
    # 8 divides by 2*4 -> both axes
    assert logical_to_spec(("batch",), rules, mesh, (8,))[0] == ("data", "model")
    # 6 divides by 2 only -> prefix
    assert logical_to_spec(("batch",), rules, mesh, (6,))[0] == "data"
    # 5 divides by nothing -> replicated
    assert logical_to_spec(("batch",), rules, mesh, (5,))[0] is None


def test_logical_to_spec_never_reuses_mesh_axes():
    mesh = _FakeMesh({"model": 4})
    rules = {"heads": ("model",), "ff": ("model",)}
    spec = logical_to_spec(("heads", "ff"), rules, mesh, (8, 8))
    assert spec[0] == "model" and spec[1] is None


def test_logical_to_spec_skips_absent_mesh_axes():
    mesh = _FakeMesh({"data": 2})
    spec = logical_to_spec(("layers", "batch"), {"batch": ("pod", "data")},
                           mesh, (3, 4))
    # "layers" has no rule -> replicated; "pod" is absent -> skipped,
    # the chain continues to "data" (multipod rules on a single-pod mesh)
    assert spec[0] is None and spec[1] == "data"


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(scale=5.0, size=(256,)).astype(np.float32))
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_quantize_all_zeros_is_exact():
    q, scale = quantize_int8(jnp.zeros((16,)))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, scale)),
                                  np.zeros((16,), np.float32))


def test_compressed_mean_host_side():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    mean, err = compressed_allreduce_mean(x)   # leading dim = workers
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x.mean(0)),
                               atol=2e-2)
    assert float(err) >= 0.0 and np.isfinite(float(err))


def test_topk_sparsify_keeps_largest_coordinates():
    x = jnp.asarray(np.array([[0.1, -5.0, 0.2], [3.0, -0.05, 0.4]],
                             np.float32))
    v, i = topk_sparsify(x, 2)
    dense = topk_densify(v, i, x.shape)
    # the two largest-|.| entries survive exactly; the rest are zeroed
    np.testing.assert_array_equal(
        np.asarray(dense), np.array([[0, -5.0, 0], [3.0, 0, 0]], np.float32))
    np.testing.assert_array_equal(np.asarray(topk_roundtrip(x, 2)),
                                  np.asarray(dense))
    # k clamps to the tensor size (full fidelity)
    np.testing.assert_array_equal(np.asarray(topk_roundtrip(x, 100)),
                                  np.asarray(x))


def test_topk_error_feedback_bounds_accumulated_error():
    """Residual carry keeps the error of a 50-step accumulated sparse
    uplink bounded (every coordinate is eventually transmitted); plain
    top-k drops the same small coordinates every step and drifts
    linearly. Mirrors the int8 `ef_roundtrip` bounded-error test."""
    rng = np.random.default_rng(0)
    d, k, steps = 128, 16, 50
    g = jnp.asarray(rng.normal(scale=1e-2, size=(d,)).astype(np.float32))
    plain = jnp.zeros_like(g)
    ef = jnp.zeros_like(g)
    residual = ef_init(g)
    for _ in range(steps):
        plain = plain + topk_roundtrip(g, k)
        dec, residual = ef_topk_roundtrip(residual, g, k)
        ef = ef + dec
    true = steps * g
    err_plain = float(jnp.max(jnp.abs(plain - true)))
    err_ef = float(jnp.max(jnp.abs(ef - true)))
    # exact telescoping identity: everything not yet sent is the residual
    np.testing.assert_allclose(np.asarray(ef + residual), np.asarray(true),
                               rtol=1e-4, atol=1e-5)
    # EF error stays bounded by one round-robin sweep of dropped mass...
    assert err_ef <= (d / k) * float(jnp.max(jnp.abs(g))) + 1e-6
    # ...while plain top-k accumulates the dropped coordinates linearly
    assert err_plain >= 0.5 * steps * float(jnp.sort(jnp.abs(g))[d - k - 1])
    assert err_ef < err_plain


def test_error_feedback_bounds_accumulated_error():
    """Residual carry keeps the error of a 50-step accumulated uplink
    bounded by ~one quantum; plain quantization drifts linearly."""
    rng = np.random.default_rng(0)
    # constant-ish gradient: round-to-nearest bias repeats every step
    g = jnp.asarray(rng.normal(scale=1e-2, size=(128,)).astype(np.float32))
    plain = jnp.zeros_like(g)
    ef = jnp.zeros_like(g)
    residual = ef_init(g)
    for _ in range(50):
        plain = plain + int8_roundtrip(g)
        dec, residual = ef_roundtrip(residual, g)
        ef = ef + dec
    true = 50.0 * g
    err_plain = float(jnp.max(jnp.abs(plain - true)))
    err_ef = float(jnp.max(jnp.abs(ef - true)))
    _, scale = quantize_int8(g)
    assert err_ef < err_plain, (err_ef, err_plain)
    # EF error never exceeds one carried quantum (scale of the last round)
    assert err_ef <= 2.0 * float(scale) + 1e-6
    # while plain accumulates a visible multiple of it
    assert err_plain > 5.0 * float(scale)


def test_rescale_cycle_preserves_values(tmp_path):
    """save -> rebuild_mesh -> reshard_tree returns the same values on a
    fresh mesh (the elastic grow/shrink runtime mechanism)."""
    tree = {"params": {"w": jnp.arange(32.0).reshape(8, 4)},
            "opt": {"m": jnp.ones((8, 4))}}
    axes = {"params": {"w": ("embed", "ff")},
            "opt": elastic.replicated_axes(tree["opt"])}
    rules = {"param": {"embed": "data", "ff": "model"}, "act": {}}
    out, mesh = elastic.rescale_cycle(tmp_path, 7, tree, axes, rules,
                                      new_workers=2)
    assert mesh.devices.size >= 1
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(out["opt"]["m"]),
                                  np.asarray(tree["opt"]["m"]))
    assert ckpt.latest_step(tmp_path) == 7


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_async_checkpointer_wait_ordering(tmp_path):
    """After wait(), every submitted step is on disk and the latest wins."""
    t = {"w": jnp.arange(16.0).reshape(4, 4)}
    ac = ckpt.AsyncCheckpointer(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ac.save(s, jax.tree.map(lambda x: x + s, t))
    ac.wait()
    assert ckpt.latest_step(tmp_path) == 5
    restored, meta = ckpt.restore(tmp_path, t)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"] + 5))
    ac.close()
    with pytest.raises(RuntimeError):
        ac.save(6, t)


def test_checkpoint_keep_retention(tmp_path):
    for s in range(6):
        ckpt.save(tmp_path, s, {"w": jnp.ones((2,))}, keep=3)
    assert ckpt.latest_step(tmp_path) == 5
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4, 5]


# ---------------------------------------------------------------------------
# train/launch wiring
# ---------------------------------------------------------------------------

def test_train_step_int8_grad_compression():
    from repro.configs import get_config
    from repro.train.optim import make_optimizer
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen2-1.5b", smoke=True)
    opt = make_optimizer(cfg, "sgd", lr=lambda step: 0.1)  # no warmup
    params = {"w": jnp.ones((4,))}

    def loss_fn(p, b):
        return jnp.sum(jnp.square(p["w"] - b["x"])), {}

    ts = make_train_step(cfg, opt, loss_fn=loss_fn, microbatches=1,
                         grad_compression="int8")
    new_p, *_ = jax.jit(ts)(params, opt.init(params), jnp.asarray(0),
                            {"x": jnp.zeros((4,))})
    # grads survive the int8 wire well enough to descend
    assert float(jnp.max(new_p["w"])) < 1.0
    with pytest.raises(ValueError, match="grad_compression"):
        make_train_step(cfg, opt, grad_compression="zfp")


def test_mesh_context_activates_recipe_rules():
    from repro.configs import get_config
    from repro.launch.mesh import mesh_context

    cfg = get_config("qwen2-1.5b", smoke=True).with_overrides(recipe="tp_fsdp")
    with mesh_context(cfg, data=2, model=4):
        assert mesh_active()
        assert axis_size("heads") == 4
    assert not mesh_active()


# ---------------------------------------------------------------------------
# elastic
# ---------------------------------------------------------------------------

def test_factor_mesh_power_of_two_data():
    assert elastic.factor_mesh(6, prefer_model=2) == (2, 2)
    assert elastic.factor_mesh(8, prefer_model=2) == (4, 2)
    assert elastic.factor_mesh(8, prefer_model=1) == (8, 1)
    assert elastic.factor_mesh(1, prefer_model=4) == (1, 1)


def test_plan_reshard_checkpoint_cycle():
    assert not elastic.plan_reshard(2, 4).needs_checkpoint_cycle   # even grow
    assert not elastic.plan_reshard(4, 2).needs_checkpoint_cycle   # even shrink
    assert elastic.plan_reshard(4, 6).needs_checkpoint_cycle       # uneven
    assert elastic.plan_reshard(3, 3).action == "hold"


def test_elastic_controller_hysteresis():
    ctl = elastic.ElasticController(workers=2, patience=3, cooldown=5)
    # two overloaded steps then relief: patience not met -> hold
    assert ctl.observe(0, offered=10.0, achieved=1.0).action == "hold"
    assert ctl.observe(1, offered=10.0, achieved=1.0).action == "hold"
    assert ctl.observe(2, offered=1.0, achieved=1.0).action == "hold"
    # three sustained overloads -> grow 2 -> 4
    for s in (3, 4):
        assert ctl.observe(s, offered=10.0, achieved=1.0).action == "hold"
    plan = ctl.observe(5, offered=10.0, achieved=1.0)
    assert plan.action == "grow" and plan.workers == 4
    # cooldown gates the next action
    assert ctl.observe(6, offered=40.0, achieved=1.0).reason == "cooldown"


def test_make_elastic_mesh_survives_failures():
    from repro.launch.mesh import make_elastic_mesh

    mesh = make_elastic_mesh(prefer_model=2, failed=[jax.devices()[0]])
    # 8 devices - 1 failed = 7 -> model 2, data pow2_floor(3) = 2
    assert dict(mesh.shape) == {"data": 2, "model": 2}
    assert jax.devices()[0] not in set(mesh.devices.flat)


def test_reshard_tree_roundtrip():
    mesh = jax.make_mesh((2, 2), ("data", "model"),
                         devices=jax.devices()[:4])
    tree = {"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones((5,))}
    axes = {"w": ("embed", "ff"), "b": ("embed",)}   # 5 % 2 -> replicated
    rules = {"param": {"embed": ("data",), "ff": ("model",)}, "act": {}}
    out = elastic.reshard_tree(tree, axes, rules, mesh)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, out)
    assert isinstance(out["w"].sharding, NamedSharding)
    assert out["w"].sharding.spec[0] == "data"
