"""S2CE core: cost model, placement (vs exhaustive oracle), offload
hysteresis, SLA tracking, end-to-end orchestrator run."""

import numpy as np
import pytest

from repro.core import costmodel as cm
from repro.core.offload import OffloadController
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import (Objective, place, place_exhaustive,
                                  standard_pipeline)
from repro.core.sla import SLA, SLATracker
from repro.streams.generators import DriftSpec, HyperplaneStream

RES = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}


def test_stage_time_roofline_max():
    op = cm.OperatorCost("x", flops_per_event=1e6, bytes_per_event=1e3,
                         out_bytes_per_event=10)
    t = cm.stage_time(op, cm.EDGE_NODE, rate=1e3)
    assert t == pytest.approx(max(1e9 / 2e12, 1e6 / 50e9))


@pytest.mark.parametrize("rate", [1e2, 1e4, 1e6])
def test_prefix_cut_matches_exhaustive_oracle(rate):
    ops = standard_pipeline(dim=16)
    obj = Objective()
    best, _ = place(ops, RES, rate, obj)
    oracle = place_exhaustive(ops, RES, rate, obj)
    assert obj.score(best) <= obj.score(oracle) * 1.0001, (
        "prefix-cut placement must match the exhaustive oracle on linear "
        "pipelines")


def test_dl_train_never_on_edge():
    ops = standard_pipeline(dim=16)
    for rate in (1e2, 1e5):
        plan, _ = place(ops, RES, rate)
        assert plan.assignment["dl_train"] == "cloud"


def test_high_rate_pushes_work_to_cloud():
    ops = standard_pipeline(dim=64)
    _, cut_lo = place(ops, RES, 1e3)
    _, cut_hi = place(ops, RES, 5e6)
    assert cut_hi <= cut_lo, "rising rate must move stages off the edge"


def test_offload_hysteresis_no_thrash():
    ops = standard_pipeline(dim=32)
    ctl = OffloadController(ops, RES, cooldown=3)
    ctl.initial_plan(1e4)
    # oscillate +-10% (inside the 1.3x band): no migrations
    for step in range(1, 30):
        rate = 1e4 * (1.1 if step % 2 else 0.9)
        ctl.observe(step, rate)
    assert ctl.migrations() == 0


def test_offload_reacts_to_burst():
    ops = standard_pipeline(dim=64)
    ctl = OffloadController(ops, RES, cooldown=1)
    d0 = ctl.initial_plan(1e3)
    d1 = ctl.observe(1, 1e7)       # big burst
    assert d1.cut <= d0.cut
    assert d1.reason == "rate_up"


def test_sla_tracker_p99_and_violations():
    t = SLATracker(SLA(max_latency_s=0.1))
    for i in range(100):
        t.observe(0.01 if i % 10 else 0.5, 1e4)
    assert t.violation_rate == pytest.approx(0.1)
    assert t.p99_latency >= 0.1
    assert not t.ok()


def test_orchestrator_end_to_end_adapts_to_drift():
    job = StreamJob("e2e", dim=8, drift_detector="ddm", sample_rate=0.8)
    orch = Orchestrator(job)
    gen = HyperplaneStream(dim=8, seed=0,
                           drift=DriftSpec("abrupt", at=0.5),
                           horizon=64 * 60.0)
    batches = [gen.batch(i, 64) for i in range(60)]
    m = orch.run(batches)
    assert m.events == 60 * 64
    assert m.drift_alarms >= 1, "DDM should fire on the abrupt concept flip"
    assert m.preq["accuracy"] > 0.6
    assert m.preq["ewma_accuracy"] > 0.65, (
        "post-drift recovery (soft reset) should restore accuracy")
