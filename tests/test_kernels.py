"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.countmin import countmin_update
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan_bd
from repro.kernels.rwkv6_wkv import rwkv6_wkv


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,KV,D", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),     # GQA
    (1, 200, 200, 2, 1, 128),    # ragged seq (padding path)
    (2, 64, 192, 2, 2, 64),      # cross-length (q shorter than kv)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, S, T, H, KV, D, dtype, causal):
    if causal and S != T:
        pytest.skip("causal with offset tested via model path")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, D), dtype)
    k = _rand(ks[1], (B, T, KV, D), dtype)
    v = _rand(ks[2], (B, T, KV, D), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shapes(bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (1, 128, 2, 64), jnp.float32)
    k = _rand(ks[1], (1, 128, 2, 64), jnp.float32)
    v = _rand(ks[2], (1, 128, 2, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hs,chunk", [
    (1, 32, 1, 16, 8),
    (2, 64, 3, 32, 16),
    (1, 50, 2, 64, 16),          # ragged
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_wkv_matches_ref(B, S, H, hs, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = _rand(ks[0], (B, S, H, hs), dtype) * 0.5
    k = _rand(ks[1], (B, S, H, hs), dtype) * 0.5
    v = _rand(ks[2], (B, S, H, hs), dtype) * 0.5
    lw = -jnp.exp(_rand(ks[3], (B, S, H, hs), jnp.float32) - 2.0)  # < 0
    u = _rand(ks[4], (H, hs), jnp.float32) * 0.3
    h0 = _rand(ks[5], (B, H, hs, hs), jnp.float32) * 0.1
    o, h_last = rwkv6_wkv(r, k, v, lw.astype(dtype), u, h0, chunk=chunk,
                          interpret=True)
    o_ref, h_ref = ref.rwkv6_wkv_ref(r, k, v, lw, u, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Mamba selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,dI,N,chunk,bd", [
    (1, 32, 64, 4, 16, 32),
    (2, 64, 128, 8, 32, 64),
    (1, 48, 256, 16, 16, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_matches_ref(B, S, dI, N, chunk, bd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    dt = jax.nn.softplus(_rand(ks[0], (B, S, dI), jnp.float32) - 2).astype(dtype)
    x = _rand(ks[1], (B, S, dI), dtype)
    Bm = _rand(ks[2], (B, S, N), dtype)
    Cm = _rand(ks[3], (B, S, N), dtype)
    A = -jnp.exp(_rand(ks[4], (dI, N), jnp.float32) * 0.5)
    h0 = jnp.zeros((B, dI, N), jnp.float32)
    y, h_last = mamba_scan_bd(dt, x, Bm, Cm, A, h0, chunk=chunk, bd=bd,
                              interpret=True)
    y_ref, h_ref = ref.mamba_scan_ref(dt, x, Bm, Cm, A, h0)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h_ref),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# Count-Min sketch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,depth,width,block", [
    (100, 2, 64, 32),
    (1000, 4, 128, 256),
    (37, 3, 32, 64),             # n < block
])
def test_countmin_matches_ref(n, depth, width, block):
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, 10_000, n), jnp.int32)
    seeds = jnp.asarray(rng.integers(1, 2**14, (depth, 2)) * 2 + 1,
                        jnp.int32)   # 15-bit: products fit int32 exactly
    out = countmin_update(ids, depth, width, seeds, block=block,
                          interpret=True)
    want = ref.countmin_ref(ids, depth, width, np.asarray(seeds))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_countmin_never_underestimates():
    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.zipf(1.5, 5000) % 1000, jnp.int32)
    depth, width = 4, 256
    seeds = jnp.asarray(rng.integers(1, 2**14, (depth, 2)) * 2 + 1, jnp.int32)
    sk = np.asarray(countmin_update(ids, depth, width, seeds, interpret=True))
    P = 2_147_483_647
    true = np.bincount(np.asarray(ids), minlength=1000)
    for item in np.unique(np.asarray(ids))[:50]:
        est = min(sk[d, ((int(item) * int(seeds[d, 0]) + int(seeds[d, 1]))
                         % P) % width] for d in range(depth))
        assert est >= true[item]
