"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run (and only the dry-run) forces 512 host platform devices;
``make_production_mesh`` then carves the single-pod (16,16)=256-chip mesh or
the multi-pod (2,16,16)=512-chip mesh out of the available devices.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    import jax
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])
