"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run (and only the dry-run) forces 512 host platform devices;
``make_production_mesh`` then carves the single-pod (16,16)=256-chip mesh or
the multi-pod (2,16,16)=512-chip mesh out of the available devices.
"""

from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    import jax
    n = data * model
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:n])


def make_elastic_mesh(prefer_model: int = 1, failed=()):
    """Best-effort mesh over whatever devices currently survive.

    Used after an elastic grow/shrink or a worker failure: carves the
    largest power-of-two data axis (x ``prefer_model``) out of the
    non-failed local devices via dist/elastic.
    """
    import jax

    from repro.dist.elastic import rebuild_mesh
    return rebuild_mesh(jax.devices(), failed=failed,
                        prefer_model=prefer_model)


def mesh_context(cfg, data: int = 1, model: int = 1, *, shape=None):
    """``use_mesh`` context for a local (data, model) mesh with the
    arch's recipe rules — the one-liner launchers use to activate
    distribution (a (1,1) request still yields a working context)."""
    from repro.dist import use_mesh
    from repro.dist.sharding import build_rules
    return use_mesh(make_local_mesh(data, model),
                    build_rules(cfg, shape=shape))
