"""Aggregate dry-run cell records into the EXPERIMENTS.md roofline tables.

Usage:
  PYTHONPATH=src python -m repro.launch.report            # print tables
  PYTHONPATH=src python -m repro.launch.report --pick3    # hillclimb picks
"""

from __future__ import annotations

import argparse
import json
import pathlib
from typing import Dict, List

ROOT = pathlib.Path(__file__).resolve().parents[3]
DRYRUN = ROOT / "experiments" / "dryrun"

HBM_CAP_GIB = 16.0


def load_cells(mesh_dir: str = "pod_16x16") -> List[dict]:
    cells = []
    base = DRYRUN / mesh_dir
    if not base.exists():
        return cells
    for arch_dir in sorted(base.iterdir()):
        for f in sorted(arch_dir.glob("*.json")):
            cells.append(json.loads(f.read_text()))
    return cells


def _row(c: dict) -> dict:
    rf = c.get("roofline", {})
    mem = c.get("memory", {}).get("total_per_device", 0) / 2**30
    return {
        "arch": c["arch"], "shape": c["shape"], "ok": c.get("ok", False),
        "recipe": c.get("recipe", "?"),
        "mem_gib": mem, "fits": mem <= HBM_CAP_GIB,
        "t_comp": rf.get("t_compute_s", 0.0),
        "t_mem": rf.get("t_memory_s", 0.0),
        "t_coll": rf.get("t_collective_s", 0.0),
        "dom": rf.get("dominant", "?"),
        "useful": rf.get("useful_flops_ratio", 0.0),
        "frac": rf.get("roofline_fraction", 0.0),
        "params_total": c.get("params_total", 0),
        "err": c.get("error", "")[:60],
    }


def table(mesh_dir: str = "pod_16x16") -> List[dict]:
    return [_row(c) for c in load_cells(mesh_dir)]


def render_markdown(rows: List[dict]) -> str:
    hdr = ("| arch | shape | recipe | mem GiB | fits | t_comp s | t_mem s | "
           "t_coll s | dominant | useful | roofline frac |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['recipe']} | "
            f"{r['mem_gib']:.2f} | {'Y' if r['fits'] else 'N'} | "
            f"{r['t_comp']:.3f} | {r['t_mem']:.3f} | {r['t_coll']:.3f} | "
            f"{r['dom']} | {r['useful']:.2f} | {r['frac']:.4f} |")
    return "\n".join(lines)


def pick3(rows: List[dict]) -> Dict[str, dict]:
    """worst roofline fraction (train), most collective-bound, and the
    serving cell most representative of the S2CE pipeline."""
    ok = [r for r in rows if r["ok"] and r["frac"] > 0]
    train = [r for r in ok if r["shape"] == "train_4k"]
    worst = min(train, key=lambda r: r["frac"])
    coll = max(ok, key=lambda r: (r["t_coll"] /
                                  max(r["t_comp"], r["t_mem"], 1e-12)))
    serve = [r for r in ok if r["shape"] in ("decode_32k", "prefill_32k")]
    rep = max(serve, key=lambda r: r["mem_gib"])
    return {"worst_fraction": worst, "most_collective": coll,
            "serving_representative": rep}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--pick3", action="store_true")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = table(args.mesh)
    if args.markdown:
        print(render_markdown(rows))
        return
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        status = "ok " if r["ok"] else "ERR"
        print(f"{status} {r['arch']:>24s} {r['shape']:>12s} {r['recipe']:>10s} "
              f"mem={r['mem_gib']:7.2f}GiB fits={'Y' if r['fits'] else 'N'} "
              f"tc={r['t_comp']:8.3f} tm={r['t_mem']:8.3f} "
              f"tl={r['t_coll']:8.3f} dom={r['dom']:10s} "
              f"useful={r['useful']:5.2f} frac={r['frac']:.4f}")
    if args.pick3:
        print("\n== hillclimb picks ==")
        for k, r in pick3(rows).items():
            print(f"{k}: {r['arch']} x {r['shape']} (dom={r['dom']}, "
                  f"frac={r['frac']:.4f}, mem={r['mem_gib']:.1f}GiB)")


if __name__ == "__main__":
    main()
