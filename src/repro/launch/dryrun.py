import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective analysis.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails the cell. Results are written incrementally to
``experiments/dryrun/<mesh>/<arch>/<shape>.json`` so reruns skip green cells.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single --force
  PYTHONPATH=src python -m repro.launch.dryrun --recipe tp --microbatches 4
"""

import argparse
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config, shapes_for, skipped_shapes_for
from repro.dist import use_mesh
from repro.dist.sharding import build_rules, param_sharding_tree
from repro.dist.api import logical_to_spec
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.models import model_zoo as zoo
from repro.models import params as pmod
from repro.models.layers import dtype_of
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step

ROOT = pathlib.Path(__file__).resolve().parents[3]
OUT = ROOT / "experiments" / "dryrun"


def _param_sds(cfg, rules, mesh):
    shapes = zoo.param_shapes(cfg)
    axes = zoo.param_axes(cfg)
    def leaf(sds, ax):
        spec = logical_to_spec(ax, rules["param"], mesh, sds.shape)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(leaf, shapes, axes)


def _batch_sds(cfg, shape, rules, mesh):
    specs = zoo.input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if k == "caches":
            axes = zoo.cache_axes(v)
            out[k] = jax.tree.map(
                lambda s, ax: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=NamedSharding(
                        mesh, logical_to_spec(ax, rules["act"], mesh, s.shape))),
                v, axes)
        else:
            spec = logical_to_spec(
                ("batch",) + (None,) * (len(v.shape) - 1), rules["act"], mesh,
                v.shape)
            out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype,
                                          sharding=NamedSharding(mesh, spec))
    return out


def build_cell(cfg, shape, mesh, rules, impl="chunked"):
    """Returns (jitted_fn, example_args) for one dry-run cell."""
    params_sds = _param_sds(cfg, rules, mesh)
    batch_sds = _batch_sds(cfg, shape, rules, mesh)

    if shape.kind == "train":
        opt = make_optimizer(cfg, "adamw")
        opt_sds_raw = jax.eval_shape(opt.init, params_sds)
        opt_axes = opt.state_axes(zoo.param_axes(cfg))
        opt_sds = jax.tree.map(
            lambda s, ax: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(
                    mesh, logical_to_spec(ax, rules["param"], mesh, s.shape))),
            opt_sds_raw, opt_axes)
        step_sds = jax.ShapeDtypeStruct((), jnp.int32)
        train_step = make_train_step(cfg, opt, impl=impl)
        fn = jax.jit(train_step, donate_argnums=(0, 1))
        args = (params_sds, opt_sds, step_sds, batch_sds)
    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            return zoo.prefill(params, cfg, batch, max_len=shape.seq_len,
                               impl=impl)
        fn = jax.jit(prefill_step)
        args = (params_sds, batch_sds)
    else:  # decode
        def serve_step(params, caches, tokens):
            return zoo.decode_step(params, cfg, caches, tokens, impl=impl)
        fn = jax.jit(serve_step, donate_argnums=(1,))
        args = (params_sds, batch_sds["caches"], batch_sds["tokens"])
    return fn, args


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             recipe=None, impl="chunked", overrides=None, tag="",
             force=False, save=True) -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    out_dir = OUT / (mesh_name + (f"_{tag}" if tag else ""))
    out_path = out_dir / arch / f"{shape_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_overrides(**overrides)
    if recipe:
        cfg = cfg.with_overrides(recipe=recipe)
    shape = SHAPES_BY_NAME[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "recipe": cfg.recipe, "impl": impl, "tag": tag,
           "overrides": overrides or {}, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        rules = build_rules(cfg, shape=shape)
        with use_mesh(mesh, rules):
            fn, args = build_cell(cfg, shape, mesh, rules, impl=impl)
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        rec["memory"]["total_per_device"] = (
            rec["memory"].get("argument_size_in_bytes", 0)
            + rec["memory"].get("temp_size_in_bytes", 0))
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if k in ("flops", "bytes accessed")}
        hlo = compiled.as_text()
        from repro.launch import hlo_analysis as ha
        scan_aware = ha.analyze(hlo)
        rec["collectives"] = {k: float(v) for k, v in
                              scan_aware["collectives"].items()}
        rec["collectives"]["total"] = scan_aware["collective_bytes_total"]
        chips = mesh.devices.size
        roof = rf.from_compiled(compiled, cfg, shape, chips, hlo_text=hlo)
        rec["roofline"] = roof.to_dict()
        rec["raw_cost_analysis_note"] = (
            "cost dict above is XLA raw (scan bodies counted once); "
            "roofline uses scan-aware HLO analysis")
        counts = cfg.param_counts()
        rec["params_total"] = counts["total"]
        rec["params_active"] = counts["active"]
        rec["ok"] = True
        del compiled, lowered, hlo
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if save:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--recipe", default=None)
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    if args.microbatches:
        overrides["microbatches"] = args.microbatches

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        names = [args.shape] if args.shape else [s.name for s in shapes_for(cfg)]
        for skipped in skipped_shapes_for(cfg):
            if not args.shape:
                print(f"SKIP  {arch:>24s} {skipped.name:>12s}  "
                      "(full attention; see DESIGN.md §Arch-applicability)")
                n_skip += 1
        for shape_name in names:
            for mp in meshes:
                rec = run_cell(arch, shape_name, mp, recipe=args.recipe,
                               impl=args.impl, tag=args.tag,
                               overrides=overrides or None, force=args.force)
                status = "OK  " if rec["ok"] else "FAIL"
                mesh_name = "multi " if mp else "single"
                extra = ""
                if rec["ok"]:
                    m = rec["memory"].get("total_per_device", 0) / 2**30
                    dom = rec["roofline"]["dominant"]
                    extra = f"mem/dev={m:6.2f}GiB dom={dom}"
                else:
                    extra = rec.get("error", "")[:120]
                print(f"{status}  {arch:>24s} {shape_name:>12s} {mesh_name} "
                      f"t={rec['total_s']:7.1f}s  {extra}", flush=True)
                n_ok += rec["ok"]
                n_fail += (not rec["ok"])
    print(f"\ndone: {n_ok} ok, {n_fail} failed, {n_skip} skipped-by-design")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
