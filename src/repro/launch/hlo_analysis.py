"""Scan-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scanned program (layer stacks, microbatch accumulation, KV-block streaming)
is wildly under-counted. This module re-derives the three roofline inputs
from the optimized HLO text, scaling every computation by the product of
enclosing while-loop trip counts (``backend_config known_trip_count``, which
jax scans always carry):

  * dot FLOPs        — 2 * prod(output dims) * prod(contracting dims)
  * HBM bytes        — sum of operand + output bytes of top-level
                       instructions (fusion bodies excluded: a fusion's
                       traffic is its call-site operands/outputs, matching
                       XLA's own model)
  * collective bytes — per-op link volume with ring factors
                       (all-reduce 2x, others 1x)

Elementwise FLOPs are not counted (dots dominate every assigned arch; the
Mamba/RWKV chunk scans are elementwise-heavy and noted as an undercount in
EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 0.125, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVE_FACTORS = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0, "collective-broadcast": 1.0,
}

_NO_TRAFFIC = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "add-dependency", "partition-id", "replica-id", "while",
    "conditional", "call",  # called bodies are counted themselves
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(%[\w.\-]+|ROOT\s+%[\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%[\w.\-]+")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]{0,24}?(\d+)')
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=(%[\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_list_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    # (called_computation, trips, kind)
    calls: List[Tuple[str, float, str]] = field(default_factory=list)


def _dot_flops(out_shape: List[int], line: str, sym_shapes: Dict[str, list]) -> float:
    # contracting dims from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    opnds = _OPND_RE.findall(line.split("dot(", 1)[1])
    if not m or not opnds:
        return 0.0
    lhs = sym_shapes.get(opnds[0])
    if lhs is None:
        return 0.0
    cdims = [int(d) for d in m.group(1).split(",") if d]
    contract = 1
    for d in cdims:
        if d < len(lhs):
            contract *= lhs[d]
    out = 1
    for d in out_shape:
        out *= d
    return 2.0 * out * contract


def parse_hlo(text: str) -> Dict[str, CompStats]:
    comps: Dict[str, CompStats] = {}
    cur: Optional[str] = None
    stats: Optional[CompStats] = None
    sym_shapes: Dict[str, list] = {}
    fusion_bodies: set = set()

    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line[0].isspace():  # computation header or footer
            if line.startswith("}"):
                cur = None
                continue
            m = re.match(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(", line)
            if m and line.endswith("{"):
                cur = m.group(1)
                if line.startswith("ENTRY"):
                    comps["__entry__"] = CompStats()
                    comps[cur] = comps["__entry__"]
                else:
                    comps[cur] = CompStats()
                stats = comps[cur]
                sym_shapes = {}
            continue
        if cur is None or stats is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name = dm.group(1).replace("ROOT", "").strip()
        rhs = dm.group(2)
        shape_info = _first_shape(rhs)
        if shape_info:
            sym_shapes[name] = shape_info[1]
        out_end = rhs.find("(")
        head = rhs[:out_end] if out_end > 0 else rhs
        opm = re.match(r"[a-z0-9]+\[[0-9,]*\][^ ]*\s+([a-z\-]+)[.\d]*\(", rhs)
        opname = None
        if opm:
            opname = opm.group(1)
        else:
            opm2 = re.search(r"\s([a-z][a-z0-9\-]*)(?:\.\d+)?\(", " " + rhs)
            opname = opm2.group(1) if opm2 else None
        # output bytes (counted x2 in analyze() as write+read traffic);
        # zero-cost ops move no data
        if opname not in _NO_TRAFFIC:
            stats.hbm_bytes += _shape_list_bytes(head)

        if opname == "dot":
            stats.flops += _dot_flops(shape_info[1] if shape_info else [],
                                      rhs, sym_shapes)
        elif opname in _COLLECTIVE_FACTORS or (
                opname and opname.rstrip("-start").rstrip("-done") in _COLLECTIVE_FACTORS):
            base = opname.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVE_FACTORS and not rhs.strip().startswith("tuple"):
                if "-done" not in (opname or ""):
                    b = _shape_list_bytes(head) * _COLLECTIVE_FACTORS[base]
                    stats.collectives[base] = stats.collectives.get(base, 0.0) + b
        # call edges
        if "while(" in rhs:
            trip = 1.0
            tm = _TRIP_RE.search(rhs)
            if tm:
                trip = float(tm.group(1))
            body_m = re.search(r"body=(%[\w.\-]+)", rhs)
            cond_m = re.search(r"condition=(%[\w.\-]+)", rhs)
            if body_m:
                stats.calls.append((body_m.group(1), trip, "while"))
            if cond_m:
                stats.calls.append((cond_m.group(1), trip + 1, "while"))
        elif re.search(r"\bfusion\(", rhs):
            fm = re.search(r"calls=(%[\w.\-]+)", rhs)
            if fm:
                fusion_bodies.add(fm.group(1))
        elif " call(" in rhs:
            fm = re.search(r"to_apply=(%[\w.\-]+)", rhs)
            if fm:
                stats.calls.append((fm.group(1), 1.0, "call"))
        elif "conditional(" in rhs:
            bm = _BRANCH_RE.search(rhs)
            if bm:
                for b in _OPND_RE.findall(bm.group(1)):
                    stats.calls.append((b, 1.0, "branch"))

    # fusions whose bodies contain dots (rare on CPU) — fold dot flops of
    # fusion bodies into their own stats and let call-sites pick them up?
    # CPU backend keeps dots top-level; fusion bodies are elementwise. We
    # exclude fusion bodies entirely (their traffic = call-site operands).
    for fb in fusion_bodies:
        if fb in comps:
            comps[fb] = CompStats()  # zero out
    return comps


def totals(comps: Dict[str, CompStats]) -> dict:
    """Aggregate from entry, scaling by while trip counts (memoized)."""
    memo: Dict[str, dict] = {}

    def visit(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None or depth > 64:
            return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {}}
        agg = {"flops": st.flops, "hbm_bytes": st.hbm_bytes,
               "collectives": dict(st.collectives)}
        for child, trips, _kind in st.calls:
            sub = visit(child, depth + 1)
            agg["flops"] += trips * sub["flops"]
            agg["hbm_bytes"] += trips * sub["hbm_bytes"]
            for k, v in sub["collectives"].items():
                agg["collectives"][k] = agg["collectives"].get(k, 0.0) + trips * v
        memo[name] = agg
        return agg

    out = visit("__entry__")
    out["collective_bytes_total"] = sum(out["collectives"].values())
    return out


def analyze(hlo_text: str) -> dict:
    comps = parse_hlo(hlo_text)
    t = totals(comps)
    # double-count outputs as read+write is closer to XLA's model:
    t["hbm_bytes"] *= 2.0
    return t
