"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(per chip). ``cost_analysis()`` of the SPMD-partitioned module reports the
per-device program, so:

    compute term    = HLO_FLOPs(per-dev)  / peak_FLOPs
    memory term     = HLO_bytes(per-dev)  / HBM_bw
    collective term = link_bytes(per-dev) / link_bw

(equivalent to the global/chips formulation). Collective link-bytes are not
in cost_analysis: we parse the optimized HLO and apply per-op volume factors
(ring algorithms): all-reduce 2x input, all-gather 1x output, reduce-scatter
1x input, all-to-all 1x input, collective-permute 1x input.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_FACTORS = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "collective-broadcast": 1.0,
}

# e.g.:  %x = bf16[16,1024,512]{2,1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\(?)((?:[a-z0-9]+\[[0-9,]*\][^)\s]*,?\s?)+)\)?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device link bytes by collective kind (factors applied)."""
    out: Dict[str, float] = {}
    for m in _OP_RE.finditer(hlo_text):
        shapes, kind = m.group(1), m.group(2)
        b = _shape_bytes(shapes) * _COLLECTIVE_FACTORS[kind]
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class Roofline:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    link_bytes_per_dev: float
    chips: int
    model_flops_global: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.link_bytes_per_dev / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of peak the step achieves if it runs at the bound:
        useful MODEL_FLOPS / (chips * peak * bound_time)."""
        denom = self.chips * PEAK_FLOPS * self.bound_time
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "link_bytes_per_dev": self.link_bytes_per_dev,
            "chips": self.chips,
            "model_flops_global": self.model_flops_global,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def op_event_costs(compiled, n_events: int) -> Tuple[float, float]:
    """Per-event ``(flops, hbm_bytes)`` of one compiled pipeline-op step
    — the measured replacements for the hand-written
    ``OperatorCost.flops_per_event`` / ``bytes_per_event`` guesses
    (:func:`repro.core.selftune.measure_operator_costs` divides a whole
    compiled batch step by its event count).

    Primary source is the backend's ``cost_analysis()``; when a backend
    reports nothing (or zeros) for a term, that term falls back to the
    scan-aware HLO parse in :mod:`repro.launch.hlo_analysis` — the same
    numbers the dry-run roofline uses."""
    flops = hbm = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0) or 0.0)
        hbm = float(ca.get("bytes accessed", 0.0) or 0.0)
    except Exception:
        pass
    if flops <= 0.0 or hbm <= 0.0:
        from repro.launch import hlo_analysis as ha
        t = ha.analyze(compiled.as_text())
        if flops <= 0.0:
            flops = float(t["flops"])
        if hbm <= 0.0:
            hbm = float(t["hbm_bytes"])
    n = max(int(n_events), 1)
    return flops / n, hbm / n


def model_flops(cfg, shape) -> float:
    """6*N*D for train (N=active params, D=tokens); 2*N*D for inference."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def from_compiled(compiled, cfg, shape, chips: int,
                  hlo_text: Optional[str] = None) -> Roofline:
    """Scan-aware roofline terms from the compiled artifact.

    Raw ``cost_analysis()`` under-counts while-loop bodies (scans run once),
    so FLOPs/bytes/collectives come from
    :mod:`repro.launch.hlo_analysis`, which scales every computation by its
    enclosing ``known_trip_count``s. Raw numbers are recorded separately by
    the dry-run for reference.
    """
    from repro.launch import hlo_analysis as ha
    text = hlo_text if hlo_text is not None else compiled.as_text()
    t = ha.analyze(text)
    return Roofline(
        flops_per_dev=t["flops"],
        hbm_bytes_per_dev=t["hbm_bytes"],
        link_bytes_per_dev=t["collective_bytes_total"],
        chips=chips,
        model_flops_global=model_flops(cfg, shape),
    )


def dl_operator_cost(name: str, cfg, *, phase: str, batch: int,
                     seq_len: int, new_tokens: int = 1,
                     param_bytes: float = 0.0, state_bytes: float = 0.0,
                     out_bytes_per_event: float = 0.0,
                     edge_capable: bool = True, downlink_ok: bool = False):
    """Declared :class:`~repro.core.costmodel.OperatorCost` for a DL op
    from the roofline flops rules — the same 6ND/2ND arithmetic
    :func:`model_flops` grounds the dry-run report with, so a declared
    train/prefill/decode op and the §Roofline analysis speak one
    language instead of hand-guessed constants. An *event* is one
    request/sequence; ``phase`` is ``"train"`` (6ND over ``seq_len``
    tokens), ``"prefill"`` (2ND over the prompt), or ``"decode"``
    (2N per generated token, ``new_tokens`` of them).

    ``bytes_per_event`` models the weight-stream traffic: parameters are
    read once per step and amortize over the ``batch`` sequences sharing
    it — except decode, which re-reads the weights for every generated
    token (the classic serving memory wall). Where a backend supports
    compiled cost analysis, :func:`repro.core.selftune.
    measure_operator_costs` replaces these numbers with measurement; the
    semantic flags (``edge_capable``, ``downlink_ok``) and the
    ``state_bytes`` residency declaration are what placement needs even
    then."""
    from repro.core.costmodel import OperatorCost
    if phase not in ("train", "prefill", "decode"):
        raise ValueError(f"phase {phase!r} not in ('train', 'prefill', "
                         "'decode')")
    n_active = float(cfg.param_counts()["active"])
    b = max(int(batch), 1)
    if phase == "train":
        flops = 6.0 * n_active * seq_len
        hbm = 3.0 * param_bytes / b          # fwd read + grad + update
    elif phase == "prefill":
        flops = 2.0 * n_active * seq_len
        hbm = param_bytes / b
    else:
        flops = 2.0 * n_active * new_tokens
        hbm = param_bytes * new_tokens / b   # weight re-read per token
    return OperatorCost(name, flops_per_event=flops, bytes_per_event=hbm,
                        out_bytes_per_event=out_bytes_per_event,
                        state_bytes=state_bytes, edge_capable=edge_capable,
                        downlink_ok=downlink_ok)
