"""Training launcher: builds the mesh, shards params/optimizer per the
arch's recipe, and runs the streaming train loop with async checkpointing
and drift-adaptive control.

On this CPU container it runs reduced configs (``--smoke``); on a pod the
same entrypoint runs the full config (remove --smoke, point JAX at the
TPU runtime). The step function is identical to the dry-run cells.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import pathlib
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "lion", "sgd"])
    ap.add_argument("--recipe", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # a >1 mesh on a CPU host needs forced host devices, and the flag must
    # land before jax initializes; harmless on real accelerator platforms.
    # An inherited flag with a too-small count is raised to n_req.
    import os
    import re
    n_req = args.data_mesh * args.model_mesh
    if n_req > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
        if m is None:
            flags = f"{flags} --xla_force_host_platform_device_count={n_req}"
        elif int(m.group(1)) < n_req:
            flags = flags.replace(
                m.group(0), f"--xla_force_host_platform_device_count={n_req}")
        os.environ["XLA_FLAGS"] = flags.strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.dist import checkpoint as ckpt
    from repro.launch.mesh import mesh_context
    from repro.models import model_zoo as zoo
    from repro.streams.generators import DriftSpec, TokenStream
    from repro.train.optim import make_optimizer
    from repro.train.train_step import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.recipe:
        cfg = cfg.with_overrides(recipe=args.recipe)
    if args.microbatches:
        cfg = cfg.with_overrides(microbatches=args.microbatches)

    n_dev = args.data_mesh * args.model_mesh
    print(f"arch={cfg.name} params={zoo.param_count(cfg)/1e6:.1f}M "
          f"recipe={cfg.recipe} mesh={n_dev} devices")

    gen = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      drift=DriftSpec("abrupt", at=0.5),
                      horizon=float(args.steps * args.batch * args.seq))
    opt = make_optimizer(cfg, args.optimizer, lr=args.lr,
                         total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, opt))

    ckpt_dir = pathlib.Path(args.ckpt_dir or tempfile.mkdtemp(prefix="s2ce_"))
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    params = zoo.init_params(cfg, 0)
    state = opt.init(params)
    step = jnp.asarray(0)
    start = 0
    if args.resume and ckpt.latest_step(ckpt_dir) is not None:
        tree, meta = ckpt.restore(ckpt_dir, {"params": params, "opt": state})
        params, state, start = tree["params"], tree["opt"], meta["step"]
        step = jnp.asarray(start)
        print(f"resumed from step {start}")

    import contextlib
    ctx = (mesh_context(cfg, args.data_mesh, args.model_mesh)
           if n_dev > 1 else contextlib.nullcontext())
    t0 = time.perf_counter()
    with ctx:
        for i in range(start, args.steps):
            batch = {"tokens": jnp.asarray(
                gen.batch(i, args.batch).data["tokens"])}
            if cfg.family == "vlm":
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.frontend_len, cfg.frontend_dim),
                    jnp.float32)
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq, cfg.frontend_dim), jnp.float32)
            params, state, step, metrics = step_fn(params, state, step, batch)
            if (i + 1) % args.ckpt_every == 0:
                saver.save(int(step), {"params": params, "opt": state})
            if i % 10 == 0:
                print(f"step {i:4d} loss={float(metrics['loss']):7.3f} "
                      f"gnorm={float(metrics['grad_norm']):6.2f}")
    saver.wait()
    dt = time.perf_counter() - t0
    toks = (args.steps - start) * args.batch * args.seq
    print(f"done: {toks/dt:.0f} tok/s; checkpoints at {ckpt_dir} "
          f"(latest {ckpt.latest_step(ckpt_dir)})")


if __name__ == "__main__":
    main()
