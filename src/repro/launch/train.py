"""Training launcher: builds the mesh, shards params/optimizer per the
arch's recipe, and runs the streaming train loop with async checkpointing
and drift-adaptive control.

On this CPU container it runs reduced configs (``--smoke``); on a pod the
same entrypoint runs the full config (remove --smoke, point JAX at the
TPU runtime). The step function is identical to the dry-run cells.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq 64

``--elastic`` activates the rate-driven :class:`ElasticController`; when
it emits a grow/shrink plan the loop drives it through the real
state-carrying cycle — ``checkpoint.save -> rebuild_mesh ->
reshard_tree -> resume`` (dist/elastic.rescale_cycle) — so a rescale
event goes through the same machinery as a failure recovery.
``--elastic-demand`` scales the offered rate relative to measured
per-worker throughput (a synthetic load curve for demos/tests).

Without ``--elastic-demand`` the offered load is derived from the
stream feeder's queue depth: batches are pulled through a
:class:`~repro.streams.feeder.StreamFeeder`, and a prefetch queue that
stays FULL for ``patience`` consecutive steps means the source outpaces
the pool, so controller utilization crosses the grow threshold.
(Previously measured-rate mode set offered = achieved x workers —
utilization exactly 1.0 forever, a silent no-op.) The backpressure
signal only grows the pool, toward the source's real rate or
``--max-workers``; shrinking needs the explicit demand curve.
"""

from __future__ import annotations

import argparse
import pathlib
import tempfile
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "lion", "sgd"])
    ap.add_argument("--recipe", default=None)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--elastic", action="store_true",
                    help="rate-driven worker scaling via checkpoint cycle")
    ap.add_argument("--max-workers", type=int, default=8,
                    help="elastic data-parallel worker cap")
    ap.add_argument("--elastic-demand", type=float, default=0.0,
                    help="offered rate = demand x per-worker throughput "
                         "(0 = use the measured rate)")
    args = ap.parse_args()

    # a >1 mesh on a CPU host needs forced host devices, and the flag must
    # land before jax initializes; harmless on real accelerator platforms.
    # An inherited flag with a too-small count is raised to n_req.
    import os
    import re
    n_req = args.data_mesh * args.model_mesh
    if args.elastic:
        n_req = max(n_req, args.max_workers * args.model_mesh)
    if n_req > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)", flags)
        if m is None:
            flags = f"{flags} --xla_force_host_platform_device_count={n_req}"
        elif int(m.group(1)) < n_req:
            flags = flags.replace(
                m.group(0), f"--xla_force_host_platform_device_count={n_req}")
        os.environ["XLA_FLAGS"] = flags.strip()

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.dist import checkpoint as ckpt
    from repro.launch.mesh import mesh_context
    from repro.models import model_zoo as zoo
    from repro.streams.generators import DriftSpec, TokenStream
    from repro.train.optim import make_optimizer
    from repro.train.train_step import make_train_step

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.recipe:
        cfg = cfg.with_overrides(recipe=args.recipe)
    if args.microbatches:
        cfg = cfg.with_overrides(microbatches=args.microbatches)

    n_dev = args.data_mesh * args.model_mesh
    print(f"arch={cfg.name} params={zoo.param_count(cfg)/1e6:.1f}M "
          f"recipe={cfg.recipe} mesh={n_dev} devices")

    gen = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      drift=DriftSpec("abrupt", at=0.5),
                      horizon=float(args.steps * args.batch * args.seq))
    opt = make_optimizer(cfg, args.optimizer, lr=args.lr,
                         total_steps=args.steps)

    ckpt_dir = pathlib.Path(args.ckpt_dir or tempfile.mkdtemp(prefix="s2ce_"))
    saver = ckpt.AsyncCheckpointer(ckpt_dir)
    params = zoo.init_params(cfg, 0)
    state = opt.init(params)
    step = jnp.asarray(0)
    start = 0
    if args.resume and ckpt.latest_step(ckpt_dir) is not None:
        tree, meta = ckpt.restore(ckpt_dir, {"params": params, "opt": state})
        params, state, start = tree["params"], tree["opt"], meta["step"]
        step = jnp.asarray(start)
        print(f"resumed from step {start}")

    import contextlib

    from repro.dist import elastic as el
    from repro.dist.sharding import build_rules

    controller = (el.ElasticController(
        workers=args.data_mesh, max_workers=args.max_workers,
        patience=2, cooldown=2) if args.elastic else None)
    workers = args.data_mesh

    # measured-rate elastic mode: pull batches through the stream feeder
    # so its queue depth gives a real offered-load signal (a backlog
    # means the source outpaces the pool -> utilization > 1 -> grow)
    feeder = None
    if controller is not None and args.elastic_demand <= 0:
        from repro.streams.feeder import StreamFeeder
        feeder = StreamFeeder(lambda shard, idx, n: gen.batch(idx, n),
                              n_shards=1, batch_per_shard=args.batch,
                              deadline_s=30.0, prefetch=4, start_idx=start)
        feeder.start()

    def make_batch(i):
        src = feeder.next() if feeder is not None else gen.batch(i, args.batch)
        batch = {"tokens": jnp.asarray(src.data["tokens"])}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.frontend_dim),
                jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, args.seq, cfg.frontend_dim), jnp.float32)
        return batch

    t0 = time.perf_counter()
    i = start
    while i < args.steps:
        # one mesh epoch: (re)trace the step under the current mesh; a
        # rescale below breaks out, round-trips state, and re-enters here
        n_dev = workers * args.model_mesh
        ctx = (mesh_context(cfg, workers, args.model_mesh)
               if n_dev > 1 else contextlib.nullcontext())
        step_fn = jax.jit(make_train_step(cfg, opt))
        plan = None
        with ctx:
            while i < args.steps:
                t_step = time.perf_counter()
                params, state, step, metrics = step_fn(
                    params, state, step, make_batch(i))
                if (i + 1) % args.ckpt_every == 0:
                    saver.save(int(step), {"params": params, "opt": state})
                if i % 10 == 0:
                    print(f"step {i:4d} loss={float(metrics['loss']):7.3f} "
                          f"gnorm={float(metrics['grad_norm']):6.2f} "
                          f"workers={workers}")
                if controller is not None:
                    jax.block_until_ready(metrics["loss"])
                    dt_step = max(time.perf_counter() - t_step, 1e-9)
                    achieved = args.batch * args.seq / dt_step / workers
                    if args.elastic_demand > 0:
                        offered = args.elastic_demand * achieved
                    elif feeder is not None:
                        # binary backpressure: a SUSTAINED-full prefetch
                        # queue (for `patience` consecutive steps) means
                        # the source outpaces the pool -> grow. This
                        # signal only ever grows (util is 1.0 when the
                        # queue has slack, never under the shrink
                        # threshold); shrinking needs a demand curve
                        # (--elastic-demand).
                        full = feeder.backlog >= feeder.prefetch
                        offered = achieved * workers * (2.0 if full else 1.0)
                    else:
                        offered = achieved * workers
                    plan = controller.observe(i, offered, achieved)
                i += 1
                if plan is not None and plan.changed:
                    break
                plan = None
        if plan is not None and plan.changed and i < args.steps:
            # the ROADMAP cycle: save -> rebuild_mesh -> reshard -> resume
            saver.wait()
            tree = {"params": params, "opt": state}
            axes = {"params": zoo.param_axes(cfg),
                    "opt": el.replicated_axes(state)}
            tree, mesh = el.rescale_cycle(
                ckpt_dir, int(step), tree, axes, build_rules(cfg),
                plan.workers, prefer_model=args.model_mesh,
                meta={"reason": plan.reason})
            params, state = tree["params"], tree["opt"]
            step = jnp.asarray(int(step))   # uncommit from the old mesh
            workers = plan.workers
            print(f"elastic {plan.action} -> {workers} workers at step "
                  f"{int(step)} ({plan.reason}); resumed from checkpoint "
                  f"cycle on a {tuple(mesh.devices.shape)} mesh")
    if feeder is not None:
        feeder.stop()
    saver.wait()
    dt = time.perf_counter() - t0
    toks = (args.steps - start) * args.batch * args.seq
    print(f"done: {toks/dt:.0f} tok/s; checkpoints at {ckpt_dir} "
          f"(latest {ckpt.latest_step(ckpt_dir)}, "
          f"rescales={controller.rescales if controller else 0})")


if __name__ == "__main__":
    main()
