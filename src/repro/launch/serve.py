"""Serving launcher: batched prefill+decode over any assigned architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 4 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--batch-size", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    import numpy as np

    from repro.configs import get_config
    from repro.models import model_zoo as zoo
    from repro.serve.engine import Request, ServeEngine
    from repro.serve.sampling import SamplingParams

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"arch={cfg.name} params={zoo.param_count(cfg)/1e6:.1f}M")
    params = zoo.init_params(cfg, 0)
    eng = ServeEngine(cfg, params, batch_size=args.batch_size,
                      max_len=args.max_len,
                      sampling=SamplingParams(greedy=args.greedy))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len)
                    .astype(np.int32), max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    done = eng.run(reqs)
    dt = time.perf_counter() - t0
    for r in done[:3]:
        print(f"req {r.rid}: out={r.out_tokens[:8]}...")
    print(f"throughput: {eng.throughput()} wall={dt:.1f}s")


if __name__ == "__main__":
    main()
