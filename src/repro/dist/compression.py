"""Edge-uplink gradient compression (symmetric int8 + top-k).

Edge workers in the S2CE deployment sync gradients to the cloud over
constrained links; symmetric per-tensor int8 cuts uplink bytes 4x
versus fp32 with a per-element error bounded by ``scale/2`` (the
property suite checks this bound). ``compressed_allreduce_mean`` is
the collective form: each participant quantizes its local tensor,
the mean runs over the *dequantized* values, and a scalar error
estimate rides along for monitoring. ``ef_quantize``/``ef_roundtrip``
add error feedback (residual carry): quantization error is folded into
the next round's payload instead of being lost, so the accumulated
error over a stream of updates stays bounded by one quantum.

``topk_sparsify`` is the orthogonal axis: ship only the ``k``
largest-magnitude coordinates (``8k`` wire bytes instead of ``4d``),
and ``ef_topk``/``ef_topk_roundtrip`` carry the dropped mass forward
as a residual so every coordinate is eventually transmitted — the
classic deep-gradient-compression memory. The two schemes compose:
sparsify first, then quantize the surviving values.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

_QMAX = 127.0


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8: returns (q int8, scale fp32) with
    ``x ~= q * scale`` and elementwise error <= scale/2."""
    x = jnp.asarray(x)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, jnp.asarray(1e-30, jnp.float32)) / _QMAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -_QMAX, _QMAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_roundtrip(x: jax.Array) -> jax.Array:
    """Quantize-dequantize in one step (what the wire does to a tensor)."""
    return dequantize_int8(*quantize_int8(x)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Error feedback (residual carry)
# ---------------------------------------------------------------------------

def ef_init(x: jax.Array) -> jax.Array:
    """Zero residual matching ``x`` (always fp32: the carry must not lose
    precision to the payload dtype)."""
    return jnp.zeros(jnp.shape(x), jnp.float32)


def ef_quantize(residual: jax.Array, x: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 compression step (1-bit/QSGD-style memory).

    The carried residual from previous rounds is folded into the tensor
    *before* quantizing, and the fresh quantization error is carried
    forward: ``(q, scale, new_residual)``. Round-to-nearest bias that a
    plain quantizer accumulates linearly over steps stays bounded by one
    quantum — the property the test suite checks over 50 steps.
    """
    xc = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(xc)
    return q, scale, xc - dequantize_int8(q, scale)


def ef_roundtrip(residual: jax.Array, x: jax.Array, *,
                 use_kernel: Optional[bool] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Wire round-trip with residual carry: ``(decoded, new_residual)``.

    Where Pallas runs, the whole fold-amax-quantize-dequantize-carry
    chain is ONE fused kernel (``kernels.ef_codec``); elsewhere the jnp
    composition below. Paths agree to <=1 ulp and both satisfy the exact
    EF identity ``decoded + new_residual == x + residual``."""
    if use_kernel is None:
        use_kernel = kops.pallas_available()
    if use_kernel and kops.pallas_available():
        return kops.ef_int8_roundtrip(residual, x)
    q, scale, residual = ef_quantize(residual, x)
    return dequantize_int8(q, scale).astype(x.dtype), residual


# ---------------------------------------------------------------------------
# Top-k sparsification (+ error feedback)
# ---------------------------------------------------------------------------

def topk_sparsify(x: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Keep the ``k`` largest-|.| coordinates of the flattened tensor.

    Returns ``(values fp32 (k,), indices int32 (k,))`` — the wire payload
    (``8k`` bytes vs ``4·size`` dense). ``k`` is clamped to the size."""
    flat = jnp.ravel(x).astype(jnp.float32)
    k = max(1, min(int(k), flat.shape[0]))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    idx = idx.astype(jnp.int32)
    return flat[idx], idx


def topk_densify(values: jax.Array, indices: jax.Array,
                 shape: Tuple[int, ...]) -> jax.Array:
    """Scatter the sparse payload back to a dense fp32 tensor."""
    size = 1
    for s in shape:
        size *= int(s)
    dense = jnp.zeros((size,), jnp.float32).at[indices].set(values)
    return dense.reshape(shape)


def topk_roundtrip(x: jax.Array, k: int) -> jax.Array:
    """Sparsify-densify in one step (what the wire does to a tensor)."""
    v, i = topk_sparsify(x, k)
    return topk_densify(v, i, jnp.shape(x)).astype(x.dtype)


def ef_topk(residual: jax.Array, x: jax.Array, k: int
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback top-k sparsification step (DGC-style memory).

    The carried residual is folded into the tensor *before* selection,
    and the dropped ``d-k`` coordinates are carried forward:
    ``(values, indices, new_residual)``. Plain top-k silently drops the
    same small coordinates every round (error grows linearly); with the
    carry, dropped mass accumulates until it wins selection, so the
    cumulative decoded stream tracks the cumulative true stream to
    within one residual (the telescoping identity the tests check).
    """
    xc = x.astype(jnp.float32) + residual
    v, i = topk_sparsify(xc, k)
    return v, i, xc - topk_densify(v, i, jnp.shape(xc))


def ef_topk_roundtrip(residual: jax.Array, x: jax.Array, k: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """Wire round-trip with residual carry: ``(decoded, new_residual)``."""
    v, i, residual = ef_topk(residual, x, k)
    return topk_densify(v, i, jnp.shape(x)).astype(x.dtype), residual


def ef_topk_int8_roundtrip(residual: jax.Array, x: jax.Array, k: int, *,
                           use_kernel: Optional[bool] = None
                           ) -> Tuple[jax.Array, jax.Array]:
    """Composed top-k + int8 wire round-trip with ONE shared residual —
    the uplink codec hot path (sparsify first, then quantize survivors).

    Where Pallas runs this is a single fused kernel pass (selection by
    the k-th-largest-magnitude threshold; identical to exact top-k for
    tie-free inputs); elsewhere the jnp oracle. The EF telescoping
    identity holds on both paths for any selection."""
    from repro.kernels import ref as _kref
    if use_kernel is None:
        use_kernel = kops.pallas_available()
    if use_kernel and kops.pallas_available():
        return kops.ef_topk_int8_roundtrip(residual, x, k=int(k))
    return _kref.ef_topk_int8_roundtrip_ref(residual, x, k)


def compressed_allreduce_mean(
        x: jax.Array, axis_name: Optional[str] = None
) -> Tuple[jax.Array, jax.Array]:
    """Mean of int8-compressed per-worker tensors.

    Inside a ``shard_map``/``pmap`` collective context, pass the mapped
    ``axis_name``: the local tensor is quantized, and ``lax.pmean`` of
    the dequantized values crosses the wire-equivalent path. Without
    ``axis_name``, the leading dim of ``x`` is treated as the worker
    dim (host-side simulation of the uplink).

    Returns ``(mean, err)`` where ``err`` is the mean per-worker max
    quantization error — finite by construction, useful as an SLA
    telemetry signal.
    """
    if axis_name is not None:
        deq = int8_roundtrip(x.astype(jnp.float32))
        err = jnp.max(jnp.abs(deq - x.astype(jnp.float32)))
        return (jax.lax.pmean(deq, axis_name),
                jax.lax.pmean(err, axis_name))
    deq = jax.vmap(lambda w: int8_roundtrip(w.astype(jnp.float32)))(x)
    err = jnp.mean(jnp.max(jnp.abs(deq - x.astype(jnp.float32)),
                           axis=tuple(range(1, x.ndim))))
    return jnp.mean(deq, axis=0), err
