"""Elastic worker management: add/remove-worker resharding decisions.

Two layers:

  * mechanism — :func:`rebuild_mesh` carves a new (data, model) mesh out
    of the surviving devices after failures/scale events, and
    :func:`reshard_tree` moves a checkpoint/parameter tree onto it
    (values preserved; layout re-derived from the logical rules).
  * policy — :class:`ElasticController` watches offered vs. achieved
    stream rate (the elasticity loop of arXiv:1709.01363) and emits
    :class:`ScalePlan` grow/shrink/hold decisions with hysteresis; the
    orchestrator logs these next to its offload decisions.

Data-parallel worker counts stay powers of two so global batches keep
dividing evenly (see api.logical_to_spec's divisibility contract).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Mechanism: mesh rebuild + tree resharding
# ---------------------------------------------------------------------------

def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def factor_mesh(n_devices: int, prefer_model: int = 1):
    """(data, model) shape for ``n_devices``: honour ``prefer_model``
    (halving until it fits) and keep data a power of two."""
    model = max(1, int(prefer_model))
    while model > n_devices:
        model //= 2
    data = _pow2_floor(max(1, n_devices // model))
    return data, model


def rebuild_mesh(devices: Sequence, failed: Sequence = (),
                 prefer_model: int = 1):
    """New ("data","model") mesh over the devices that survived.

    ``failed`` entries may be device ids (ints) or device objects.
    """
    import jax

    failed_ids = {getattr(f, "id", f) for f in failed}
    alive = [d for d in devices if d.id not in failed_ids]
    if not alive:
        raise RuntimeError("no surviving devices to rebuild a mesh from")
    data, model = factor_mesh(len(alive), prefer_model)
    n = data * model
    grid = np.array(alive[:n], dtype=object).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))


def reshard_tree(tree, axes_tree, rules: dict, mesh):
    """Re-place a tree onto ``mesh`` per its logical axes (values kept).

    Layouts are re-derived through ``rules["param"]`` with the usual
    divisibility fallback, so a tree sharded for an 8-way mesh restores
    cleanly onto a degraded 4-way one.
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.dist.api import logical_to_spec

    def leaf(x, ax):
        spec = logical_to_spec(ax, rules.get("param", {}), mesh,
                               np.shape(x))
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(leaf, tree, axes_tree)


def replicated_axes(tree):
    """Logical-axes tree marking every dim of every leaf unsharded — the
    ``axes_tree`` to pass :func:`reshard_tree`/:func:`rescale_cycle` for
    state with no sharding recipe (e.g. optimizer accumulators)."""
    import jax

    return jax.tree.map(lambda x: tuple(None for _ in np.shape(x)), tree)


def rescale_cycle(directory, step: int, tree, axes_tree, rules: dict,
                  new_workers: int, *, prefer_model: int = 1,
                  meta: Optional[dict] = None, keep: Optional[int] = None):
    """Drive a :class:`ScalePlan` through the real state-carrying
    machinery: ``checkpoint.save -> rebuild_mesh -> reshard_tree`` and
    hand back the tree resident on the new mesh, ready to resume.

    This is the runtime mechanism behind elastic grow/shrink — the same
    cycle a failure recovery takes, so a rescale that is not an even
    re-partition of the old layout (``plan.needs_checkpoint_cycle``)
    still round-trips safely. ``keep`` bounds the published step dirs
    (checkpoint GC) so repeated rescales don't grow the directory
    unboundedly. Returns ``(tree_on_new_mesh, mesh)``.
    """
    import jax

    from repro.dist import checkpoint as ckpt

    ckpt.save(directory, int(step), tree, keep=keep,
              meta={"workers": int(new_workers), **(meta or {})})
    restored, _ = ckpt.restore(directory, tree, step=int(step))
    devices = jax.devices()
    n = max(1, min(len(devices), int(new_workers) * int(prefer_model)))
    mesh = rebuild_mesh(devices[:n], prefer_model=prefer_model)
    return reshard_tree(restored, axes_tree, rules, mesh), mesh


# ---------------------------------------------------------------------------
# Policy: scale decisions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ScalePlan:
    action: str              # "hold" | "grow" | "shrink" | "recover"
    workers: int             # target data-parallel worker count
    reason: str
    # a grow/shrink that is not an even re-partition of the old layout
    # must round through a checkpoint (save -> rebuild mesh -> restore)
    needs_checkpoint_cycle: bool = False

    @property
    def changed(self) -> bool:
        return self.action != "hold"


def plan_reshard(old_workers: int, new_workers: int, *,
                 reason: str = "manual") -> ScalePlan:
    """Resharding plan for an explicit worker-count change."""
    if new_workers == old_workers:
        return ScalePlan("hold", old_workers, reason)
    action = "grow" if new_workers > old_workers else "shrink"
    even = (max(old_workers, new_workers) % min(old_workers, new_workers) == 0)
    return ScalePlan(action, new_workers, reason,
                     needs_checkpoint_cycle=not even)


class ElasticController:
    """Hysteresis-guarded worker scaling from rate telemetry.

    ``observe(step, offered, achieved)`` compares the offered stream
    rate against pool capacity, where ``achieved`` is the measured
    *per-worker* throughput (pool capacity = achieved x workers); the
    orchestrator passes its single-pipeline rate. Sustained overload
    (utilization > ``high``) doubles workers; sustained slack
    (utilization < ``low``) halves them. ``patience`` consecutive
    breaches are required before acting, and ``cooldown`` steps must
    pass between actions, so transient bursts don't thrash the mesh.
    """

    def __init__(self, workers: int = 1, *, min_workers: int = 1,
                 max_workers: int = 64, high: float = 1.0, low: float = 0.35,
                 patience: int = 3, cooldown: int = 10):
        self.workers = int(workers)
        self.min_workers = min_workers
        self.max_workers = max_workers
        self.high = high
        self.low = low
        self.patience = patience
        self.cooldown = cooldown
        self._over = 0
        self._under = 0
        self._last_action_step: Optional[int] = None
        self.rescales = 0

    def observe(self, step: int, offered: float, achieved: float) -> ScalePlan:
        # utilization: how much of the pool's throughput the stream needs
        util = offered / max(achieved * self.workers, 1e-9)
        self._over = self._over + 1 if util > self.high else 0
        self._under = self._under + 1 if util < self.low else 0
        in_cooldown = (self._last_action_step is not None and
                       step - self._last_action_step < self.cooldown)
        if in_cooldown:
            return ScalePlan("hold", self.workers, "cooldown")
        if self._over >= self.patience and self.workers < self.max_workers:
            return self._act(step, min(self.workers * 2, self.max_workers),
                             f"overload util={util:.2f}")
        if self._under >= self.patience and self.workers > self.min_workers:
            return self._act(step, max(self.workers // 2, self.min_workers),
                             f"slack util={util:.2f}")
        return ScalePlan("hold", self.workers, "steady")

    def _act(self, step: int, new_workers: int, reason: str) -> ScalePlan:
        plan = plan_reshard(self.workers, new_workers, reason=reason)
        self.workers = new_workers
        self._over = self._under = 0
        self._last_action_step = step
        self.rescales += 1
        return plan

    def involuntary(self, step: int, reason: str,
                    workers: Optional[int] = None) -> ScalePlan:
        """An involuntary rescale — pool loss / failure recovery. The
        trigger is a topology FACT, not a rate sample, so it bypasses
        the patience/cooldown hysteresis entirely and always rounds
        through the checkpoint cycle (the surviving mesh layout is not
        an even re-partition of one that included the dead pool's
        share). Resets the rate streaks and starts the cooldown clock,
        so the next voluntary action still waits out hysteresis."""
        new = self.workers if workers is None else \
            max(self.min_workers, min(int(workers), self.max_workers))
        plan = ScalePlan("recover", new, reason,
                         needs_checkpoint_cycle=True)
        self.workers = new
        self._over = self._under = 0
        self._last_action_step = step
        self.rescales += 1
        return plan
