"""Recipe -> logical->mesh sharding rules.

A *recipe* (``cfg.recipe``) names a parallelism strategy; ``build_rules``
expands it into two rule tables consumed by :func:`repro.dist.api
.logical_to_spec`:

  * ``rules["param"]`` — how parameter Spec axes map onto the mesh
    (FSDP shards fan-in ``embed`` over ``data``; TP shards ``heads`` /
    ``ff`` / ``vocab`` over ``model``; EP shards ``experts`` over
    ``model``).
  * ``rules["act"]``  — how activation dims map (``batch`` over the
    data axes, TP-parallel dims over ``model``, MoE token groups over
    ``expert_groups`` -> data).

Rules reference the *union* mesh axes (``pod``, ``data``, ``model``);
axes absent from the actual mesh are dropped at spec time, so the same
rules drive the 512-chip multipod dry-run and a 2x4 test mesh.

Recipes: ``dp`` (replicated params), ``fsdp``, ``tp_fsdp``,
``ep_fsdp``, ``ep_tp_fsdp``.
"""

from __future__ import annotations

from typing import Optional

from jax.sharding import NamedSharding

from repro.dist.api import logical_to_spec

# ordered data-parallel axes: multipod meshes put "pod" outermost
_DATA = ("pod", "data")
_MODEL = ("model",)

_RECIPES = ("dp", "fsdp", "tp_fsdp", "ep_fsdp", "ep_tp_fsdp")


def build_rules(cfg=None, *, shape=None, recipe: Optional[str] = None) -> dict:
    """Build ``{"param": {...}, "act": {...}}`` for an arch config.

    ``recipe`` overrides ``cfg.recipe``; ``shape`` (an InputShape) lets
    decode cells drop sequence-parallel pins on their length-1 query dim.
    """
    name = recipe or (getattr(cfg, "recipe", None) or "dp")
    if name not in _RECIPES:
        raise ValueError(f"unknown recipe {name!r}; expected one of {_RECIPES}")
    tp = name in ("tp_fsdp", "ep_tp_fsdp")
    ep = name.startswith("ep")
    fsdp = name != "dp"

    param = {}
    if fsdp:
        param["embed"] = ("data",)
    if ep:
        param["experts"] = _MODEL
    if tp:
        param.update({
            "heads": _MODEL, "kv_heads": _MODEL, "ff": _MODEL,
            "vocab": _MODEL, "dinner": _MODEL,
        })
        if not ep:
            param["experts"] = _MODEL

    act = {"batch": _DATA, "expert_groups": _DATA}
    if ep:
        act["experts"] = _MODEL
    if tp:
        act.update({
            "heads": _MODEL, "kv_heads": _MODEL, "ff": _MODEL,
            "vocab": _MODEL, "dinner": _MODEL,
        })
        seq_shard = getattr(cfg, "seq_shard", False)
        if seq_shard and not (shape is not None and
                              getattr(shape, "is_decode", False)):
            act["seq_sp"] = _MODEL
    return {"recipe": name, "param": dict(param), "act": dict(act)}


def param_sharding_tree(axes_or_cfg, mesh, rules, shapes=None):
    """NamedSharding tree for a parameter tree.

    ``axes_or_cfg`` is either a logical-axes tree (as from
    ``models.params.axes_of``) or an ArchConfig (resolved lazily through
    model_zoo to avoid an import cycle). When ``shapes`` (a matching
    ShapeDtypeStruct tree) is given, divisibility is enforced per leaf;
    otherwise rules apply unconditionally.
    """
    import jax

    axes = axes_or_cfg
    if hasattr(axes_or_cfg, "recipe"):  # an ArchConfig
        from repro.models import model_zoo as zoo
        axes = zoo.param_axes(axes_or_cfg)
        if shapes is None:
            shapes = zoo.param_shapes(axes_or_cfg)

    if shapes is None:
        return jax.tree.map(
            lambda ax: NamedSharding(
                mesh, logical_to_spec(ax, rules["param"], mesh)),
            axes, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(
        lambda s, ax: NamedSharding(
            mesh, logical_to_spec(ax, rules["param"], mesh, s.shape)),
        shapes, axes)
