"""Logical-axis -> PartitionSpec mapping.

The single place where "logical" tensor dimension names (``batch``,
``heads``, ``ff``, ...) meet "physical" mesh axis names (``pod``,
``data``, ``model``). The invariant this module guarantees — and that
``tests/test_property.py`` property-checks — is *safe degradation*: a
logical dim is only mapped onto mesh axes whose total size divides the
dim exactly; anything else stays replicated. Rules can therefore be
written once for the production mesh and reused unchanged on a laptop,
a reduced smoke config, or a degraded post-failure mesh.
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple

from jax.sharding import PartitionSpec


def _as_tuple(rule) -> Tuple[str, ...]:
    """Normalize a rule value (str | None | sequence of str) to a tuple."""
    if rule is None:
        return ()
    if isinstance(rule, str):
        return (rule,)
    return tuple(rule)


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    rules: Mapping[str, object],
                    mesh,
                    shape: Optional[Sequence[int]] = None) -> PartitionSpec:
    """Map per-dim logical names to a PartitionSpec on ``mesh``.

    For each dim, the rule's mesh axes are taken as an ordered candidate
    list and greedily accumulated: an axis is used when it exists in the
    mesh, is not already consumed by an earlier dim, and (when ``shape``
    is given) keeps the accumulated size-product dividing the dim; other
    candidates are skipped. Dims with no rule, no usable candidate, or
    ``None`` stay unsharded.

    ``mesh`` only needs ``.shape`` (name -> size mapping) and
    ``.axis_names`` — a real ``jax.sharding.Mesh`` or any stand-in works.
    """
    sizes = dict(mesh.shape)
    used: set = set()
    parts = []
    for i, name in enumerate(logical_axes):
        if name is None or name not in rules:
            parts.append(None)
            continue
        chosen = []
        prod = 1
        dim = None if shape is None else int(shape[i])
        for ax in _as_tuple(rules[name]):
            if ax not in sizes or ax in used:
                continue
            if dim is not None and dim % (prod * sizes[ax]) != 0:
                continue
            chosen.append(ax)
            prod *= sizes[ax]
        used.update(chosen)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return PartitionSpec(*parts)


def spec_is_replicated(spec: PartitionSpec) -> bool:
    """True when a spec places nothing on any mesh axis."""
    return all(p is None for p in spec)
