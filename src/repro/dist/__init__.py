"""repro.dist — the distributed-execution subsystem.

S2CE's hybrid cloud/edge promise needs one substrate that the models,
the train step, the launchers, and the orchestrator all share. This
package is that substrate; everything else in the repo talks to it
through a handful of names:

  * :func:`use_mesh`     — context manager activating a (mesh, rules)
    pair. Accepts a ``jax.sharding.Mesh``, a ``{axis: size}`` dict, or
    ``None`` (degrades to a single-device mesh — CPU laptops work).
  * :func:`shard` / :func:`shard_param` — ``with_sharding_constraint``
    wrappers keyed by *logical* axis names; strict no-ops outside a
    mesh, and per-dim divisibility-guarded inside one.
  * :func:`pin_params`   — tree-level :func:`shard_param` (the train
    step pins stacked weights so GSPMD cannot hoist whole-stack
    all-gathers out of scan loops).
  * :func:`axis_size`    — resolved size of a logical axis (1 when
    unmapped / no mesh); drives KV-head TP duplication and MoE token
    grouping.
  * submodules: :mod:`api` (logical->PartitionSpec), :mod:`sharding`
    (recipe->rules), :mod:`checkpoint` (step-dir save/restore + async),
    :mod:`compression` (int8 edge-uplink gradient compression),
    :mod:`elastic` (worker add/remove resharding decisions).

Logical-axis naming conventions (used across ``models/transformer.py``,
``models/moe.py``, ``models/ssm.py``, ``models/rwkv.py``):

  ============== =====================================================
  name           meaning
  ============== =====================================================
  batch          global example dim (data parallel: pod x data)
  seq_sp         sequence dim in reduce-scattered residual form
  kv_seq         key/value sequence dim (never sharded today)
  embed          model/residual feature dim (params: FSDP over data)
  heads / kv_heads  attention head dims (TP over model)
  ff             MLP hidden dim (TP over model)
  dinner         SSM/RWKV inner feature dim (TP over model)
  vocab          softmax/vocab dim (TP over model)
  experts        expert weight dim (expert parallel over model)
  expert_groups  MoE token-group dim G (mirrors data sharding)
  layers         scanned layer stack dim (always replicated)
  head_dim/lora  per-head / low-rank dims (always replicated)
  ============== =====================================================
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.dist import checkpoint  # noqa: F401  (re-export submodule)
from repro.dist.api import logical_to_spec, spec_is_replicated

__all__ = [
    "use_mesh", "current_mesh", "current_rules", "mesh_active",
    "shard", "shard_param", "pin_params", "axis_size", "checkpoint",
]


@dataclass(frozen=True)
class _MeshContext:
    mesh: Mesh
    rules: dict


class _State(threading.local):
    def __init__(self):
        self.stack = []


_STATE = _State()


def _current() -> Optional[_MeshContext]:
    return _STATE.stack[-1] if _STATE.stack else None


def current_mesh() -> Optional[Mesh]:
    ctx = _current()
    return ctx.mesh if ctx else None


def current_rules() -> Optional[dict]:
    ctx = _current()
    return ctx.rules if ctx else None


def mesh_active() -> bool:
    return _current() is not None


def _coerce_mesh(mesh) -> Mesh:
    if mesh is None:
        mesh = {"data": 1, "model": 1}
    if isinstance(mesh, dict):
        names = tuple(mesh)
        shape = tuple(int(v) for v in mesh.values())
        n = 1
        for s in shape:
            n *= s
        devs = jax.devices()
        if len(devs) < n:
            raise ValueError(
                f"mesh {dict(zip(names, shape))} needs {n} devices, "
                f"have {len(devs)}")
        return jax.make_mesh(shape, names, devices=devs[:n])
    return mesh


@contextlib.contextmanager
def use_mesh(mesh=None, rules: Optional[dict] = None):
    """Activate (mesh, rules) for the enclosed block.

    ``mesh``: a Mesh, an ``{axis: size}`` dict (built over local devices),
    or None (single-device degenerate mesh). ``rules``: as produced by
    :func:`repro.dist.sharding.build_rules`; defaults to empty rules,
    i.e. everything replicated.
    """
    ctx = _MeshContext(_coerce_mesh(mesh),
                       rules if rules is not None else {"param": {}, "act": {}})
    _STATE.stack.append(ctx)
    try:
        yield ctx.mesh
    finally:
        _STATE.stack.pop()


def _constrain(x, logical_axes, table_key: str):
    ctx = _current()
    if ctx is None or not hasattr(x, "ndim"):
        return x
    if len(logical_axes) != x.ndim:
        return x
    rules = ctx.rules.get(table_key, {})
    if not rules:
        return x
    spec = logical_to_spec(logical_axes, rules, ctx.mesh, x.shape)
    if spec_is_replicated(spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def shard(x, *logical_axes):
    """Constrain an activation to its logical layout (no-op outside a
    mesh, or when a dim does not divide by its mesh axes)."""
    return _constrain(x, logical_axes, "act")


def shard_param(x, logical_axes):
    """Constrain a parameter (or grad) leaf to its param-rule layout."""
    return _constrain(x, tuple(logical_axes), "param")


def pin_params(tree, axes_tree):
    """Apply :func:`shard_param` across a tree; leaves whose rank does
    not match their axes entry (e.g. non-array aux state) pass through."""
    if _current() is None:
        return tree
    return jax.tree.map(
        lambda x, ax: shard_param(x, ax)
        if hasattr(x, "ndim") and x.ndim == len(ax) else x,
        tree, axes_tree)


def axis_size(name: str) -> int:
    """Resolved size of logical axis ``name`` under the active mesh.

    Returns 1 with no active mesh, for unmapped names, and for mesh
    axes absent from the current mesh. ``name`` may also be a physical
    mesh axis name.
    """
    ctx = _current()
    if ctx is None:
        return 1
    sizes = dict(ctx.mesh.shape)
    if name in sizes:
        return int(sizes[name])
    rule = ctx.rules.get("act", {}).get(name)
    if rule is None:
        rule = ctx.rules.get("param", {}).get(name)
    if rule is None:
        return 1
    if isinstance(rule, str):
        rule = (rule,)
    n = 1
    for ax in rule:
        n *= int(sizes.get(ax, 1))
    return n
