"""Step-numbered checkpointing with async publish.

Layout: ``<dir>/step_<010d>/{arrays.npz, manifest.json}``. Writes are
atomic (tmp dir + ``os.replace``) so a reader never sees a partial
checkpoint and ``latest_step`` only reports fully-published steps.
Restore is *structure-checked*: the target tree must have exactly the
saved leaves (a mismatch raises ``ValueError`` naming the keys) and
each leaf is cast to the target leaf's dtype, so a bf16 serving tree
can restore an fp32 training checkpoint directly.

``AsyncCheckpointer`` snapshots device arrays on the caller thread
(cheap device_get) and performs serialization + disk I/O on a single
background thread; ``wait()`` drains the queue and re-raises any
writer-side failure.
"""

from __future__ import annotations

import json
import os
import pathlib
import queue
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_STEP_PREFIX = "step_"
_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _step_dir(directory, step: int) -> pathlib.Path:
    return pathlib.Path(directory) / f"{_STEP_PREFIX}{int(step):010d}"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in leaves], treedef


def _to_numpy(leaf) -> np.ndarray:
    a = np.asarray(leaf)
    # np.savez cannot serialize extension dtypes (bfloat16, fp8);
    # widen to float32 — restore casts back to the target dtype anyway
    if a.dtype.kind not in "biufc":
        a = a.astype(np.float32)
    return a


def save(directory, step: int, tree, *, meta: Optional[dict] = None,
         keep: Optional[int] = None) -> pathlib.Path:
    """Write ``tree`` as checkpoint ``step``; optionally GC old steps."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = directory / f".tmp_{final.name}_{os.getpid()}_{threading.get_ident()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    try:
        flat, _ = _flatten(tree)
        arrays = {k: _to_numpy(v) for k, v in flat}
        with open(tmp / _ARRAYS, "wb") as f:
            np.savez(f, **arrays)
        manifest = {"step": int(step), "meta": meta or {},
                    "keys": sorted(arrays)}
        (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    if keep is not None:
        _gc(directory, keep)
    return final


def _published_steps(directory) -> list:
    directory = pathlib.Path(directory)
    if not directory.is_dir():
        return []
    out = []
    for p in directory.iterdir():
        if p.name.startswith(_STEP_PREFIX) and (p / _MANIFEST).exists():
            try:
                out.append(int(p.name[len(_STEP_PREFIX):]))
            except ValueError:
                continue
    return sorted(out)


def _gc(directory, keep: int):
    steps = _published_steps(directory)
    for s in steps[:-keep] if keep > 0 else steps:
        shutil.rmtree(_step_dir(directory, s), ignore_errors=True)


def latest_step(directory) -> Optional[int]:
    steps = _published_steps(directory)
    return steps[-1] if steps else None


def restore(directory, like, step: Optional[int] = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like``; returns (tree, meta).

    ``meta`` is ``{"step": int, "meta": {...saved metadata...}}``. The
    saved leaf set must match ``like`` exactly; extra or missing leaves
    raise ``ValueError`` naming the offending keys. Each restored leaf
    is cast to the corresponding ``like`` leaf's dtype.
    """
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = _step_dir(directory, step)
    manifest = json.loads((d / _MANIFEST).read_text())
    flat, treedef = _flatten(like)
    want = [k for k, _ in flat]
    have = set(manifest["keys"])
    missing = sorted(set(want) - have)   # in `like` but not in checkpoint
    extra = sorted(have - set(want))     # in checkpoint but not in `like`
    if missing or extra:
        raise ValueError(
            f"checkpoint structure mismatch at step {step}: "
            f"target leaves not in checkpoint: {missing or 'none'}; "
            f"checkpoint leaves not in target: {extra or 'none'}")
    leaves = []
    with np.load(d / _ARRAYS) as z:
        for k, ref in flat:
            arr = jnp.asarray(z[k])
            dt = getattr(ref, "dtype", None)
            leaves.append(arr.astype(dt) if dt is not None else arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, {"step": manifest["step"], "meta": manifest["meta"]}


class AsyncCheckpointer:
    """Background-thread checkpoint writer.

    ``save`` returns as soon as the tree is snapshotted to host memory;
    serialization and disk I/O happen on the worker. ``wait`` blocks
    until all submitted saves are on disk and re-raises the first
    writer error, if any.
    """

    def __init__(self, directory, *, keep: Optional[int] = None):
        self.directory = pathlib.Path(directory)
        self.keep = keep
        self._q: "queue.Queue" = queue.Queue()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(
            target=self._worker, name="ckpt-writer", daemon=True)
        self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree, meta = item
                save(self.directory, step, tree, meta=meta, keep=self.keep)
            except BaseException as e:  # surfaced on wait()
                if self._error is None:
                    self._error = e
            finally:
                self._q.task_done()

    def save(self, step: int, tree, *, meta: Optional[dict] = None):
        if not self._thread.is_alive():
            raise RuntimeError("AsyncCheckpointer is closed")
        snapshot = jax.device_get(tree)
        self._q.put((int(step), snapshot, meta))

    def wait(self):
        self._q.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def close(self):
        if self._thread.is_alive():
            self._q.put(None)
            self._thread.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
