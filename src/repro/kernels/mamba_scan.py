"""Mamba selective scan as a chunked Pallas TPU kernel.

Grid: (B, d_inner blocks, chunks) with chunks innermost-sequential; the
running state h (bd, N) persists in VMEM scratch. Within a chunk the scan
is evaluated by a cumulative-product formulation entirely in VMEM:

    h_t = a_t h_{t-1} + b_t,  a_t = exp(dt_t * A)

Per-chunk working set at Lc=128, bd=256, N=16: a/b tiles (Lc,bd,N) f32
~= 4 MB — VMEM-sized by construction (that's the reason for chunking: the
(B,S,dI,N) tensor of the naive parallel scan would be HBM-resident).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(dt_ref, x_ref, B_ref, C_ref, A_ref, h0_ref, y_ref,
                  hout_ref, h_scr, *, chunks: int, chunk: int, bd: int,
                  n: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    dt = dt_ref[0].astype(jnp.float32)        # (Lc, bd)
    x = x_ref[0].astype(jnp.float32)          # (Lc, bd)
    Bm = B_ref[0].astype(jnp.float32)         # (Lc, N)
    Cm = C_ref[0].astype(jnp.float32)         # (Lc, N)
    A = A_ref[0].astype(jnp.float32)          # (bd, N)

    a = jnp.exp(dt[:, :, None] * A[None])     # (Lc, bd, N)
    b = (dt * x)[:, :, None] * Bm[:, None, :]

    # in-chunk associative scan via cumulative log-products:
    # h_t = P_t * (h_0 + sum_{s<=t} b_s / P_s), P_t = prod_{s<=t} a_s.
    # Stable form: logP is a cumsum of negatives; b_s/P_s can overflow, so
    # use the scan-free two-pass with renormalization by P_t directly:
    logP = jnp.cumsum(dt[:, :, None] * A[None], axis=0)   # (Lc,bd,N) <= 0
    P = jnp.exp(logP)
    # sum_{s<=t} b_s * exp(logP_t - logP_s)  — pairwise would be (Lc,Lc,..);
    # instead do a short sequential fori over the chunk (VMEM-resident).
    h = h_scr[...]

    def step(t, carry):
        h_c, y_acc = carry
        h_c = a[t] * h_c + b[t]
        y_t = jnp.sum(h_c * Cm[t][None, :], axis=-1)      # (bd,)
        y_acc = jax.lax.dynamic_update_index_in_dim(y_acc, y_t, t, 0)
        return h_c, y_acc

    y0 = jnp.zeros((chunk, bd), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h, y0))
    del P, logP
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = h

    @pl.when(ci == chunks - 1)
    def _final():
        hout_ref[0] = h


def mamba_scan_bd(dt, x, Bm, Cm, A, h0, *, chunk: int = 128, bd: int = 256,
                  interpret: bool = False):
    """dt,x: (B, S, dI); Bm,Cm: (B, S, N); A: (dI, N); h0: (B, dI, N) fp32.
    Returns (y (B,S,dI) fp32, h_last (B,dI,N) fp32)."""
    B, S, dI = dt.shape
    N = Bm.shape[-1]
    bd = min(bd, dI)
    assert dI % bd == 0, (dI, bd)
    chunk = min(chunk, S)
    Sp = -(-S // chunk) * chunk
    if Sp != S:
        dt = jnp.pad(dt, ((0, 0), (0, Sp - S), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, Sp - S), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, Sp - S), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, Sp - S), (0, 0)))
    chunks = Sp // chunk
    kernel = functools.partial(_mamba_kernel, chunks=chunks, chunk=chunk,
                               bd=bd, n=N)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, dI // bd, chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, bd, N), lambda b, d, c: (0, d, 0)),
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, dI), jnp.float32),
            jax.ShapeDtypeStruct((B, dI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(dt, x, Bm, Cm, A[None], h0)
    return y[:, :S, :], h_last
