"""Fused error-feedback codec round-trip Pallas kernels (uplink hot path).

``dist/compression.py``'s wire round-trips are chains of small jnp
programs — fold residual, global amax, quantize, dequantize, subtract —
each materializing a tensor-sized intermediate in HBM. On the uplink
path the orchestrator runs one round-trip per crossing batch tensor per
step, so the traffic is all memory-bound. These kernels fuse each
round-trip into one ``pallas_call`` over the flattened tensor:

* :func:`ef_int8_roundtrip` — int8 error-feedback round-trip
  ``(residual, x) -> (decoded, residual')``. Two-phase grid: phase 0
  reduces the global amax of ``x + residual`` into VMEM scratch (max is
  an exact reduction, so the scale matches ``ef_roundtrip`` exactly);
  phase 1 quantizes, dequantizes, and emits the fresh residual per
  block. Outputs agree with ``dist.compression.ef_roundtrip`` to <=1 ulp
  (the scale division may fuse differently across the two programs);
  the EF identity ``decoded + residual' == x + residual`` is exact.

* :func:`ef_topk_int8_roundtrip` — the composed sparsify-then-quantize
  round-trip with ONE shared residual. Top-k selection is expressed as a
  magnitude threshold (the k-th largest ``|x + residual|``, found with
  ``jax.lax.top_k`` on the host side — selection is the one genuinely
  global, sort-shaped step); the kernel then fuses mask + survivor amax
  + quantize-dequantize + residual in one pass. For tie-free inputs this
  is bitwise the same selection as exact top-k, and the error-feedback
  telescoping identity ``decoded + residual' == x + residual`` holds for
  ANY selection, ties included.

Twins: ``ref.ef_int8_roundtrip_ref`` / ``ref.ef_topk_int8_roundtrip_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_QMAX = 127.0


def _blocked_1d(t: jax.Array, block: int):
    """Flatten + zero-pad to (blocks, block)."""
    flat = jnp.ravel(t).astype(jnp.float32)
    size = flat.shape[0]
    npad = -(-size // block) * block
    if npad != size:
        flat = jnp.pad(flat, (0, npad - size))
    return flat.reshape(npad // block, block), size


def _int8_kernel(x_ref, r_ref, dec_ref, rout_ref, amax_scr, scale_scr, *,
                 blocks: int):
    phase = pl.program_id(0)
    bi = pl.program_id(1)
    xc = x_ref[...] + r_ref[...]                          # (1, block)

    @pl.when(phase == 0)
    def _reduce():
        @pl.when(bi == 0)
        def _init():
            amax_scr[0, 0] = 0.0

        amax_scr[0, 0] = jnp.maximum(amax_scr[0, 0], jnp.max(jnp.abs(xc)))

        @pl.when(bi == blocks - 1)
        def _scale():
            scale_scr[0, 0] = jnp.maximum(amax_scr[0, 0], 1e-30) / _QMAX

    @pl.when(phase == 1)
    def _roundtrip():
        scale = scale_scr[0, 0]
        q = jnp.clip(jnp.round(xc / scale), -_QMAX, _QMAX)
        dec = q * scale
        dec_ref[...] = dec
        rout_ref[...] = xc - dec


def ef_int8_roundtrip(residual: jax.Array, x: jax.Array, *,
                      block: int = 2048, interpret: bool = False):
    """Fused int8 EF wire round-trip: ``(decoded, new_residual)``.

    Agrees with ``dist.compression.ef_roundtrip`` to <=1 ulp; the
    internal EF identity is exact."""
    xb, size = _blocked_1d(x, block)
    rb, _ = _blocked_1d(residual, block)
    blocks = xb.shape[0]
    kernel = functools.partial(_int8_kernel, blocks=blocks)
    dec, rout = pl.pallas_call(
        kernel,
        grid=(2, blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xb.shape, jnp.float32),
            jax.ShapeDtypeStruct(xb.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xb, rb)
    shape = jnp.shape(x)
    return (dec.reshape(-1)[:size].reshape(shape).astype(x.dtype),
            rout.reshape(-1)[:size].reshape(shape))


def _topk_int8_kernel(x_ref, r_ref, t_ref, dec_ref, rout_ref,
                      amax_scr, scale_scr, *, blocks: int):
    phase = pl.program_id(0)
    bi = pl.program_id(1)
    xc = x_ref[...] + r_ref[...]                          # (1, block)
    kept = jnp.abs(xc) >= t_ref[0, 0]

    @pl.when(phase == 0)
    def _reduce():
        @pl.when(bi == 0)
        def _init():
            amax_scr[0, 0] = 0.0

        amax_scr[0, 0] = jnp.maximum(
            amax_scr[0, 0], jnp.max(jnp.where(kept, jnp.abs(xc), 0.0)))

        @pl.when(bi == blocks - 1)
        def _scale():
            scale_scr[0, 0] = jnp.maximum(amax_scr[0, 0], 1e-30) / _QMAX

    @pl.when(phase == 1)
    def _roundtrip():
        scale = scale_scr[0, 0]
        q = jnp.clip(jnp.round(jnp.where(kept, xc, 0.0) / scale),
                     -_QMAX, _QMAX)
        dec = jnp.where(kept, q * scale, 0.0)
        dec_ref[...] = dec
        rout_ref[...] = xc - dec


def ef_topk_int8_roundtrip(residual: jax.Array, x: jax.Array, k: int, *,
                           block: int = 2048, interpret: bool = False):
    """Fused top-k + int8 EF wire round-trip with one shared residual.

    Keeps the coordinates of ``x + residual`` whose magnitude reaches the
    k-th largest, int8-quantizes the survivors against their own amax,
    and carries dropped mass AND quantization error forward:
    ``(decoded, new_residual)``."""
    xc = jnp.ravel(x).astype(jnp.float32) + jnp.ravel(residual)
    size = xc.shape[0]
    k = max(1, min(int(k), size))
    # the selection threshold — the one sort-shaped global step
    t = jax.lax.top_k(jnp.abs(xc), k)[0][-1]
    xb, _ = _blocked_1d(x, block)
    rb, _ = _blocked_1d(residual, block)
    blocks = xb.shape[0]
    kernel = functools.partial(_topk_int8_kernel, blocks=blocks)
    dec, rout = pl.pallas_call(
        kernel,
        grid=(2, blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
            pl.BlockSpec((1, 1), lambda p, b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
            pl.BlockSpec((1, block), lambda p, b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xb.shape, jnp.float32),
            jax.ShapeDtypeStruct(xb.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(xb, rb, t.reshape(1, 1))
    shape = jnp.shape(x)
    return (dec.reshape(-1)[:size].reshape(shape).astype(x.dtype),
            rout.reshape(-1)[:size].reshape(shape))
