"""Fused streaming-preprocess Pallas kernels (S2CE Transformations hot path).

The per-batch edge preprocessing path in ``streams/preprocess.py`` is
three separate host-dispatched jnp programs (impute, Welford update,
normalize), each materializing an (n, d) intermediate in HBM. These two
kernels fuse that path:

* :func:`fused_normalize` — impute (NaN -> prior running mean) + Welford
  merge of the batch statistics + normalize, in ONE ``pallas_call`` over
  the batch. A two-phase grid visits the row blocks twice: phase 0
  accumulates the batch's raw moments (sum, sum-of-squares) in VMEM
  scratch and merges them into the carried running state; phase 1
  re-reads each block and writes the normalized rows with the merged
  statistics. The imputed/centered intermediates never touch HBM.

* :func:`fused_hash_features` — signed feature hashing
  ``(ids, vals) -> dense (n, dim)``. TPU has no scatter-add, so each
  feature column scatters the VPU way: compare the hashed slots against
  a broadcasted column iota and accumulate ``val * sign`` where they
  match (the same one-hot trick as the count-min kernel).

Both are differential-tested against the jnp twins in ``kernels/ref.py``
(``tests/test_kernel_oracles.py``). Hashing is bitwise-identical (pure
int32 ops); normalization is tolerance-equal, not bitwise, because the
kernel accumulates raw moments while the jnp path subtracts the two-pass
batch mean first.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_HASH_P = 2_147_483_647
_HASH_C = 0x9E37


def _normalize_kernel(x_ref, n0_ref, mean0_ref, m20_ref,
                      y_ref, n1_ref, mean1_ref, m21_ref,
                      s1_scr, s2_scr, stat_scr, *,
                      blocks: int, block: int, n: int, impute: bool):
    phase = pl.program_id(0)
    bi = pl.program_id(1)
    mean0 = mean0_ref[0]                                  # (d,)
    x = x_ref[...]                                        # (block, d)
    if impute:
        x = jnp.where(jnp.isnan(x), mean0[None, :], x)
    valid = (bi * block
             + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)) < n
    xm = jnp.where(valid, x, 0.0)

    @pl.when(phase == 0)
    def _accumulate():
        @pl.when(bi == 0)
        def _init():
            s1_scr[...] = jnp.zeros_like(s1_scr)
            s2_scr[...] = jnp.zeros_like(s2_scr)

        s1_scr[...] = s1_scr[...] + jnp.sum(xm, axis=0)
        s2_scr[...] = s2_scr[...] + jnp.sum(xm * xm, axis=0)

        @pl.when(bi == blocks - 1)
        def _merge():
            # Welford batch merge from raw moments: the batch m2 is
            # sum(x^2) - nb*mean_b^2 (algebraically equal to the jnp
            # twin's centered sum; tolerance-equal in fp32).
            n0 = n0_ref[0, 0]
            nb = jnp.float32(n)
            mean_b = s1_scr[...] / nb
            m2_b = jnp.maximum(s2_scr[...] - nb * mean_b * mean_b, 0.0)
            n1 = n0 + nb
            delta = mean_b - mean0
            mean1 = mean0 + delta * (nb / jnp.maximum(n1, 1.0))
            m21 = (m20_ref[0] + m2_b
                   + delta * delta * n0 * nb / jnp.maximum(n1, 1.0))
            var = m21 / jnp.maximum(n1 - 1.0, 1.0)
            stat_scr[0] = mean1
            stat_scr[1] = jax.lax.rsqrt(var + 1e-6)
            n1_ref[0, 0] = n1
            mean1_ref[0] = mean1
            m21_ref[0] = m21

    @pl.when(phase == 1)
    def _normalize():
        y_ref[...] = (x - stat_scr[0][None, :]) * stat_scr[1][None, :]


def fused_normalize(x: jax.Array, n0: jax.Array, mean0: jax.Array,
                    m20: jax.Array, *, impute: bool = True,
                    block: int = 256, interpret: bool = False):
    """Fused impute + Welford-update + normalize over one batch.

    x: (n, d) fp32 (may contain NaN when ``impute``); n0: scalar count,
    mean0/m20: (d,) running stats. Returns ``(y, n1, mean1, m21)`` —
    the normalized batch and the updated running state, matching
    ``ref.fused_normalize_ref`` (= impute_with_mean + norm_update_apply).
    """
    n, d = x.shape
    block = min(block, max(n, 8))
    npad = -(-n // block) * block
    if npad != n:
        x = jnp.pad(x, ((0, npad - n), (0, 0)))
    blocks = npad // block
    kernel = functools.partial(_normalize_kernel, blocks=blocks, block=block,
                               n=n, impute=impute)
    y, n1, mean1, m21 = pl.pallas_call(
        kernel,
        grid=(2, blocks),
        in_specs=[
            pl.BlockSpec((block, d), lambda p, b: (b, 0)),
            pl.BlockSpec((1, 1), lambda p, b: (0, 0)),
            pl.BlockSpec((1, d), lambda p, b: (0, 0)),
            pl.BlockSpec((1, d), lambda p, b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block, d), lambda p, b: (b, 0)),
            pl.BlockSpec((1, 1), lambda p, b: (0, 0)),
            pl.BlockSpec((1, d), lambda p, b: (0, 0)),
            pl.BlockSpec((1, d), lambda p, b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad, d), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((2, d), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32),
      jnp.asarray(n0, jnp.float32).reshape(1, 1),
      jnp.asarray(mean0, jnp.float32)[None, :],
      jnp.asarray(m20, jnp.float32)[None, :])
    return y[:n], n1[0, 0], mean1[0], m21[0]


def _hash_kernel(ids_ref, vals_ref, out_ref, *, dim: int, f: int, a: int):
    ids = ids_ref[...]                                    # (block, f) int32
    vals = vals_ref[...].astype(jnp.float32)              # (block, f)
    h = (ids * jnp.int32(a) + jnp.int32(_HASH_C)) % _HASH_P
    slot = h % dim                                        # (block, f)
    sign = jnp.where((h // dim) % 2 == 0, 1.0, -1.0)
    block = ids.shape[0]
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, dim), 1)
    acc = jnp.zeros((block, dim), jnp.float32)
    for j in range(f):                                    # f is small/static
        acc = acc + jnp.where(cols == slot[:, j][:, None],
                              (vals[:, j] * sign[:, j])[:, None], 0.0)
    out_ref[...] = acc


def fused_hash_features(ids: jax.Array, vals: jax.Array, dim: int, *,
                        seed: int = 17, block: int = 256,
                        interpret: bool = False) -> jax.Array:
    """Signed feature hashing: ids/vals (n, f) -> dense (n, dim) fp32.

    Bitwise-identical to ``ref.hash_features_ref`` — the hash is pure
    int32 arithmetic and the per-row accumulation order is the feature
    order in both.
    """
    n, f = ids.shape
    block = min(block, max(n, 8))
    npad = -(-n // block) * block
    if npad != n:
        ids = jnp.pad(ids, ((0, npad - n), (0, 0)))
        vals = jnp.pad(vals, ((0, npad - n), (0, 0)))
    blocks = npad // block
    kernel = functools.partial(_hash_kernel, dim=dim, f=f, a=2 * seed + 1)
    out = pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((block, f), lambda b: (b, 0)),
            pl.BlockSpec((block, f), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((block, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((npad, dim), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), vals)
    return out[:n]
