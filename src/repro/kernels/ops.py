"""Jit'd public wrappers for the Pallas kernels.

Kernel dispatch policy: the Pallas path is taken on TPU backends (or when
``REPRO_FORCE_PALLAS_INTERPRET=1`` forces interpret mode, used by tests and
CPU benchmarks); otherwise callers fall back to the XLA chunked
implementations. This keeps one model code path across dev CPU and
production TPU.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import countmin as _cms
from repro.kernels import ef_codec as _ef
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import preprocess as _pp
from repro.kernels import rwkv6_wkv as _wkv


def _interpret() -> bool:
    # JAX_PALLAS_INTERPRET is the conventional spelling the CI oracle job
    # uses; REPRO_FORCE_PALLAS_INTERPRET kept for back-compat.
    return (os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"
            or os.environ.get("JAX_PALLAS_INTERPRET", "0") == "1")


def pallas_available() -> bool:
    return jax.default_backend() == "tpu" or _interpret()


def flash_supported(q, k, v, causal, q_offset, kv_len) -> bool:
    """Kernel handles plain causal/full attention without offsets/lengths
    (the cached-decode path uses the XLA implementation)."""
    if not pallas_available():
        return False
    if kv_len is not None:
        return False
    if isinstance(q_offset, jax.Array) or q_offset:
        return False
    return q.shape[-1] == k.shape[-1]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, lw, u, h0, *, chunk: int = 32):
    return _wkv.rwkv6_wkv(r, k, v, lw, u, h0, chunk=chunk,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "bd"))
def mamba_scan(dt, x, Bm, Cm, A, h0, *, chunk: int = 128, bd: int = 256):
    return _ms.mamba_scan_bd(dt, x, Bm, Cm, A, h0, chunk=chunk, bd=bd,
                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("depth", "width", "block"))
def countmin_update(ids, *, depth: int, width: int, seeds, block: int = 1024):
    return _cms.countmin_update(ids, depth, width, seeds, block=block,
                                interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block",))
def countmin_update_query(ids, table, seeds, *, block: int = 1024):
    return _cms.countmin_update_query(ids, table, seeds, block=block,
                                      interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("impute", "block"))
def fused_normalize(x, n0, mean0, m20, *, impute: bool = True,
                    block: int = 256):
    return _pp.fused_normalize(x, n0, mean0, m20, impute=impute,
                               block=block, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("dim", "seed", "block"))
def hash_features(ids, vals, *, dim: int, seed: int = 17, block: int = 256):
    return _pp.fused_hash_features(ids, vals, dim, seed=seed, block=block,
                                   interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block",))
def ef_int8_roundtrip(residual, x, *, block: int = 2048):
    return _ef.ef_int8_roundtrip(residual, x, block=block,
                                 interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("k", "block"))
def ef_topk_int8_roundtrip(residual, x, *, k: int, block: int = 2048):
    return _ef.ef_topk_int8_roundtrip(residual, x, k, block=block,
                                      interpret=_interpret())
