"""Jit'd public wrappers for the Pallas kernels.

Kernel dispatch policy: the Pallas path is taken on TPU backends (or when
``REPRO_FORCE_PALLAS_INTERPRET=1`` forces interpret mode, used by tests and
CPU benchmarks); otherwise callers fall back to the XLA chunked
implementations. This keeps one model code path across dev CPU and
production TPU.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import countmin as _cms
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import rwkv6_wkv as _wkv


def _interpret() -> bool:
    return os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"


def pallas_available() -> bool:
    return jax.default_backend() == "tpu" or _interpret()


def flash_supported(q, k, v, causal, q_offset, kv_len) -> bool:
    """Kernel handles plain causal/full attention without offsets/lengths
    (the cached-decode path uses the XLA implementation)."""
    if not pallas_available():
        return False
    if kv_len is not None:
        return False
    if isinstance(q_offset, jax.Array) or q_offset:
        return False
    return q.shape[-1] == k.shape[-1]


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256):
    return _fa.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, lw, u, h0, *, chunk: int = 32):
    return _wkv.rwkv6_wkv(r, k, v, lw, u, h0, chunk=chunk,
                          interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "bd"))
def mamba_scan(dt, x, Bm, Cm, A, h0, *, chunk: int = 128, bd: int = 256):
    return _ms.mamba_scan_bd(dt, x, Bm, Cm, A, h0, chunk=chunk, bd=bd,
                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("depth", "width", "block"))
def countmin_update(ids, *, depth: int, width: int, seeds, block: int = 1024):
    return _cms.countmin_update(ids, depth, width, seeds, block=block,
                                interpret=_interpret())
