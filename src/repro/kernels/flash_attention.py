"""Flash attention (forward) as a Pallas TPU kernel.

Grid: (batch*heads, q_blocks, kv_blocks) with the kv dimension innermost
("arbitrary" semantics) so the online-softmax accumulators live in VMEM
scratch across kv steps. Causal blocks above the diagonal are skipped with
``pl.when`` — the 2x compute saving the XLA chunked path cannot express
(see EXPERIMENTS.md §Perf).

Block shapes are MXU-aligned (multiples of 128 whenever the sequence
allows; the head dim rides whole). VMEM working set per grid point:
q (bq,D) + k,v (bk,D) + acc (bq,D) fp32 + scores (bq,bk) — ~1.3 MB at
bq=bk=256, D=128, far under the v5e VMEM budget, leaving room for double
buffering.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      causal: bool, scale: float, bq: int, bk: int,
                      kv_blocks: int, kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # causal: skip blocks fully above the diagonal
    run = (k_start <= q_start + bq - 1) if causal else (ki >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, D)
        k = k_ref[0].astype(jnp.float32)                 # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, bq: int = 256,
                         bk: int = 256, interpret: bool = False):
    """q: (BH, S, D); k,v: (BH, T, D). Returns (BH, S, D)."""
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    Sp, Tp = -(-S // bq) * bq, -(-T // bk) * bk
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0)))
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tp - T), (0, 0)))
    kv_blocks = Tp // bk
    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, scale=1.0 / math.sqrt(D),
        bq=bq, bk=bk, kv_blocks=kv_blocks, kv_len=T)
    out = pl.pallas_call(
        kernel,
        grid=(BH, Sp // bq, kv_blocks),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S, :]


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: bool = False):
    """Model-layout wrapper. q: (B,S,H,D); k,v: (B,T,KV,D) (GQA expanded
    here). Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    qr = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, T, D)
    o = flash_attention_bhsd(qr, kr, vr, causal=causal, bq=bq, bk=bk,
                             interpret=interpret)
    return o.reshape(B, H, S, D).transpose(0, 2, 1, 3)
