"""Count-Min sketch update as a Pallas TPU kernel (S2CE ingest hot path).

TPU has no atomic scatter-add, so per-depth histogram accumulation is done
the MXU way: hash each item id to a column, build a one-hot (block, width)
matrix, and matmul with a ones-vector — i.e. a column-count reduction per
block, accumulated across the item grid in VMEM scratch. The sketch row
for each hash depth is updated independently (grid dim 0).

Hashing: universal (a*x + b) mod p mod width, with per-depth odd constants
(same family as the jnp oracle in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_P = 2_147_483_647  # Mersenne prime 2^31-1


def hash_ids(ids: jax.Array, a: jax.Array, b: jax.Array, width: int):
    """Universal hash; seeds must be < 2^15 so products stay exact in the
    int32 domain (jax x64 is disabled in production configs)."""
    h = (ids.astype(jnp.int32) * a.astype(jnp.int32) + b.astype(jnp.int32))
    return ((h % _P) % width).astype(jnp.int32)


def _cms_kernel(ids_ref, a_ref, b_ref, out_ref, acc_scr, *,
                blocks: int, block: int, width: int, n: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ids = ids_ref[0].astype(jnp.int32)                 # (block,)
    a = a_ref[0]
    b = b_ref[0]
    hi = ((ids.astype(jnp.int32) * a.astype(jnp.int32)
           + b.astype(jnp.int32)) % _P) % width        # (block,)
    valid = (bi * block + jax.lax.iota(jnp.int32, block)) < n
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, width), 1)
    onehot = jnp.where(
        jnp.logical_and(cols == hi.astype(jnp.int32)[:, None],
                        valid[:, None]),
        1.0, 0.0)
    counts = jnp.sum(onehot, axis=0)                   # (width,)
    acc_scr[...] = acc_scr[...] + counts

    @pl.when(bi == blocks - 1)
    def _final():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def countmin_update(ids: jax.Array, depth: int, width: int,
                    seeds: jax.Array, *, block: int = 1024,
                    interpret: bool = False) -> jax.Array:
    """ids: (n,) int32 -> sketch increment (depth, width) int32.
    seeds: (depth, 2) int64-ish hash constants."""
    n = ids.shape[0]
    block = min(block, max(n, 8))
    npad = -(-n // block) * block
    if npad != n:
        ids = jnp.pad(ids, (0, npad - n))
    blocks = npad // block
    kernel = functools.partial(_cms_kernel, blocks=blocks, block=block,
                               width=width, n=n)
    out = pl.pallas_call(
        kernel,
        grid=(depth, blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda d, b: (0, b)),
            pl.BlockSpec((1,), lambda d, b: (d,)),
            pl.BlockSpec((1,), lambda d, b: (d,)),
        ],
        out_specs=pl.BlockSpec((1, width), lambda d, b: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        scratch_shapes=[pltpu.VMEM((width,), jnp.float32)],
        interpret=interpret,
    )(ids[None, :], seeds[:, 0], seeds[:, 1])
    return out
