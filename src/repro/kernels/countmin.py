"""Count-Min sketch update as a Pallas TPU kernel (S2CE ingest hot path).

TPU has no atomic scatter-add, so per-depth histogram accumulation is done
the MXU way: hash each item id to a column, build a one-hot (block, width)
matrix, and matmul with a ones-vector — i.e. a column-count reduction per
block, accumulated across the item grid in VMEM scratch. The sketch row
for each hash depth is updated independently (grid dim 0).

Hashing: universal (a*x + b) mod p mod width, with per-depth odd constants
(same family as the jnp oracle in ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_P = 2_147_483_647  # Mersenne prime 2^31-1


def hash_ids(ids: jax.Array, a: jax.Array, b: jax.Array, width: int):
    """Universal hash; seeds must be < 2^15 so products stay exact in the
    int32 domain (jax x64 is disabled in production configs)."""
    h = (ids.astype(jnp.int32) * a.astype(jnp.int32) + b.astype(jnp.int32))
    return ((h % _P) % width).astype(jnp.int32)


def _cms_kernel(ids_ref, a_ref, b_ref, out_ref, acc_scr, *,
                blocks: int, block: int, width: int, n: int):
    bi = pl.program_id(1)

    @pl.when(bi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ids = ids_ref[0].astype(jnp.int32)                 # (block,)
    a = a_ref[0]
    b = b_ref[0]
    hi = ((ids.astype(jnp.int32) * a.astype(jnp.int32)
           + b.astype(jnp.int32)) % _P) % width        # (block,)
    valid = (bi * block + jax.lax.iota(jnp.int32, block)) < n
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, width), 1)
    onehot = jnp.where(
        jnp.logical_and(cols == hi.astype(jnp.int32)[:, None],
                        valid[:, None]),
        1.0, 0.0)
    counts = jnp.sum(onehot, axis=0)                   # (width,)
    acc_scr[...] = acc_scr[...] + counts

    @pl.when(bi == blocks - 1)
    def _final():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def countmin_update(ids: jax.Array, depth: int, width: int,
                    seeds: jax.Array, *, block: int = 1024,
                    interpret: bool = False) -> jax.Array:
    """ids: (n,) int32 -> sketch increment (depth, width) int32.
    seeds: (depth, 2) int64-ish hash constants."""
    n = ids.shape[0]
    block = min(block, max(n, 8))
    npad = -(-n // block) * block
    if npad != n:
        ids = jnp.pad(ids, (0, npad - n))
    blocks = npad // block
    kernel = functools.partial(_cms_kernel, blocks=blocks, block=block,
                               width=width, n=n)
    out = pl.pallas_call(
        kernel,
        grid=(depth, blocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda d, b: (0, b)),
            pl.BlockSpec((1,), lambda d, b: (d,)),
            pl.BlockSpec((1,), lambda d, b: (d,)),
        ],
        out_specs=pl.BlockSpec((1, width), lambda d, b: (d, 0)),
        out_shape=jax.ShapeDtypeStruct((depth, width), jnp.int32),
        scratch_shapes=[pltpu.VMEM((width,), jnp.float32)],
        interpret=interpret,
    )(ids[None, :], seeds[:, 0], seeds[:, 1])
    return out


def _cms_uq_kernel(ids_ref, table_ref, a_ref, b_ref, tout_ref, est_ref,
                   acc_scr, est_scr, *, blocks: int, depth: int, block: int,
                   width: int, n: int):
    phase = pl.program_id(0)
    bi = pl.program_id(1)
    di = pl.program_id(2)

    ids = ids_ref[0].astype(jnp.int32)                     # (block,)
    hi = ((ids * a_ref[0].astype(jnp.int32)
           + b_ref[0].astype(jnp.int32)) % _P) % width     # (block,)
    valid = (bi * block + jax.lax.iota(jnp.int32, block)) < n
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, width), 1)
    onehot = jnp.where(cols == hi[:, None], 1.0, 0.0)      # (block, width)

    @pl.when(phase == 0)
    def _accumulate():
        @pl.when(jnp.logical_and(bi == 0, di == 0))
        def _init():
            acc_scr[...] = jnp.zeros_like(acc_scr)

        counts = jnp.sum(jnp.where(valid[:, None], onehot, 0.0), axis=0)
        acc_scr[di] = acc_scr[di] + counts

    @pl.when(phase == 1)
    def _query():
        new_row = table_ref[0].astype(jnp.float32) + acc_scr[di]
        tout_ref[0] = new_row.astype(jnp.int32)
        # gather the MXU/VPU way: the one-hot row picks its sketch cell
        est_d = jnp.sum(onehot * new_row[None, :], axis=1)  # (block,)

        @pl.when(di == 0)
        def _first():
            est_scr[...] = est_d

        @pl.when(di > 0)
        def _min():
            est_scr[...] = jnp.minimum(est_scr[...], est_d)

        @pl.when(di == depth - 1)
        def _emit():
            est_ref[0] = est_scr[...].astype(jnp.int32)


def countmin_update_query(ids: jax.Array, table: jax.Array,
                          seeds: jax.Array, *, block: int = 1024,
                          interpret: bool = False):
    """Fused batched add-then-query: fold ``ids`` into ``table`` and
    estimate each id's count against the UPDATED sketch in one pass.

    ids: (n,) int32; table: (depth, width) int32; seeds: (depth, 2).
    Returns ``(new_table (depth, width) int32, est (n,) int32)`` — the
    same result as ``countmin_update`` + a per-depth gather + min, but
    hashing each block once instead of twice and with no (n, depth)
    estimate matrix materialized. Counts stay exact: they live in fp32
    (< 2^24) until the final int32 cast.
    """
    depth, width = table.shape
    n = ids.shape[0]
    block = min(block, max(n, 8))
    npad = -(-n // block) * block
    if npad != n:
        ids = jnp.pad(ids, (0, npad - n))
    blocks = npad // block
    kernel = functools.partial(_cms_uq_kernel, blocks=blocks, depth=depth,
                               block=block, width=width, n=n)
    new_table, est = pl.pallas_call(
        kernel,
        grid=(2, blocks, depth),
        in_specs=[
            pl.BlockSpec((1, block), lambda p, b, d: (0, b)),
            pl.BlockSpec((1, width), lambda p, b, d: (d, 0)),
            pl.BlockSpec((1,), lambda p, b, d: (d,)),
            pl.BlockSpec((1,), lambda p, b, d: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((1, width), lambda p, b, d: (d, 0)),
            pl.BlockSpec((1, block), lambda p, b, d: (0, b)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((depth, width), jnp.int32),
            jax.ShapeDtypeStruct((1, npad), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((depth, width), jnp.float32),
                        pltpu.VMEM((block,), jnp.float32)],
        interpret=interpret,
    )(ids[None, :], table, seeds[:, 0], seeds[:, 1])
    return new_table, est[0, :n]
