"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are swept against in tests/test_kernels.py)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,D); k,v: (B,T,KV,D). Materialized-softmax oracle."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rwkv6_wkv_ref(r, k, v, lw, u, h0):
    """Naive per-timestep recurrence. r,k,v,lw: (B,S,H,hs); u: (H,hs);
    h0: (B,H,hs,hs). Returns (o, h_last) in fp32."""
    B, S, H, hs = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(lw.astype(jnp.float32))          # decay in (0,1]

    def step(h, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], w[:, t]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        o_t = jnp.einsum("bhi,bhij->bhj", rt, h + u[None, :, :, None] * kv)
        h = wt[..., None] * h + kv
        return h, o_t

    h, outs = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(outs, 0, 1), h            # (B,S,H,hs), (B,H,hs,hs)


def mamba_scan_ref(dt, x, Bm, Cm, A, h0):
    """Naive per-timestep selective scan. dt,x: (B,S,dI); Bm,Cm: (B,S,N);
    A: (dI,N); h0: (B,dI,N). Returns (y (B,S,dI), h_last)."""
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t][:, :, None] * Af[None])
        b = (dtf[:, t] * xf[:, t])[:, :, None] * Bf[:, t][:, None, :]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(dt.shape[1]))
    return jnp.moveaxis(ys, 0, 1), h


def countmin_ref(ids, depth, width, seeds):
    """Scatter-add oracle for the Count-Min sketch increment."""
    P = 2_147_483_647
    out = jnp.zeros((depth, width), jnp.int32)
    for d in range(depth):
        h = ((ids.astype(jnp.int32) * int(seeds[d, 0])
              + int(seeds[d, 1])) % P) % width
        out = out.at[d].add(
            jnp.zeros((width,), jnp.int32).at[h].add(1))
    return out
