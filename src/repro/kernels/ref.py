"""Pure-jnp oracles for every Pallas kernel (the ground truth the kernels
are swept against in tests/test_kernels.py)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,D); k,v: (B,T,KV,D). Materialized-softmax oracle."""
    B, S, H, D = q.shape
    T, KV = k.shape[1], k.shape[2]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rwkv6_wkv_ref(r, k, v, lw, u, h0):
    """Naive per-timestep recurrence. r,k,v,lw: (B,S,H,hs); u: (H,hs);
    h0: (B,H,hs,hs). Returns (o, h_last) in fp32."""
    B, S, H, hs = r.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    w = jnp.exp(lw.astype(jnp.float32))          # decay in (0,1]

    def step(h, t):
        rt, kt, vt, wt = rf[:, t], kf[:, t], vf[:, t], w[:, t]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)
        o_t = jnp.einsum("bhi,bhij->bhj", rt, h + u[None, :, :, None] * kv)
        h = wt[..., None] * h + kv
        return h, o_t

    h, outs = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(outs, 0, 1), h            # (B,S,H,hs), (B,H,hs,hs)


def mamba_scan_ref(dt, x, Bm, Cm, A, h0):
    """Naive per-timestep selective scan. dt,x: (B,S,dI); Bm,Cm: (B,S,N);
    A: (dI,N); h0: (B,dI,N). Returns (y (B,S,dI), h_last)."""
    dtf = dt.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    Bf, Cf = Bm.astype(jnp.float32), Cm.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t][:, :, None] * Af[None])
        b = (dtf[:, t] * xf[:, t])[:, :, None] * Bf[:, t][:, None, :]
        h = a * h + b
        y = jnp.einsum("bdn,bn->bd", h, Cf[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(dt.shape[1]))
    return jnp.moveaxis(ys, 0, 1), h


def countmin_ref(ids, depth, width, seeds):
    """Scatter-add oracle for the Count-Min sketch increment."""
    P = 2_147_483_647
    out = jnp.zeros((depth, width), jnp.int32)
    for d in range(depth):
        h = ((ids.astype(jnp.int32) * int(seeds[d, 0])
              + int(seeds[d, 1])) % P) % width
        out = out.at[d].add(
            jnp.zeros((width,), jnp.int32).at[h].add(1))
    return out


def countmin_update_query_ref(ids, table, seeds):
    """Scatter-add + gather oracle for the fused add-then-query kernel:
    fold the batch into the sketch, then estimate each id against the
    UPDATED table (min over depths)."""
    P = 2_147_483_647
    depth, width = table.shape
    new_table = table + countmin_ref(ids, depth, width, seeds)
    ests = []
    for d in range(depth):
        h = ((ids.astype(jnp.int32) * int(seeds[d, 0])
              + int(seeds[d, 1])) % P) % width
        ests.append(new_table[d, h])
    return new_table, jnp.min(jnp.stack(ests), axis=0)


def fused_normalize_ref(x, n0, mean0, m20, *, impute=True):
    """Impute (NaN -> prior mean) + Welford merge + normalize — the
    composition ``impute_with_mean`` then ``norm_update_apply`` from
    streams/preprocess.py, restated here as a standalone oracle."""
    x = jnp.asarray(x, jnp.float32)
    mean0 = jnp.asarray(mean0, jnp.float32)
    m20 = jnp.asarray(m20, jnp.float32)
    n0 = jnp.asarray(n0, jnp.float32)
    if impute:
        x = jnp.where(jnp.isnan(x), mean0[None, :], x)
    nb = x.shape[0]
    mean_b = jnp.mean(x, axis=0)
    m2_b = jnp.sum(jnp.square(x - mean_b), axis=0)
    n1 = n0 + nb
    delta = mean_b - mean0
    mean1 = mean0 + delta * (nb / jnp.maximum(n1, 1.0))
    m21 = m20 + m2_b + jnp.square(delta) * n0 * nb / jnp.maximum(n1, 1.0)
    var = m21 / jnp.maximum(n1 - 1.0, 1.0)
    y = (x - mean1) * jax.lax.rsqrt(var + 1e-6)
    return y, n1, mean1, m21


def hash_features_ref(ids, vals, dim, seed=17):
    """Signed feature hashing oracle (scatter-add form): ids/vals (n, f)
    -> dense (n, dim). Same int32 hash as streams/preprocess."""
    a = 2 * seed + 1
    h = (ids.astype(jnp.int32) * a + 0x9E37) % 2_147_483_647
    slot = h % dim
    sign = jnp.where((h // dim) % 2 == 0, 1.0, -1.0)
    n, f = ids.shape
    out = jnp.zeros((n, dim), jnp.float32)
    return out.at[jnp.arange(n)[:, None], slot].add(
        vals.astype(jnp.float32) * sign)


def ef_int8_roundtrip_ref(residual, x):
    """Int8 error-feedback wire round-trip oracle: fold the carried
    residual, symmetric per-tensor int8 quantize-dequantize, carry the
    fresh error. Mirrors dist.compression.ef_roundtrip."""
    xc = x.astype(jnp.float32) + residual
    amax = jnp.max(jnp.abs(xc))
    scale = jnp.maximum(amax, jnp.asarray(1e-30, jnp.float32)) / 127.0
    q = jnp.clip(jnp.round(xc / scale), -127.0, 127.0)
    dec = q * scale
    return dec.astype(x.dtype), xc - dec


def ef_topk_int8_roundtrip_ref(residual, x, k):
    """Composed top-k + int8 EF round-trip oracle with one shared
    residual. Selection is by magnitude threshold (the k-th largest
    ``|x + residual|``) — for tie-free inputs identical to exact top-k,
    and the EF telescoping identity holds for any selection."""
    xc = jnp.ravel(x).astype(jnp.float32) + jnp.ravel(residual)
    k = max(1, min(int(k), xc.shape[0]))
    t = jax.lax.top_k(jnp.abs(xc), k)[0][-1]
    kept = jnp.abs(xc) >= t
    amax = jnp.max(jnp.where(kept, jnp.abs(xc), 0.0))
    scale = jnp.maximum(amax, jnp.asarray(1e-30, jnp.float32)) / 127.0
    q = jnp.clip(jnp.round(jnp.where(kept, xc, 0.0) / scale), -127.0, 127.0)
    dec = jnp.where(kept, q * scale, 0.0)
    shape = jnp.shape(x)
    return (dec.reshape(shape).astype(x.dtype),
            (xc - dec).reshape(shape))
