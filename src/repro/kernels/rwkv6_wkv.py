"""RWKV6 WKV recurrence as a chunked Pallas TPU kernel.

Grid: (B*H, chunks) with chunks innermost-sequential; the per-head state
S (hs x hs) persists in VMEM scratch across chunk steps. Within a chunk the
pairwise decay exponent L_excl[t]-L[s] <= 0 keeps everything overflow-free
(same math as the jnp path in models/rwkv.py — the two are asserted
allclose in tests). The intra-chunk term is a (Lc, Lc, hs) pairwise tensor:
VPU-heavy but VMEM-resident; an all-MXU log-space variant is future work
(EXPERIMENTS.md §Perf).

VMEM per grid point at Lc=32, hs=64: r/k/v/lw tiles 4x(32,64)f32 + pair
(32,32,64)f32 + state (64,64)f32 ~= 0.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, h0_ref, o_ref, hout_ref,
                h_scr, *, chunks: int, chunk: int, hs: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0]

    r = r_ref[0].astype(jnp.float32)          # (Lc, hs)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)        # log-decay <= 0
    u = u_ref[0, 0].astype(jnp.float32)       # (hs,)
    h = h_scr[...]                            # (hs, hs)

    L = jnp.cumsum(lw, axis=0)                # inclusive
    L_excl = L - lw
    # inter-chunk: (r_t * exp(L_excl_t)) @ S
    q_in = r * jnp.exp(L_excl)
    o = jax.lax.dot_general(q_in, h, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk pairwise-stable
    dpair = jnp.exp(jnp.minimum(L_excl[:, None, :] - L[None, :, :], 0.0))
    scores = jnp.einsum("ti,tsi,si->ts", r, dpair, k)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(s_idx < t_idx, scores, 0.0)
    o = o + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # diagonal bonus
    diag = jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True)
    o = o + diag * v
    o_ref[0] = o.astype(o_ref.dtype)

    # state update
    L_end = L[-1]                             # (hs,)
    kdec = k * jnp.exp(L_end[None, :] - L)
    h_new = jnp.exp(L_end)[:, None] * h + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    @pl.when(ci == chunks - 1)
    def _final():
        hout_ref[0] = h_new


def rwkv6_wkv_bh(r, k, v, lw, u, h0, *, chunk: int = 32,
                 interpret: bool = False):
    """r,k,v,lw: (BH, S, hs); u: (BH, hs); h0: (BH, hs, hs) fp32.
    Returns (o (BH, S, hs), h_last (BH, hs, hs))."""
    BH, S, hs = r.shape
    chunk = min(chunk, S)
    Sp = -(-S // chunk) * chunk
    if Sp != S:  # pad with zero k/v (contributes nothing), decay 0
        pad = ((0, 0), (0, Sp - S), (0, 0))
        r, k, v = (jnp.pad(t, pad) for t in (r, k, v))
        lw = jnp.pad(lw, pad)
    chunks = Sp // chunk
    kernel = functools.partial(_wkv_kernel, chunks=chunks, chunk=chunk, hs=hs)
    o, h_last = pl.pallas_call(
        kernel,
        grid=(BH, chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hs), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, hs, hs), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hs), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hs, hs), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sp, hs), r.dtype),
            jax.ShapeDtypeStruct((BH, hs, hs), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hs, hs), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u[:, None, :], h0)
    return o[:, :S, :], h_last


def rwkv6_wkv(r, k, v, lw, u, h0, *, chunk: int = 32,
              interpret: bool = False):
    """Model-layout wrapper. r,k,v,lw: (B,S,H,hs); u: (H,hs);
    h0: (B,H,hs,hs). Returns (o (B,S,H,hs), h_last)."""
    B, S, H, hs = r.shape
    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(B * H, S, hs)
    uf = jnp.broadcast_to(u[None], (B, H, hs)).reshape(B * H, hs)
    o, h_last = rwkv6_wkv_bh(fold(r), fold(k), fold(v), fold(lw), uf,
                             h0.reshape(B * H, hs, hs), chunk=chunk,
                             interpret=interpret)
    return (o.reshape(B, H, S, hs).transpose(0, 2, 1, 3),
            h_last.reshape(B, H, hs, hs))
