"""Stream summarization sketches (edge-side, S2CE O2).

Count-Min (frequency estimation; Pallas kernel on the ingest hot path),
Misra-Gries heavy hitters, and streaming moments — the summaries an edge
node ships upstream instead of raw events.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels.countmin import hash_ids
from repro.kernels.ref import countmin_ref, countmin_update_query_ref


class CountMin(NamedTuple):
    table: jax.Array      # (depth, width) int32
    seeds: jax.Array      # (depth, 2) int32 odd constants < 2^15


def countmin_init(depth: int = 4, width: int = 1024, seed: int = 0) -> CountMin:
    rng = np.random.default_rng(seed)
    seeds = jnp.asarray(rng.integers(1, 2**14, (depth, 2)) * 2 + 1, jnp.int32)
    return CountMin(jnp.zeros((depth, width), jnp.int32), seeds)


# Which path actually ran, per entry point. A kernel request that silently
# fell back to the reference used to be invisible (and untestable); now the
# dispatcher counts every call and warns on requested-but-unavailable. The
# counter lives module-level rather than on CountMin so the sketch stays a
# plain int32 pytree (jit/shard_map-safe).
_DISPATCH_COUNTS = {"pallas": 0, "reference": 0}


def dispatch_counts() -> dict:
    """Snapshot of {"pallas": n, "reference": n} calls since last reset."""
    return dict(_DISPATCH_COUNTS)


def reset_dispatch_counts() -> None:
    _DISPATCH_COUNTS["pallas"] = 0
    _DISPATCH_COUNTS["reference"] = 0


def _resolve_kernel(use_kernel: Optional[bool], who: str) -> bool:
    """None -> auto (kernel wherever Pallas runs); True -> kernel, with a
    loud warning + fallback when unavailable; False -> reference."""
    available = kops.pallas_available()
    if use_kernel is None:
        picked = available
    elif use_kernel and not available:
        warnings.warn(
            f"{who}: use_kernel=True but the Pallas path is unavailable "
            "(no TPU backend and interpret mode not forced); falling back "
            "to the jnp reference.", RuntimeWarning, stacklevel=3)
        picked = False
    else:
        picked = use_kernel
    _DISPATCH_COUNTS["pallas" if picked else "reference"] += 1
    return picked


def countmin_add(cm: CountMin, ids: jax.Array,
                 use_kernel: Optional[bool] = None) -> CountMin:
    depth, width = cm.table.shape
    if _resolve_kernel(use_kernel, "countmin_add"):
        inc = kops.countmin_update(ids, depth=depth, width=width,
                                   seeds=cm.seeds)
    else:
        inc = countmin_ref(ids, depth, width, np.asarray(cm.seeds))
    return cm._replace(table=cm.table + inc)


def countmin_add_query(cm: CountMin, ids: jax.Array,
                       use_kernel: Optional[bool] = None
                       ) -> Tuple[CountMin, jax.Array]:
    """Fold ``ids`` into the sketch AND estimate each id's count against
    the updated table in one pass: ``(cm', est (n,) int32)``. On the
    Pallas path the batch is hashed once (fused kernel); the reference
    path is the scatter-add + gather oracle. Both paths agree exactly."""
    if _resolve_kernel(use_kernel, "countmin_add_query"):
        table, est = kops.countmin_update_query(ids, cm.table, cm.seeds)
    else:
        table, est = countmin_update_query_ref(ids, cm.table, cm.seeds)
    return cm._replace(table=table), est


def countmin_query(cm: CountMin, ids: jax.Array) -> jax.Array:
    depth, width = cm.table.shape
    ests = []
    for d in range(depth):
        h = hash_ids(ids, cm.seeds[d, 0], cm.seeds[d, 1], width)
        ests.append(cm.table[d, h])
    return jnp.min(jnp.stack(ests), axis=0)


# ---------------------------------------------------------------------------
# Misra-Gries heavy hitters
# ---------------------------------------------------------------------------

class MisraGries(NamedTuple):
    keys: jax.Array       # (k,) item ids, -1 = empty
    counts: jax.Array     # (k,)


def mg_init(k: int = 64) -> MisraGries:
    return MisraGries(jnp.full((k,), -1, jnp.int32), jnp.zeros((k,), jnp.int32))


def mg_update(mg: MisraGries, ids: jax.Array) -> MisraGries:
    def step(st, item):
        keys, counts = st
        hit = keys == item
        has = jnp.any(hit)
        empty = counts == 0
        has_empty = jnp.any(empty)
        slot = jnp.argmax(hit)
        empty_slot = jnp.argmax(empty)

        def on_hit(_):
            return keys, counts.at[slot].add(1)

        def on_empty(_):
            return keys.at[empty_slot].set(item), counts.at[empty_slot].set(1)

        def on_full(_):
            return keys, counts - 1

        keys2, counts2 = jax.lax.cond(
            has, on_hit,
            lambda o: jax.lax.cond(has_empty, on_empty, on_full, o), None)
        return (keys2, counts2), None

    (keys, counts), _ = jax.lax.scan(step, (mg.keys, mg.counts),
                                     ids.astype(jnp.int32))
    return MisraGries(keys, counts)


# ---------------------------------------------------------------------------
# Streaming moments (count / mean / var / min / max per feature)
# ---------------------------------------------------------------------------

class Moments(NamedTuple):
    n: jax.Array
    mean: jax.Array
    m2: jax.Array
    min: jax.Array
    max: jax.Array


def moments_init(dim: int) -> Moments:
    return Moments(jnp.zeros(()), jnp.zeros((dim,)), jnp.zeros((dim,)),
                   jnp.full((dim,), jnp.inf), jnp.full((dim,), -jnp.inf))


def moments_update(m: Moments, x: jax.Array) -> Moments:
    nb = x.shape[0]
    mean_b = x.mean(0)
    m2_b = jnp.sum(jnp.square(x - mean_b), axis=0)
    n = m.n + nb
    delta = mean_b - m.mean
    mean = m.mean + delta * nb / jnp.maximum(n, 1.0)
    m2 = m.m2 + m2_b + jnp.square(delta) * m.n * nb / jnp.maximum(n, 1.0)
    return Moments(n, mean, m2, jnp.minimum(m.min, x.min(0)),
                   jnp.maximum(m.max, x.max(0)))
