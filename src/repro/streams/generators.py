"""Synthetic stream generators (S2CE O4).

Controllable volume / velocity / skew / concept drift, plus a
privacy-preserving *fitted* generator that releases only moment statistics
of a real stream (mean/cov/class priors) and synthesizes surrogate data —
the paper's mechanism for sharing "closed business data" across companies.

All generators are deterministic functions of (seed, batch_index): streams
are replayable (required for fault-tolerant training restarts) and
parallelizable across feeder shards.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.streams.events import StreamBatch


@dataclass
class DriftSpec:
    kind: str = "none"            # none|abrupt|gradual|recurring
    at: float = 0.5               # position (fraction of horizon) of change
    width: float = 0.05           # transition width for gradual
    period: float = 0.25          # for recurring
    magnitude: float = 2.0


def _drift_mix(spec: DriftSpec, t: float, horizon: float) -> float:
    """Mixing weight in [0,1] between concept A and concept B at time t."""
    x = t / max(horizon, 1e-9)
    if spec.kind == "none":
        return 0.0
    if spec.kind == "abrupt":
        return float(x >= spec.at)
    if spec.kind == "gradual":
        return float(np.clip((x - spec.at) / max(spec.width, 1e-9), 0, 1))
    if spec.kind == "recurring":
        return float(0.5 * (1 + math.sin(2 * math.pi * x / spec.period)))
    raise ValueError(spec.kind)


@dataclass
class HyperplaneStream:
    """Rotating-hyperplane classification stream (the MOA classic)."""
    dim: int = 16
    noise: float = 0.05
    drift: DriftSpec = field(default_factory=DriftSpec)
    horizon: float = 1e6          # events until drift schedule completes
    rate: float = 1e4             # events/sec (velocity; drives timestamps)
    seed: int = 0
    source_id: int = 0

    def _concepts(self):
        rng = np.random.default_rng(self.seed)
        wa = rng.normal(size=self.dim)
        wb = rng.normal(size=self.dim) * self.drift.magnitude
        return wa / np.linalg.norm(wa), wb / np.linalg.norm(wb)

    def batch(self, idx: int, n: int) -> StreamBatch:
        rng = np.random.default_rng((self.seed, idx))
        wa, wb = self._concepts()
        t0 = idx * n / self.rate
        mix = _drift_mix(self.drift, idx * n, self.horizon)
        w = (1 - mix) * wa + mix * wb
        x = rng.normal(size=(n, self.dim)).astype(np.float32)
        margin = x @ w
        y = (margin > 0).astype(np.int32)
        flip = rng.random(n) < self.noise
        y = np.where(flip, 1 - y, y)
        ts = t0 + np.arange(n) / self.rate
        return StreamBatch(data={"x": x, "y": y}, ts=ts,
                           source_id=self.source_id, seq_no=idx,
                           watermark=float(ts[-1]))


@dataclass
class TokenStream:
    """Synthetic token stream for LM continual training: a Zipfian unigram
    mixture whose distribution drifts between two "domains"."""
    vocab_size: int = 1024
    seq_len: int = 128
    zipf_a: float = 1.3
    drift: DriftSpec = field(default_factory=DriftSpec)
    horizon: float = 1e6
    rate: float = 1e5
    seed: int = 0
    source_id: int = 0

    def batch(self, idx: int, n_seqs: int) -> StreamBatch:
        rng = np.random.default_rng((self.seed, idx))
        mix = _drift_mix(self.drift, idx * n_seqs * self.seq_len, self.horizon)
        # domain B permutes the vocabulary (same marginal, drifted mapping)
        perm = np.random.default_rng(self.seed + 1).permutation(self.vocab_size)
        raw = rng.zipf(self.zipf_a, size=(n_seqs, self.seq_len))
        toks = (raw % self.vocab_size).astype(np.int32)
        use_b = rng.random(n_seqs) < mix
        toks = np.where(use_b[:, None], perm[toks], toks).astype(np.int32)
        t0 = idx * n_seqs / self.rate
        ts = t0 + np.arange(n_seqs) / self.rate
        return StreamBatch(data={"tokens": toks}, ts=ts,
                           source_id=self.source_id, seq_no=idx,
                           watermark=float(ts[-1]))


@dataclass
class FittedGaussianGenerator:
    """Privacy-preserving generator: fit per-class moments on real data,
    release ONLY the moments, synthesize surrogate streams from them."""
    means: np.ndarray = None
    chols: np.ndarray = None
    priors: np.ndarray = None
    seed: int = 0

    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray, ridge: float = 1e-3,
            seed: int = 0) -> "FittedGaussianGenerator":
        classes = np.unique(y)
        means, chols, priors = [], [], []
        for c in classes:
            xc = x[y == c]
            mu = xc.mean(0)
            cov = np.cov(xc.T) + ridge * np.eye(x.shape[1])
            means.append(mu)
            chols.append(np.linalg.cholesky(cov))
            priors.append(len(xc) / len(x))
        return cls(np.stack(means), np.stack(chols), np.asarray(priors), seed)

    def batch(self, idx: int, n: int) -> StreamBatch:
        rng = np.random.default_rng((self.seed, idx))
        ys = rng.choice(len(self.priors), size=n, p=self.priors)
        z = rng.normal(size=(n, self.means.shape[1])).astype(np.float32)
        x = self.means[ys] + np.einsum("nij,nj->ni", self.chols[ys], z)
        return StreamBatch(data={"x": x.astype(np.float32),
                                 "y": ys.astype(np.int32)},
                           ts=np.arange(n, dtype=np.float64), seq_no=idx,
                           watermark=float(n))


@dataclass
class BurstyRateModulator:
    """Wraps a generator to modulate batch sizes (volume bursts) — used by
    the offload benchmarks to trigger edge->cloud migration."""
    inner: object
    burst_every: int = 50
    burst_factor: float = 4.0

    def batch(self, idx: int, n: int) -> StreamBatch:
        if self.burst_every and idx % self.burst_every == 0 and idx > 0:
            n = int(n * self.burst_factor)
        return self.inner.batch(idx, n)
