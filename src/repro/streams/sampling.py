"""Stream sampling for edge-side volume reduction (S2CE O2).

Property-preserving (unbiased) sampling is what lets the edge cut volume
without biasing downstream models: Algorithm-R reservoir sampling (uniform
over the whole history) and per-batch Bernoulli thinning, plus stratified
reservoirs for label balance. Pure-JAX, jit-steppable.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ReservoirState(NamedTuple):
    buf: jax.Array        # (k, d)
    extra: jax.Array      # (k,) payload (e.g. labels)
    seen: jax.Array       # () total items observed
    rng: jax.Array


def reservoir_init(k: int, dim: int, seed: int = 0) -> ReservoirState:
    return ReservoirState(
        buf=jnp.zeros((k, dim)),
        extra=jnp.zeros((k,), jnp.int32),
        seen=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(seed),
    )


def reservoir_update(state: ReservoirState, x: jax.Array, y: jax.Array
                     ) -> ReservoirState:
    """Algorithm R over a batch. x: (n, d); y: (n,)."""
    k = state.buf.shape[0]

    def step(st, item):
        xi, yi = item
        rng, r1 = jax.random.split(st.rng)
        seen = st.seen + 1
        # position: if seen <= k -> seen-1 else random j in [0, seen)
        j = jax.random.randint(r1, (), 0, seen)
        idx = jnp.where(seen <= k, seen - 1, j)
        take = (seen <= k) | (j < k)
        idx = jnp.clip(idx, 0, k - 1)
        buf = jnp.where(take, st.buf.at[idx].set(xi), st.buf)
        extra = jnp.where(take, st.extra.at[idx].set(yi), st.extra)
        return ReservoirState(buf, extra, seen, rng), None

    state, _ = jax.lax.scan(step, state, (x, y.astype(jnp.int32)))
    return state


def bernoulli_thin(rng: jax.Array, x: jax.Array, rate: float
                   ) -> Tuple[jax.Array, jax.Array]:
    """Unbiased thinning: keep each item w.p. `rate`; returns (mask, rng).
    Downstream estimators reweight by 1/rate."""
    rng, sub = jax.random.split(rng)
    mask = jax.random.bernoulli(sub, rate, (x.shape[0],))
    return mask, rng


class StratifiedReservoir(NamedTuple):
    states: ReservoirState          # stacked per class (C leading dim)


def stratified_init(n_classes: int, k: int, dim: int,
                    seed: int = 0) -> StratifiedReservoir:
    def one(c):
        return reservoir_init(k, dim, seed + c)
    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[one(c) for c in range(n_classes)])
    return StratifiedReservoir(states)


def stratified_update(sr: StratifiedReservoir, x: jax.Array, y: jax.Array,
                      n_classes: int) -> StratifiedReservoir:
    def upd_class(c, st):
        mask = (y == c)
        # gather class items to front; pad with repeats masked out by weight 0
        w = mask.astype(jnp.float32)
        # simple approach: scan full batch, take only when class matches
        def step(s, item):
            xi, yi, mi = item
            def do(s):
                return reservoir_update(
                    ReservoirState(*s), xi[None], yi[None])
            s2 = jax.lax.cond(mi, lambda ss: tuple(do(ss)),
                              lambda ss: ss, tuple(s))
            return s2, None
        st_t, _ = jax.lax.scan(step, tuple(st), (x, y, mask))
        return ReservoirState(*st_t)

    new_states = []
    for c in range(n_classes):
        st_c = jax.tree.map(lambda a: a[c], sr.states)
        new_states.append(upd_class(c, st_c))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
    return StratifiedReservoir(stacked)
