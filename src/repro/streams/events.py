"""Stream events: the unit of data flowing through an S2CE pipeline.

A :class:`StreamBatch` is a pytree of equal-leading-dim arrays plus
watermark/ordering metadata — directly shardable over the `batch` logical
axis, so the same batch object flows from edge preprocessing into cloud
training without conversion (S2CE O1: data-in-motion and data-at-rest
processed uniformly).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class StreamBatch:
    data: Dict[str, Any]                  # str -> array (n, ...)
    ts: Any = None                        # (n,) event timestamps (float64 sec)
    source_id: int = 0
    seq_no: int = 0                       # per-source monotone batch counter
    watermark: float = 0.0                # max event time fully observed
    labels_delay: float = 0.0             # label availability lag (§2.5)

    @property
    def n(self) -> int:
        return int(next(iter(jax.tree.leaves(self.data))).shape[0])

    def with_data(self, **kw) -> "StreamBatch":
        d = dict(self.data)
        d.update(kw)
        return replace(self, data=d)

    def select(self, idx) -> "StreamBatch":
        return replace(
            self,
            data=jax.tree.map(lambda a: a[idx], self.data),
            ts=None if self.ts is None else self.ts[idx],
        )

    def concat(self, other: "StreamBatch") -> "StreamBatch":
        data = jax.tree.map(lambda a, b: jnp.concatenate([a, b]),
                            self.data, other.data)
        ts = None
        if self.ts is not None and other.ts is not None:
            ts = np.concatenate([np.asarray(self.ts), np.asarray(other.ts)])
        return replace(self, data=data, ts=ts,
                       watermark=max(self.watermark, other.watermark))


def merge_watermark(batches) -> float:
    """Pipeline watermark = min over sources (an event-time barrier)."""
    return min(b.watermark for b in batches)
