"""Host->device stream feeder with prefetch and straggler mitigation.

The feeder owns N worker "shards" (one per source partition). Each shard
produces batches on a deadline; a shard that misses its deadline is a
*straggler* and its batch is served from a backup generator replica instead
(generators are deterministic in (seed, index), so the backup produces the
identical batch — no data loss, no duplicates). This is the data-plane half
of S2CE fault tolerance; the compute-plane half is dist/elastic.py.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.streams.events import StreamBatch


@dataclass
class FeederStats:
    batches: int = 0
    straggler_rescues: int = 0
    wait_s: float = 0.0


class StreamFeeder:
    """Pulls from `make_batch(shard, idx, n)` across shards, double-buffers
    device puts, rescues stragglers from the deterministic replay path."""

    def __init__(self, make_batch: Callable[[int, int, int], StreamBatch],
                 n_shards: int = 2, batch_per_shard: int = 64,
                 deadline_s: float = 1.0, prefetch: int = 2,
                 inject_straggle: Optional[Callable[[int, int], float]] = None,
                 start_idx: int = 0):
        self.make_batch = make_batch
        self.n_shards = n_shards
        self.batch_per_shard = batch_per_shard
        self.deadline_s = deadline_s
        self.prefetch = prefetch
        self.inject_straggle = inject_straggle     # (shard, idx) -> sleep s
        self.stats = FeederStats()
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._idx = start_idx        # first batch index (resume support)
        self._thread: Optional[threading.Thread] = None

    @property
    def backlog(self) -> int:
        """Prefetched batches waiting to be consumed. A persistently full
        queue means the producers outpace the consumer — the offered-load
        signal elastic scaling uses when no demand curve is given."""
        return self._q.qsize()

    # -- worker ------------------------------------------------------------
    def _produce_one(self, idx: int) -> StreamBatch:
        results: List[Optional[StreamBatch]] = [None] * self.n_shards

        def work(shard):
            if self.inject_straggle:
                time.sleep(self.inject_straggle(shard, idx))
            results[shard] = self.make_batch(shard, idx, self.batch_per_shard)

        threads = [threading.Thread(target=work, args=(s,), daemon=True)
                   for s in range(self.n_shards)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        deadline = t0 + self.deadline_s
        for t in threads:
            t.join(max(0.0, deadline - time.perf_counter()))
        # straggler rescue: deterministic replay on the caller thread
        for s in range(self.n_shards):
            if results[s] is None:
                results[s] = self.make_batch(s, idx, self.batch_per_shard)
                self.stats.straggler_rescues += 1
        out = results[0]
        for b in results[1:]:
            out = out.concat(b)
        self.stats.batches += 1
        return out

    def _run(self):
        while not self._stop.is_set():
            b = self._produce_one(self._idx)
            self._idx += 1
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    # -- public ------------------------------------------------------------
    def start(self):
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def next(self, timeout: float = 30.0) -> StreamBatch:
        t0 = time.perf_counter()
        b = self._q.get(timeout=timeout)
        self.stats.wait_s += time.perf_counter() - t0
        return b

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=2.0)
