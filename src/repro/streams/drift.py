"""Concept-drift detectors as pure JAX step functions (S2CE §2.4).

Each detector is ``(state, x) -> (state, level)`` with level 0=stable,
1=warning, 2=drift — steppable under ``lax.scan`` for whole-stream
evaluation, and cheap enough for the S2 "microsecond updates" criterion
(benchmarks/bench_streams.py measures the per-update latency).

Implemented: DDM (Gama'04), EDDM (Baena-Garcia'06), Page-Hinkley, and a
fixed-memory ADWIN variant (exponential bucket histogram with capped bucket
rows, so state is a static-shape array — required for jit).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

STABLE, WARNING, DRIFT = 0, 1, 2


# ---------------------------------------------------------------------------
# DDM
# ---------------------------------------------------------------------------

class DDMState(NamedTuple):
    n: jax.Array
    p: jax.Array          # running error rate
    s_min: jax.Array      # min of p + s
    p_min: jax.Array
    level: jax.Array


def ddm_init() -> DDMState:
    return DDMState(jnp.zeros(()), jnp.zeros(()), jnp.asarray(1e9),
                    jnp.asarray(1e9), jnp.zeros((), jnp.int32))


def ddm_step(state: DDMState, error: jax.Array,
             warn: float = 2.0, drift: float = 3.0) -> Tuple[DDMState, jax.Array]:
    n = state.n + 1.0
    p = state.p + (error - state.p) / n
    s = jnp.sqrt(p * (1 - p) / jnp.maximum(n, 1.0))
    # track minima only after warm-up: tiny-n noise would otherwise set an
    # absurdly low baseline and cause false alarms (MOA does the same)
    better = jnp.logical_and(n >= 30, (p + s) < (state.p_min + state.s_min))
    p_min = jnp.where(better, p, state.p_min)
    s_min = jnp.where(better, s, state.s_min)
    level = jnp.where(
        (p + s) > (p_min + drift * s_min), DRIFT,
        jnp.where((p + s) > (p_min + warn * s_min), WARNING, STABLE)
    ).astype(jnp.int32)
    level = jnp.where(n < 30, STABLE, level).astype(jnp.int32)  # warm-up (MOA)
    # on drift: reset statistics (keep detection sticky for one step)
    reset = level == DRIFT
    new = DDMState(
        n=jnp.where(reset, 0.0, n),
        p=jnp.where(reset, 0.0, p),
        s_min=jnp.where(reset, 1e9, s_min),
        p_min=jnp.where(reset, 1e9, p_min),
        level=level,
    )
    return new, level


# ---------------------------------------------------------------------------
# EDDM (distance-between-errors)
# ---------------------------------------------------------------------------

class EDDMState(NamedTuple):
    n_err: jax.Array
    since_last: jax.Array
    mean_d: jax.Array
    var_d: jax.Array
    best: jax.Array       # max of mean + 2*std
    level: jax.Array


def eddm_init() -> EDDMState:
    return EDDMState(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()),
                     jnp.zeros(()), jnp.asarray(-1e9),
                     jnp.zeros((), jnp.int32))


def eddm_step(state: EDDMState, error: jax.Array, alpha: float = 0.92,
              beta: float = 0.85) -> Tuple[EDDMState, jax.Array]:
    since = state.since_last + 1.0

    def on_error(st):
        n = st.n_err + 1.0
        delta = since - st.mean_d
        mean_d = st.mean_d + delta / n
        var_d = st.var_d + delta * (since - mean_d)
        std = jnp.sqrt(var_d / jnp.maximum(n, 1.0))
        metric = mean_d + 2 * std
        best = jnp.maximum(st.best, metric)
        ratio = metric / jnp.maximum(best, 1e-9)
        level = jnp.where(ratio < beta, DRIFT,
                          jnp.where(ratio < alpha, WARNING, STABLE))
        warm = n < 50
        level = jnp.where(warm, STABLE, level).astype(jnp.int32)
        reset = level == DRIFT
        return EDDMState(
            n_err=jnp.where(reset, 0.0, n),
            since_last=jnp.zeros(()),
            mean_d=jnp.where(reset, 0.0, mean_d),
            var_d=jnp.where(reset, 0.0, var_d),
            best=jnp.where(reset, -1e9, best),
            level=level)

    def no_error(st):
        return EDDMState(st.n_err, since, st.mean_d, st.var_d, st.best,
                         jnp.zeros((), jnp.int32))

    new = jax.lax.cond(error > 0.5, on_error, no_error, state)
    return new, new.level


# ---------------------------------------------------------------------------
# Page-Hinkley
# ---------------------------------------------------------------------------

class PHState(NamedTuple):
    n: jax.Array
    mean: jax.Array
    cum: jax.Array
    cum_min: jax.Array
    level: jax.Array


def ph_init() -> PHState:
    return PHState(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()),
                   jnp.zeros(()), jnp.zeros((), jnp.int32))


def ph_step(state: PHState, x: jax.Array, delta: float = 0.005,
            lam: float = 50.0) -> Tuple[PHState, jax.Array]:
    n = state.n + 1.0
    mean = state.mean + (x - state.mean) / n
    cum = state.cum + x - mean - delta
    cum_min = jnp.minimum(state.cum_min, cum)
    level = jnp.where(cum - cum_min > lam, DRIFT, STABLE).astype(jnp.int32)
    reset = level == DRIFT
    new = PHState(jnp.where(reset, 0.0, n), jnp.where(reset, 0.0, mean),
                  jnp.where(reset, 0.0, cum), jnp.where(reset, 0.0, cum_min),
                  level)
    return new, level


# ---------------------------------------------------------------------------
# Fixed-memory ADWIN (exponential bucket histogram)
# ---------------------------------------------------------------------------

class AdwinState(NamedTuple):
    # buckets[l, m]: (count, sum) — level l holds buckets of size 2^l
    counts: jax.Array     # (L, M)
    sums: jax.Array       # (L, M)
    n_buckets: jax.Array  # (L,) used slots per level
    level: jax.Array


ADWIN_LEVELS = 12
ADWIN_M = 5               # buckets per level before merge (MOA default)


def adwin_init() -> AdwinState:
    return AdwinState(
        counts=jnp.zeros((ADWIN_LEVELS, ADWIN_M)),
        sums=jnp.zeros((ADWIN_LEVELS, ADWIN_M)),
        n_buckets=jnp.zeros((ADWIN_LEVELS,), jnp.int32),
        level=jnp.zeros((), jnp.int32),
    )


def _insert(counts, sums, n_buckets, c, s, lvl):
    """Insert bucket (c, s) at level lvl; cascade merges when full."""
    def body(carry, l):
        counts, sums, n_buckets, c, s, pending = carry
        here = jnp.logical_and(pending, l >= lvl)
        nb = n_buckets[l]
        room = nb < ADWIN_M

        def do_insert(args):
            counts, sums, n_buckets = args
            counts = counts.at[l, nb].set(c)
            sums = sums.at[l, nb].set(s)
            n_buckets = n_buckets.at[l].add(1)
            return counts, sums, n_buckets

        counts, sums, n_buckets = jax.lax.cond(
            jnp.logical_and(here, room), do_insert,
            lambda a: a, (counts, sums, n_buckets))
        inserted = jnp.logical_and(here, room)

        # merge two oldest into one bucket for the next level
        def do_merge(args):
            counts, sums, n_buckets = args
            mc = counts[l, 0] + counts[l, 1]
            ms = sums[l, 0] + sums[l, 1]
            counts = counts.at[l, :-2].set(counts[l, 2:]).at[l, -2:].set(0.0).at[l, ADWIN_M - 2].set(0.0)
            sums = sums.at[l, :-2].set(sums[l, 2:]).at[l, -2:].set(0.0)
            n_buckets = n_buckets.at[l].add(-2)
            return (counts, sums, n_buckets), mc, ms

        def no_merge(args):
            return args, 0.0, 0.0

        need_merge = jnp.logical_and(here, jnp.logical_not(room))
        (counts, sums, n_buckets), mc, ms = jax.lax.cond(
            need_merge, do_merge, no_merge, (counts, sums, n_buckets))
        # after a merge we must (a) insert the pending bucket here (room now)
        def insert_after_merge(args):
            counts, sums, n_buckets = args
            nb2 = n_buckets[l]
            counts = counts.at[l, nb2].set(c)
            sums = sums.at[l, nb2].set(s)
            n_buckets = n_buckets.at[l].add(1)
            return counts, sums, n_buckets
        counts, sums, n_buckets = jax.lax.cond(
            need_merge, insert_after_merge, lambda a: a,
            (counts, sums, n_buckets))
        # (b) cascade the merged bucket upward
        c = jnp.where(need_merge, mc, c)
        s = jnp.where(need_merge, ms, s)
        pending = jnp.where(here, need_merge, pending)
        return (counts, sums, n_buckets, c, s, pending), None

    (counts, sums, n_buckets, _, _, _), _ = jax.lax.scan(
        body, (counts, sums, n_buckets, c, s, jnp.asarray(True)),
        jnp.arange(ADWIN_LEVELS))
    return counts, sums, n_buckets


def adwin_step(state: AdwinState, x: jax.Array,
               delta: float = 0.002) -> Tuple[AdwinState, jax.Array]:
    counts, sums, n_buckets = _insert(
        state.counts, state.sums, state.n_buckets,
        jnp.asarray(1.0), x.astype(jnp.float32), jnp.asarray(0, jnp.int32))

    # drift check: scan cut points old->new (levels high..low); ADWIN cuts
    # where |mean_old - mean_new| exceeds eps(delta)
    total_n = counts.sum()
    total_s = sums.sum()
    # suffix accumulation over flattened (level-major, oldest=highest level)
    flat_c = counts[::-1].reshape(-1)
    flat_s = sums[::-1].reshape(-1)
    cum_c = jnp.cumsum(flat_c)
    cum_s = jnp.cumsum(flat_s)
    n0, s0 = cum_c, cum_s                    # "old" window prefix
    n1, s1 = total_n - cum_c, total_s - cum_s
    valid = (n0 >= 1) & (n1 >= 1)
    m0 = s0 / jnp.maximum(n0, 1.0)
    m1 = s1 / jnp.maximum(n1, 1.0)
    m = 1.0 / (1.0 / jnp.maximum(n0, 1.0) + 1.0 / jnp.maximum(n1, 1.0))
    dp = jnp.log(2.0 * jnp.log(jnp.maximum(total_n, 2.0)) / delta)
    eps = jnp.sqrt(dp / (2.0 * jnp.maximum(m, 1e-9)))  # Hoeffding, x in [0,1]
    cut = valid & (jnp.abs(m0 - m1) > eps)
    drift = jnp.any(cut)

    # on drift: drop the oldest half of the window (clear highest levels)
    def do_drop(args):
        counts, sums, n_buckets = args
        half = ADWIN_LEVELS // 2
        counts = counts.at[half:].set(0.0)
        sums = sums.at[half:].set(0.0)
        n_buckets = n_buckets.at[half:].set(0)
        return counts, sums, n_buckets

    counts, sums, n_buckets = jax.lax.cond(
        drift, do_drop, lambda a: a, (counts, sums, n_buckets))
    level = jnp.where(drift, DRIFT, STABLE).astype(jnp.int32)
    return AdwinState(counts, sums, n_buckets, level), level


# ---------------------------------------------------------------------------
# Batched stream evaluation
# ---------------------------------------------------------------------------

def run_detector(step_fn, init_state, xs: jax.Array):
    """Run a detector over a whole stream with lax.scan.
    Returns (final_state, levels (n,))."""
    return jax.lax.scan(step_fn, init_state, xs)
