"""Streaming preprocessing (S2CE Transformations component).

Instance/attribute transforms with O(1) running state: normalization
(Welford), missing-value imputation, streaming PCA-lite projection
(Oja's rule) for online dimensionality reduction (§2.5), and feature
hashing. All are (state, batch) -> (state, batch) pure functions, so they
can be placed on edge or cloud by the orchestrator interchangeably.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.streams.events import StreamBatch


# ---------------------------------------------------------------------------
# Running normalization (Welford)
# ---------------------------------------------------------------------------

class NormState(NamedTuple):
    n: jax.Array
    mean: jax.Array
    m2: jax.Array


def norm_init(dim: int) -> NormState:
    return NormState(jnp.zeros(()), jnp.zeros((dim,)), jnp.zeros((dim,)))


def norm_update_apply(state: NormState, x: jax.Array
                      ) -> Tuple[NormState, jax.Array]:
    """x: (n, d). Updates running stats with the batch, then normalizes."""
    n_b = x.shape[0]
    mean_b = jnp.mean(x, axis=0)
    m2_b = jnp.sum(jnp.square(x - mean_b), axis=0)
    n = state.n + n_b
    delta = mean_b - state.mean
    mean = state.mean + delta * (n_b / jnp.maximum(n, 1.0))
    m2 = state.m2 + m2_b + jnp.square(delta) * state.n * n_b / jnp.maximum(n, 1.0)
    var = m2 / jnp.maximum(n - 1.0, 1.0)
    y = (x - mean) * jax.lax.rsqrt(var + 1e-6)
    return NormState(n, mean, m2), y


# ---------------------------------------------------------------------------
# Missing-value imputation (NaN -> running mean)
# ---------------------------------------------------------------------------

def impute_with_mean(state: NormState, x: jax.Array) -> jax.Array:
    return jnp.where(jnp.isnan(x), state.mean[None, :], x)


def norm_impute_fused(state: NormState, x: jax.Array, *,
                      impute: bool = True,
                      use_kernel: Optional[bool] = None
                      ) -> Tuple[NormState, jax.Array]:
    """Impute + Welford update + normalize as ONE fused step.

    On TPU (or with Pallas interpret forced) this dispatches to the fused
    ``kernels.preprocess`` kernel — one pass over the batch, no (n, d)
    intermediates in HBM. Elsewhere it composes ``impute_with_mean`` +
    ``norm_update_apply``, so CPU results are bitwise the legacy path.
    The two paths are tolerance-equal (the kernel accumulates raw
    moments; the jnp path centers first)."""
    if use_kernel is None:
        use_kernel = kops.pallas_available()
    if use_kernel and kops.pallas_available():
        y, n1, mean1, m21 = kops.fused_normalize(
            x, state.n, state.mean, state.m2, impute=impute)
        return NormState(n1, mean1, m21), y
    if impute:
        x = impute_with_mean(state, x)
    return norm_update_apply(state, x)


# ---------------------------------------------------------------------------
# Online PCA-lite (Oja's rule) — streaming dimensionality reduction
# ---------------------------------------------------------------------------

class OjaState(NamedTuple):
    w: jax.Array          # (d, k) projection
    n: jax.Array


def oja_init(dim: int, k: int, seed: int = 0) -> OjaState:
    w = jax.random.normal(jax.random.PRNGKey(seed), (dim, k)) / jnp.sqrt(dim)
    return OjaState(w, jnp.zeros(()))


def oja_update_project(state: OjaState, x: jax.Array, lr: float = 1e-2
                       ) -> Tuple[OjaState, jax.Array]:
    """One Oja step on the batch covariance, then project."""
    y = x @ state.w                              # (n, k)
    grad = x.T @ y / x.shape[0]                  # (d, k)
    w = state.w + lr * (grad - state.w @ jnp.triu(state.w.T @ grad))
    # orthonormalize softly via QR every step (cheap for small k)
    q, r = jnp.linalg.qr(w)
    w = q * jnp.sign(jnp.diagonal(r))[None, :]
    return OjaState(w, state.n + x.shape[0]), x @ w


# ---------------------------------------------------------------------------
# Feature hashing (sparse/categorical -> fixed dim)
# ---------------------------------------------------------------------------

def hash_features(ids: jax.Array, vals: jax.Array, dim: int,
                  seed: int = 17, *,
                  use_kernel: Optional[bool] = None) -> jax.Array:
    """ids/vals: (n, f) -> dense (n, dim) via signed feature hashing.

    Dispatches to the Pallas one-hot-scatter kernel where available
    (bitwise-identical hash — pure int32 arithmetic both paths)."""
    if use_kernel is None:
        use_kernel = kops.pallas_available()
    if use_kernel and kops.pallas_available():
        return kops.hash_features(ids.astype(jnp.int32), vals,
                                  dim=dim, seed=seed).astype(vals.dtype)
    a = 2 * seed + 1
    h = (ids * a + 0x9E37) % 2_147_483_647
    slot = h % dim
    sign = jnp.where((h // dim) % 2 == 0, 1.0, -1.0)
    n, f = ids.shape
    out = jnp.zeros((n, dim), vals.dtype)
    return out.at[jnp.arange(n)[:, None], slot].add(vals * sign)


def preprocess_batch(state, batch: StreamBatch,
                     normalize: bool = True, impute: bool = True
                     ) -> Tuple[object, StreamBatch]:
    """The standard edge-side preprocessing pipeline for feature streams.

    When normalizing, routes through :func:`norm_impute_fused` so the
    whole impute+update+normalize step runs as one Pallas kernel on TPU
    (and stays the bitwise-identical legacy composition on CPU)."""
    x = batch.data["x"]
    if normalize:
        state, x = norm_impute_fused(state, x, impute=impute)
    elif impute:
        x = impute_with_mean(state, x)
    return state, batch.with_data(x=x)
