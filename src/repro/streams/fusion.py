"""Multi-stream fusion: time-window joins and delayed-label alignment
(S2CE Input Interface / Transformations; §2.5 delayed labels).

Host-side (numpy) ring buffers: fusion is an ingest-time, latency-bound
operation that runs before device dispatch. The joined output is a
StreamBatch ready for the device pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.streams.events import StreamBatch


@dataclass
class WindowJoin:
    """Join two streams on event time: for each left event, attach the
    nearest right event within `tolerance` seconds (as-of join).

    The ring is a TRUE circular buffer: a pair of preallocated numpy
    arrays (capacity ``2 * max_buffer``) with head/tail indices. A push
    writes in place at the tail and eviction just advances the head —
    amortized O(1) per event (the buffer compacts to the front at most
    once per ``max_buffer`` pushed events, instead of reallocating the
    whole ring on *every* push as the concatenate version did). The live
    window ``buf[head:tail]`` stays contiguous and time-sorted, so the
    as-of match remains one vectorized ``np.searchsorted`` over the whole
    left batch.
    """
    tolerance: float = 1.0
    max_buffer: int = 100_000
    _buf_t: Optional[np.ndarray] = field(default=None, repr=False)
    _buf_v: Optional[np.ndarray] = field(default=None, repr=False)
    _head: int = 0
    _tail: int = 0

    @property
    def _rt(self) -> np.ndarray:
        """The live (time-sorted, contiguous) timestamp window."""
        if self._buf_t is None:
            return np.empty(0, np.float64)
        return self._buf_t[self._head:self._tail]

    @property
    def _rv(self) -> Optional[np.ndarray]:
        if self._buf_v is None:
            return None
        return self._buf_v[self._head:self._tail]

    def push_right(self, batch: StreamBatch, key: str = "x"):
        ts = np.asarray(batch.ts, np.float64)
        vals = np.asarray(batch.data[key])
        if len(ts) > self.max_buffer:       # oversized push: newest survive
            ts, vals = ts[-self.max_buffer:], vals[-self.max_buffer:]
        n = len(ts)
        if self._buf_t is None:             # value width known on first push
            cap = max(2 * self.max_buffer, n)
            self._buf_t = np.empty(cap, np.float64)
            self._buf_v = np.empty((cap,) + vals.shape[1:], vals.dtype)
        cap = len(self._buf_t)
        want = np.promote_types(self._buf_v.dtype, vals.dtype)
        if want != self._buf_v.dtype:       # dtype widened mid-stream:
            self._buf_v = self._buf_v.astype(want)   # promote (rare; the
            # old concatenate path upcast the same way)
        if self._tail + n > cap:            # wrap: compact live window to 0
            live = self._tail - self._head
            self._buf_t[:live] = self._buf_t[self._head:self._tail]
            self._buf_v[:live] = self._buf_v[self._head:self._tail]
            self._head, self._tail = 0, live
        self._buf_t[self._tail:self._tail + n] = ts
        self._buf_v[self._tail:self._tail + n] = vals
        self._tail += n
        if self._tail - self._head > self.max_buffer:   # evict: O(1)
            self._head = self._tail - self.max_buffer

    def join_left(self, batch: StreamBatch, out_key: str = "joined"
                  ) -> Tuple[StreamBatch, np.ndarray]:
        """Returns (batch with `out_key` column, matched mask).

        Before the first ``push_right`` the value width is unknown and the
        joined column is width-0; once anything has been pushed the column
        keeps the right stream's value shape (zeros where unmatched), so
        downstream consumers see a stable shape from then on.
        """
        ts = np.asarray(batch.ts, np.float64)
        n_left, n_right = len(ts), len(self._rt)
        if n_right == 0:
            return (batch.with_data(**{out_key: np.zeros((n_left, 0),
                                                         np.float32)}),
                    np.zeros(n_left, bool))
        # nearest right neighbour of each left timestamp: one of the two
        # events bracketing the insertion point (ties prefer the later one,
        # matching the old scalar scan)
        j = np.searchsorted(self._rt, ts)
        jl = np.clip(j - 1, 0, n_right - 1)
        jr = np.clip(j, 0, n_right - 1)
        dl = np.where(j > 0, np.abs(self._rt[jl] - ts), np.inf)
        dr = np.where(j < n_right, np.abs(self._rt[jr] - ts), np.inf)
        use_r = dr <= dl
        best = np.where(use_r, jr, jl)
        dist = np.where(use_r, dr, dl)
        matched = dist <= self.tolerance
        out = np.zeros((n_left,) + self._rv.shape[1:], self._rv.dtype)
        out[matched] = self._rv[best[matched]]
        return batch.with_data(**{out_key: out}), matched


@dataclass
class DelayedLabelAligner:
    """Features arrive now; labels arrive `delay` seconds later. Buffers
    features until their label shows up, then emits joined batches —
    the §2.5 "verification latency" setting."""
    delay_tolerance: float = 0.5
    _pending: Dict[int, Tuple[float, np.ndarray]] = field(default_factory=dict)

    def push_features(self, ids: np.ndarray, ts: np.ndarray, x: np.ndarray):
        for i, t, xi in zip(ids, ts, x):
            self._pending[int(i)] = (float(t), xi)

    def push_labels(self, ids: np.ndarray, y: np.ndarray
                    ) -> Optional[StreamBatch]:
        xs, ys, tss = [], [], []
        for i, yi in zip(ids, y):
            hit = self._pending.pop(int(i), None)
            if hit is not None:
                tss.append(hit[0])
                xs.append(hit[1])
                ys.append(yi)
        if not xs:
            return None
        return StreamBatch(
            data={"x": np.stack(xs).astype(np.float32),
                  "y": np.asarray(ys, np.int32)},
            ts=np.asarray(tss), watermark=float(max(tss)))

    @property
    def backlog(self) -> int:
        return len(self._pending)
