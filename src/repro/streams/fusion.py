"""Multi-stream fusion: time-window joins and delayed-label alignment
(S2CE Input Interface / Transformations; §2.5 delayed labels).

Host-side (numpy) ring buffers: fusion is an ingest-time, latency-bound
operation that runs before device dispatch. The joined output is a
StreamBatch ready for the device pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.streams.events import StreamBatch


@dataclass
class WindowJoin:
    """Join two streams on event time: for each left event, attach the
    nearest right event within `tolerance` seconds (as-of join).

    The ring buffer is a pair of numpy arrays: eviction is a tail slice
    (amortized O(1) per event, versus the O(n^2) ``list.pop(0)`` loop this
    replaced) and the as-of match is one vectorized ``np.searchsorted``
    over the whole left batch instead of a Python double loop.
    """
    tolerance: float = 1.0
    max_buffer: int = 100_000
    _rt: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.float64))
    _rv: Optional[np.ndarray] = None

    def push_right(self, batch: StreamBatch, key: str = "x"):
        ts = np.asarray(batch.ts, np.float64)
        vals = np.asarray(batch.data[key])
        self._rt = np.concatenate([self._rt, ts])
        self._rv = (vals.copy() if self._rv is None
                    else np.concatenate([self._rv, vals]))
        if len(self._rt) > self.max_buffer:
            self._rt = self._rt[-self.max_buffer:]
            self._rv = self._rv[-self.max_buffer:]

    def join_left(self, batch: StreamBatch, out_key: str = "joined"
                  ) -> Tuple[StreamBatch, np.ndarray]:
        """Returns (batch with `out_key` column, matched mask).

        Before the first ``push_right`` the value width is unknown and the
        joined column is width-0; once anything has been pushed the column
        keeps the right stream's value shape (zeros where unmatched), so
        downstream consumers see a stable shape from then on.
        """
        ts = np.asarray(batch.ts, np.float64)
        n_left, n_right = len(ts), len(self._rt)
        if n_right == 0:
            return (batch.with_data(**{out_key: np.zeros((n_left, 0),
                                                         np.float32)}),
                    np.zeros(n_left, bool))
        # nearest right neighbour of each left timestamp: one of the two
        # events bracketing the insertion point (ties prefer the later one,
        # matching the old scalar scan)
        j = np.searchsorted(self._rt, ts)
        jl = np.clip(j - 1, 0, n_right - 1)
        jr = np.clip(j, 0, n_right - 1)
        dl = np.where(j > 0, np.abs(self._rt[jl] - ts), np.inf)
        dr = np.where(j < n_right, np.abs(self._rt[jr] - ts), np.inf)
        use_r = dr <= dl
        best = np.where(use_r, jr, jl)
        dist = np.where(use_r, dr, dl)
        matched = dist <= self.tolerance
        out = np.zeros((n_left,) + self._rv.shape[1:], self._rv.dtype)
        out[matched] = self._rv[best[matched]]
        return batch.with_data(**{out_key: out}), matched


@dataclass
class DelayedLabelAligner:
    """Features arrive now; labels arrive `delay` seconds later. Buffers
    features until their label shows up, then emits joined batches —
    the §2.5 "verification latency" setting."""
    delay_tolerance: float = 0.5
    _pending: Dict[int, Tuple[float, np.ndarray]] = field(default_factory=dict)

    def push_features(self, ids: np.ndarray, ts: np.ndarray, x: np.ndarray):
        for i, t, xi in zip(ids, ts, x):
            self._pending[int(i)] = (float(t), xi)

    def push_labels(self, ids: np.ndarray, y: np.ndarray
                    ) -> Optional[StreamBatch]:
        xs, ys, tss = [], [], []
        for i, yi in zip(ids, y):
            hit = self._pending.pop(int(i), None)
            if hit is not None:
                tss.append(hit[0])
                xs.append(hit[1])
                ys.append(yi)
        if not xs:
            return None
        return StreamBatch(
            data={"x": np.stack(xs).astype(np.float32),
                  "y": np.asarray(ys, np.int32)},
            ts=np.asarray(tss), watermark=float(max(tss)))

    @property
    def backlog(self) -> int:
        return len(self._pending)
