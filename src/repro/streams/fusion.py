"""Multi-stream fusion: time-window joins and delayed-label alignment
(S2CE Input Interface / Transformations; §2.5 delayed labels).

Host-side (numpy) ring buffers: fusion is an ingest-time, latency-bound
operation that runs before device dispatch. The joined output is a
StreamBatch ready for the device pipeline.
"""

from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.streams.events import StreamBatch


@dataclass
class WindowJoin:
    """Join two streams on event time: for each left event, attach the
    nearest right event within `tolerance` seconds (as-of join)."""
    tolerance: float = 1.0
    max_buffer: int = 100_000
    _rt: List[float] = field(default_factory=list)
    _rv: Deque = field(default_factory=deque)

    def push_right(self, batch: StreamBatch, key: str = "x"):
        ts = np.asarray(batch.ts)
        vals = np.asarray(batch.data[key])
        for t, v in zip(ts, vals):
            self._rt.append(float(t))
            self._rv.append(v)
        while len(self._rt) > self.max_buffer:
            self._rt.pop(0)
            self._rv.popleft()

    def join_left(self, batch: StreamBatch, out_key: str = "joined"
                  ) -> Tuple[StreamBatch, np.ndarray]:
        """Returns (batch with `out_key` column, matched mask)."""
        ts = np.asarray(batch.ts)
        vals = list(self._rv)
        matched = np.zeros(len(ts), bool)
        out = None
        for i, t in enumerate(ts):
            j = bisect.bisect_left(self._rt, t)
            best, bd = None, self.tolerance
            for jj in (j - 1, j):
                if 0 <= jj < len(self._rt):
                    d = abs(self._rt[jj] - t)
                    if d <= bd:
                        best, bd = jj, d
            if best is not None:
                matched[i] = True
                if out is None:
                    out = np.zeros((len(ts),) + np.shape(vals[best]),
                                   np.asarray(vals[best]).dtype)
                out[i] = vals[best]
        if out is None:
            out = np.zeros((len(ts), 0), np.float32)
        return batch.with_data(**{out_key: out}), matched


@dataclass
class DelayedLabelAligner:
    """Features arrive now; labels arrive `delay` seconds later. Buffers
    features until their label shows up, then emits joined batches —
    the §2.5 "verification latency" setting."""
    delay_tolerance: float = 0.5
    _pending: Dict[int, Tuple[float, np.ndarray]] = field(default_factory=dict)

    def push_features(self, ids: np.ndarray, ts: np.ndarray, x: np.ndarray):
        for i, t, xi in zip(ids, ts, x):
            self._pending[int(i)] = (float(t), xi)

    def push_labels(self, ids: np.ndarray, y: np.ndarray
                    ) -> Optional[StreamBatch]:
        xs, ys, tss = [], [], []
        for i, yi in zip(ids, y):
            hit = self._pending.pop(int(i), None)
            if hit is not None:
                tss.append(hit[0])
                xs.append(hit[1])
                ys.append(yi)
        if not xs:
            return None
        return StreamBatch(
            data={"x": np.stack(xs).astype(np.float32),
                  "y": np.asarray(ys, np.int32)},
            ts=np.asarray(tss), watermark=float(max(tss)))

    @property
    def backlog(self) -> int:
        return len(self._pending)
