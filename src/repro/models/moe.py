"""Mixture-of-Experts with grouped sort-based capacity dispatch (TPU-native).

No atomic scatters (TPU has none): token->expert routing is a stable sort
over expert ids + positional scatter into an (E, C, d) buffer. The dispatch
is *grouped*: tokens are reshaped to (G, t/G, d) where G matches the data
sharding, and the sort/scatter runs per group under vmap — every dispatch
op keeps a sharded leading dim, so GSPMD never replicates token tensors
(the ungrouped variant materialized unsharded (t*K, d) fp32 tensors; see
EXPERIMENTS.md §Perf for the before/after). Expert compute shards E over
the `experts` logical axis (expert parallelism); overflow beyond capacity
is dropped (GShard/Switch semantics). Shared experts run densely.

Returns the load-balancing auxiliary loss alongside the output.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import axis_size, shard
from repro.models.layers import _act
from repro.models.params import Spec


def moe_specs(cfg: ArchConfig):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    glu = cfg.mlp_act.endswith("_glu")
    sp = {
        "router": Spec((d, e.num_experts), ("embed", "experts"), scale=0.02),
        "w_up": Spec((e.num_experts, d, f), ("experts", "embed", "ff")),
        "w_down": Spec((e.num_experts, f, d), ("experts", "ff", "embed")),
    }
    if glu:
        sp["w_gate"] = Spec((e.num_experts, d, f), ("experts", "embed", "ff"))
    if e.num_shared:
        fs = e.d_ff_shared or e.num_shared * f
        sp["shared"] = {
            "w_up": Spec((d, fs), ("embed", "ff")),
            "w_down": Spec((fs, d), ("ff", "embed")),
        }
        if glu:
            sp["shared"]["w_gate"] = Spec((d, fs), ("embed", "ff"))
    return sp


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    e = cfg.moe
    c = int(n_tokens * e.top_k * e.capacity_factor / e.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8


def _dispatch_group(cfg: ArchConfig, C: int, xf, expert_ids, gate_vals):
    """Per-group dispatch. xf: (t,D); expert_ids/gate_vals: (t,K).
    Returns (buf (E,C,D), dest (t*K,), order (t*K,), keep (t*K,))."""
    e = cfg.moe
    t, D = xf.shape
    E, K = e.num_experts, e.top_k
    flat_e = expert_ids.reshape(-1)                                # (t*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    tok_of = order // K
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * K) - starts[sorted_e]
    keep = pos < C
    dest = jnp.where(keep, sorted_e * C + pos, E * C)              # overflow
    # scatter ONLY int32 indices (a data scatter would materialize a huge
    # u32 index broadcast under GSPMD); the payload moves via gather
    slot_to_assign = jnp.full((E * C + 1,), t * K, jnp.int32).at[dest].set(
        jnp.arange(t * K, dtype=jnp.int32))
    slot_tok = jnp.where(slot_to_assign[:-1] < t * K,
                         tok_of[jnp.minimum(slot_to_assign[:-1], t * K - 1)],
                         t)                                        # sentinel
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)])
    buf = xf_pad[slot_tok]                                         # (E*C, D)
    return buf.reshape(E, C, D), dest, order, keep


def _combine_group(out_buf, dest, order, keep, gate_flat, t, K, D):
    """out_buf: (E,C,D) -> y (t,D) weighted by gates (all gathers)."""
    flat_out = jnp.concatenate(
        [out_buf.reshape(-1, D), jnp.zeros((1, D), out_buf.dtype)])
    y_sorted = flat_out[dest] * gate_flat[order][:, None]          # (t*K,D)
    inv = jnp.argsort(order)                                       # assign->sorted pos
    y_assign = y_sorted[inv]
    return y_assign.reshape(t, K, D).sum(axis=1)


def apply_moe(p, cfg: ArchConfig, x: jax.Array,
              rng=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    e = cfg.moe
    B, S, D = x.shape
    t = B * S
    E, K = e.num_experts, e.top_k

    G = max(1, axis_size("expert_groups"))
    if t % G:
        G = 1
    tg = t // G
    xg = shard(x.reshape(G, tg, D), "expert_groups", None, None)

    logits = (xg @ p["router"].astype(xg.dtype)).astype(jnp.float32)
    if e.router_jitter and rng is not None:
        logits = logits + e.router_jitter * jax.random.normal(rng, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)                        # (G,tg,E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                # (G,tg,K)
    gate_vals = (gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)).astype(xg.dtype)

    # load-balance aux (Switch): E * sum_e f_e * P_e, averaged over groups
    me = jnp.mean(probs, axis=1)                                   # (G,E)
    fe = jnp.mean(jax.nn.one_hot(expert_ids[..., 0], E,
                                 dtype=jnp.float32), axis=1)       # (G,E)
    aux = e.aux_loss_coef * E * jnp.mean(jnp.sum(fe * me, axis=-1))

    C = _capacity(cfg, tg)
    buf, dest, order, keep = jax.vmap(
        lambda xf, ids, gv: _dispatch_group(cfg, C, xf, ids, gv)
    )(xg, expert_ids, gate_vals)
    buf = shard(buf, "expert_groups", "experts", None, None)       # (G,E,C,D)

    if "w_gate" in p:
        h = _act(cfg.mlp_act, jnp.einsum(
            "gecd,edf->gecf", buf, p["w_gate"].astype(buf.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(buf.dtype))
    else:
        h = _act(cfg.mlp_act, jnp.einsum(
            "gecd,edf->gecf", buf, p["w_up"].astype(buf.dtype)))
    h = shard(h, "expert_groups", "experts", None, "ff")
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(buf.dtype))
    out_buf = shard(out_buf, "expert_groups", "experts", None, None)

    y = jax.vmap(
        lambda ob, de, orr, ke, gf: _combine_group(ob, de, orr, ke, gf,
                                                   tg, K, D)
    )(out_buf, dest, order, keep, gate_vals.reshape(G, tg * K))
    y = shard(y, "expert_groups", None, None)
    y = y.reshape(B, S, D)

    if e.num_shared:
        sp = p["shared"]
        xf = x.reshape(t, D)
        if "w_gate" in sp:
            hs = _act(cfg.mlp_act, xf @ sp["w_gate"].astype(xf.dtype)) * (
                xf @ sp["w_up"].astype(xf.dtype))
        else:
            hs = _act(cfg.mlp_act, xf @ sp["w_up"].astype(xf.dtype))
        y = y + (hs @ sp["w_down"].astype(xf.dtype)).reshape(B, S, D)

    return y, aux
