"""Attention: GQA self-attention, MLA (DeepSeek latent), cross-attention.

Three execution paths, all numerically equivalent (tested against each other):

* ``dense``   — materialized scores; smoke tests / short sequences.
* ``chunked`` — lax.scan over KV blocks with online softmax; O(S * chunk)
                memory; the portable path used by dry-runs (compiles on any
                backend, XLA-fusable on TPU).
* ``pallas``  — the flash-attention kernel in :mod:`repro.kernels`
                (TPU target; validated in interpret mode).

GQA under tensor parallelism: when the `heads` logical axis maps to a mesh
axis wider than n_kv_heads, KV heads are repeated to `tp` virtual KV heads
(standard Megatron-GQA duplication) so both q and kv shard evenly.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import axis_size, shard
from repro.models.layers import apply_norm, apply_rope
from repro.models.params import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ArchConfig, cross: bool = False):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    sp = {
        "wq": Spec((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": Spec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": Spec((d, KV, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": Spec((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = Spec((H, Dh), ("heads", "head_dim"), "zeros")
        sp["bk"] = Spec((KV, Dh), ("kv_heads", "head_dim"), "zeros")
        sp["bv"] = Spec((KV, Dh), ("kv_heads", "head_dim"), "zeros")
    return sp


def mla_specs(cfg: ArchConfig):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qdim = m.nope_head_dim + m.rope_head_dim
    sp = {
        "w_dkv": Spec((d, m.kv_lora_rank), ("embed", "lora")),
        "w_kr": Spec((d, m.rope_head_dim), ("embed", "head_dim")),
        "kv_norm": Spec((m.kv_lora_rank,), ("lora",), "ones"),
        "w_uk": Spec((m.kv_lora_rank, H, m.nope_head_dim), ("lora", "heads", "head_dim")),
        "w_uv": Spec((m.kv_lora_rank, H, m.v_head_dim), ("lora", "heads", "head_dim")),
        "wo": Spec((H, m.v_head_dim, d), ("heads", "head_dim", "embed")),
    }
    if m.q_lora_rank:
        sp["w_dq"] = Spec((d, m.q_lora_rank), ("embed", "lora"))
        sp["q_norm"] = Spec((m.q_lora_rank,), ("lora",), "ones")
        sp["w_uq"] = Spec((m.q_lora_rank, H, qdim), ("lora", "heads", "head_dim"))
    else:
        sp["wq"] = Spec((d, H, qdim), ("embed", "heads", "head_dim"))
    return sp


# ---------------------------------------------------------------------------
# KV repeat for TP (Megatron-GQA duplication)
# ---------------------------------------------------------------------------

def kv_repeat_factor(cfg: ArchConfig) -> int:
    tp = axis_size("heads")
    if tp <= cfg.n_kv_heads:
        return 1
    rep = tp // cfg.n_kv_heads
    if (cfg.n_kv_heads * rep) > cfg.n_heads or cfg.n_heads % (cfg.n_kv_heads * rep):
        return 1  # cannot repeat evenly; fall back to plain GQA grouping
    return rep


def _expand_kv(k: jax.Array, rep: int) -> jax.Array:
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=2)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _group(q: jax.Array, n_kv: int):
    B, S, H, Dh = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, Dh)


def dense_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Materialized-scores attention. q:(B,S,H,Dh) k,v:(B,T,KV,Dh)."""
    B, S, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    qg = _group(q, KV)
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = _make_mask(S, T, causal, q_offset, kv_len, B)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, v.shape[-1]).astype(q.dtype)


def _make_mask(S, T, causal, q_offset, kv_len, B):
    """(B, S, T) bool validity mask."""
    qpos = jnp.arange(S)[:, None] + q_offset            # (S,1) (+ (B,1,1) if array)
    kpos = jnp.arange(T)[None, :]
    if isinstance(q_offset, jax.Array) and q_offset.ndim > 0:
        qpos = jnp.arange(S)[None, :, None] + q_offset.reshape(-1, 1, 1)
        kpos = kpos[None]
    m = jnp.ones((S, T), bool) if not causal else (kpos <= qpos)
    if m.ndim == 2:
        m = jnp.broadcast_to(m[None], (B, S, T))
    if kv_len is not None:
        kl = jnp.asarray(kv_len).reshape(-1, 1, 1)
        m = m & (jnp.arange(T)[None, None, :] < kl)
    return m


def chunked_attention(q, k, v, *, causal: bool, chunk: int = 512, q_offset=0,
                      kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention scanning KV blocks; O(S*chunk) memory."""
    B, S, H, Dh = q.shape
    T, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    chunk = min(chunk, T)
    nblk = -(-T // chunk)
    Tp = nblk * chunk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    qg = _group(q, KV).astype(jnp.float32)
    scale = 1.0 / math.sqrt(Dh)
    ks = jnp.moveaxis(k.reshape(B, nblk, chunk, KV, k.shape[-1]), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nblk, chunk, KV, Dv), 1, 0)

    qoff = jnp.asarray(q_offset)
    if qoff.ndim == 0:
        qpos_b = jnp.broadcast_to(jnp.arange(S)[None] + qoff, (B, S))
    else:
        qpos_b = jnp.arange(S)[None] + qoff.reshape(-1, 1)      # (B,S)
    kl = None if kv_len is None else jnp.asarray(kv_len).reshape(-1)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, blk = xs
        kpos = blk * chunk + jnp.arange(chunk)          # (chunk,)
        s = jnp.einsum("bskgd,bckd->bkgsc", qg, kb.astype(jnp.float32)) * scale
        valid = kpos[None, None, :] < T                  # padding
        if causal:
            valid = valid & (kpos[None, None, :] <= qpos_b[:, :, None])
        if kl is not None:
            valid = valid & (kpos[None, None, :] < kl[:, None, None])
        s = jnp.where(valid[:, None, None], s, NEG_INF)  # (B,KV,G,S,chunk)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgsc,bckd->bkgsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, KV, G, S), NEG_INF, jnp.float32),
            jnp.zeros((B, KV, G, S), jnp.float32),
            jnp.zeros((B, KV, G, S, Dv), jnp.float32))
    # nested remat: keep per-block fp32 score residuals out of the backward
    # save-list (flash-attention-style recompute)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init,
                                  (ks, vs, jnp.arange(nblk)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.moveaxis(o, 3, 1)                            # (B,S,KV,G,Dv)
    return o.reshape(B, S, H, Dv).astype(q.dtype)


def attention(q, k, v, *, causal: bool, impl: str = "dense", chunk: int = 512,
              q_offset=0, kv_len=None) -> jax.Array:
    if impl == "pallas":
        from repro.kernels import ops as kops
        if kops.flash_supported(q, k, v, causal, q_offset, kv_len):
            return kops.flash_attention(q, k, v, causal=causal)
        impl = "chunked"
    if impl == "chunked" and k.shape[1] > chunk:
        return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                 q_offset=q_offset, kv_len=kv_len)
    return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                           kv_len=kv_len)


# ---------------------------------------------------------------------------
# Self-attention block (GQA)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, T, KV, Dh)
    v: jax.Array
    length: jax.Array     # () int32 — filled prefix


def _project(p, cfg, x, name):
    w = p["w" + name]
    y = jnp.einsum("bsd,dhe->bshe", x, w.astype(x.dtype))
    if cfg.qkv_bias and ("b" + name) in p:
        y = y + p["b" + name].astype(x.dtype)
    return y


def self_attention(p, cfg: ArchConfig, x: jax.Array, *, positions,
                   cache: Optional[KVCache] = None, causal: bool = True,
                   impl: str = "chunked"):
    """x: (B,S,D). Returns (out, new_cache)."""
    q = _project(p, cfg, x, "q")
    k = _project(p, cfg, x, "k")
    v = _project(p, cfg, x, "v")
    if cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)

    new_cache = None
    kv_len = None
    if isinstance(positions, jax.Array):
        q_offset = positions[:, 0] if positions.ndim == 2 else positions[0]
    else:
        q_offset = positions
    if cache is not None:
        k_all = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), cache.length, axis=1)
        v_all = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), cache.length, axis=1)
        new_cache = KVCache(k_all, v_all, cache.length + k.shape[1])
        k, v = k_all.astype(x.dtype), v_all.astype(x.dtype)
        kv_len = cache.length + q.shape[1]
        q_offset = cache.length
    rep = kv_repeat_factor(cfg)
    k = shard(_expand_kv(k, rep), "batch", "kv_seq", "heads" if rep > 1 else "kv_heads", None)
    v = shard(_expand_kv(v, rep), "batch", "kv_seq", "heads" if rep > 1 else "kv_heads", None)

    o = attention(q, k, v, causal=causal, impl=impl, chunk=cfg.attn_chunk,
                  q_offset=q_offset, kv_len=kv_len)
    o = shard(o, "batch", None, "heads", None)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    KV, Dh = cfg.n_kv_heads, cfg.d_head
    return KVCache(
        k=jnp.zeros((batch, max_len, KV, Dh), dtype),
        v=jnp.zeros((batch, max_len, KV, Dh), dtype),
        length=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    c_kv: jax.Array       # (B, T, r)  compressed latent
    k_rope: jax.Array     # (B, T, dr) shared rope key
    length: jax.Array


def mla_attention(p, cfg: ArchConfig, x: jax.Array, *, positions,
                  cache: Optional[MLACache] = None, impl: str = "chunked"):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim

    if m.q_lora_rank:
        cq = x @ p["w_dq"]
        cq = cq * jax.lax.rsqrt(jnp.mean(jnp.square(cq.astype(jnp.float32)),
                                         -1, keepdims=True) + cfg.norm_eps).astype(x.dtype)
        q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c = x @ p["w_dkv"]                                   # (B,S,r)
    cf = c.astype(jnp.float32)
    c = (cf * jax.lax.rsqrt(jnp.mean(jnp.square(cf), -1, keepdims=True)
                            + cfg.norm_eps) * p["kv_norm"].astype(jnp.float32)
         ).astype(x.dtype)
    kr = apply_rope((x @ p["w_kr"])[:, :, None, :], positions, cfg.rope_theta)
    kr = kr[:, :, 0, :]                                  # (B,S,dr)

    q_offset = 0
    kv_len = None
    if cache is not None:
        c_all = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c.astype(cache.c_kv.dtype), cache.length, axis=1)
        kr_all = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr.astype(cache.k_rope.dtype), cache.length, axis=1)
        new_cache = MLACache(c_all, kr_all, cache.length + S)
        c, kr = c_all.astype(x.dtype), kr_all.astype(x.dtype)
        kv_len = cache.length + S
        q_offset = cache.length
    else:
        new_cache = None

    # expand latent -> per-head keys/values (naive path; absorbed variant is a
    # perf iteration, see EXPERIMENTS.md §Perf)
    k_nope = jnp.einsum("btr,rhe->bthe", c, p["w_uk"].astype(x.dtype))
    vv = jnp.einsum("btr,rhe->bthe", c, p["w_uv"].astype(x.dtype))
    T = k_nope.shape[1]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[:, :, None, :], (B, T, H, dr))], -1)
    qq = jnp.concatenate([q_nope, q_rope], -1)
    qq = shard(qq, "batch", None, "heads", None)
    k = shard(k, "batch", "kv_seq", "heads", None)
    vv = shard(vv, "batch", "kv_seq", "heads", None)

    o = attention(qq, k, vv, causal=True, impl=impl, chunk=cfg.attn_chunk,
                  q_offset=q_offset, kv_len=kv_len)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.rope_head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Cross-attention (enc-dec / VLM)
# ---------------------------------------------------------------------------

class CrossCache(NamedTuple):
    k: jax.Array          # (B, T_src, KV, Dh) — precomputed from memory
    v: jax.Array


def cross_attention(p, cfg: ArchConfig, x: jax.Array,
                    memory: Optional[jax.Array] = None,
                    cache: Optional[CrossCache] = None,
                    impl: str = "chunked"):
    """K/V from `memory` (encoder output / image embeds) or from `cache`."""
    q = _project(p, cfg, x, "q")
    q = shard(q, "batch", None, "heads", None)
    if cache is None:
        assert memory is not None
        k = _project(p, cfg, memory, "k")
        v = _project(p, cfg, memory, "v")
        new_cache = CrossCache(k, v)
    else:
        k, v = cache.k.astype(x.dtype), cache.v.astype(x.dtype)
        new_cache = cache
    rep = kv_repeat_factor(cfg)
    k = shard(_expand_kv(k, rep), "batch", None, "heads" if rep > 1 else "kv_heads", None)
    v = shard(_expand_kv(v, rep), "batch", None, "heads" if rep > 1 else "kv_heads", None)
    o = attention(q, k, v, causal=False, impl=impl, chunk=cfg.attn_chunk)
    out = jnp.einsum("bshe,hed->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache
