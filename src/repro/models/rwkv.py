"""RWKV6 ("Finch") mixer: data-dependent decay WKV recurrence + channel mix.

Chunked evaluation: within a chunk the pairwise decay exponent
L_excl[t] - L_incl[s] (s < t) is always <= 0, so the intra-chunk part is
computed in a numerically safe pairwise form (no exp overflow, unlike the
factored q'k' form); inter-chunk contributions flow through the per-head
state S (hs_k x hs_v). The Pallas kernel (:mod:`repro.kernels.rwkv6_wkv`)
tiles the same math into VMEM.

Decode state per layer: (tm_shift (B,D), cm_shift (B,D), wkv (B,H,hk,hv)).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models.layers import groupnorm_heads
from repro.models.params import Spec


class RWKVState(NamedTuple):
    tm_shift: jax.Array   # (B, D) last input to time-mix
    cm_shift: jax.Array   # (B, D) last input to channel-mix
    wkv: jax.Array        # (B, H, hs, hs) fp32


_MIX_NAMES = ("w", "k", "v", "r", "g")


def rwkv_time_mix_specs(cfg: ArchConfig):
    c = cfg.rwkv
    d, H, hs = cfg.d_model, cfg.n_heads, c.head_size
    return {
        "mu_x": Spec((d,), ("embed",), "zeros"),
        "mu": Spec((5, d), (None, "embed"), "zeros"),
        "mix_w1": Spec((d, 5 * c.mix_lora), ("embed", "lora"), scale=0.02),
        "mix_w2": Spec((5, c.mix_lora, d), (None, "lora", "embed"), scale=0.02),
        "w0": Spec((d,), ("embed",), "constant", const=-2.0),
        "dec_w1": Spec((d, c.decay_lora), ("embed", "lora"), scale=0.02),
        "dec_w2": Spec((c.decay_lora, d), ("lora", "embed"), scale=0.02),
        "u": Spec((H, hs), ("heads", None), scale=0.5),
        "wr": Spec((d, d), ("embed", "dinner")),
        "wk": Spec((d, d), ("embed", "dinner")),
        "wv": Spec((d, d), ("embed", "dinner")),
        "wg": Spec((d, d), ("embed", "dinner")),
        "wo": Spec((d, d), ("dinner", "embed")),
        "lnx_scale": Spec((d,), ("embed",), "ones"),
        "lnx_bias": Spec((d,), ("embed",), "zeros"),
    }


def rwkv_channel_mix_specs(cfg: ArchConfig):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": Spec((d,), ("embed",), "zeros"),
        "mu_r": Spec((d,), ("embed",), "zeros"),
        "wk": Spec((d, f), ("embed", "ff")),
        "wv": Spec((f, d), ("ff", "embed")),
        "wr": Spec((d, d), ("embed", "dinner")),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """xx[t] = x[t-1]; xx[0] = prev (or 0). x:(B,S,D), prev:(B,D)."""
    first = (prev if prev is not None
             else jnp.zeros((x.shape[0], x.shape[2]), x.dtype))[:, None, :]
    return jnp.concatenate([first.astype(x.dtype), x[:, :-1, :]], axis=1)


def wkv_chunked(r, k, v, lw, u, h0, chunk: int):
    """RWKV6 WKV, chunked. r,k,v: (B,S,H,hs); lw: (B,S,H,hs) log-decay (<=0);
    u: (H,hs); h0: (B,H,hs,hs) fp32. Returns (out (B,S,H,hs), h_last)."""
    B, S, H, hs = r.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S
    n = S // chunk
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lwf = lw.astype(jnp.float32)

    def body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
        rc, kc, vc, lc = sl(rf), sl(kf), sl(vf), sl(lwf)
        L = jnp.cumsum(lc, axis=1)                    # inclusive (B,Lc,H,hs)
        L_excl = L - lc
        # inter-chunk: o_t += (r_t * exp(L_excl_t)) @ h
        q_in = rc * jnp.exp(L_excl)
        o = jnp.einsum("blhi,bhij->blhj", q_in, h)
        # intra-chunk (pairwise-stable): exponent L_excl[t]-L[s] <= 0 for s<t
        dpair = jnp.exp(jnp.minimum(L_excl[:, :, None] - L[:, None], 0.0))
        # (B,t,s,H,hs)
        scores = jnp.einsum("blhi,blshi,bshi->blsh", rc, dpair, kc)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
        scores = scores * tri[None, :, :, None]
        o = o + jnp.einsum("blsh,bshj->blhj", scores, vc)
        # diagonal bonus: (r_t . (u*k_t)) v_t
        diag = jnp.einsum("blhi,hi,blhi->blh", rc, u.astype(jnp.float32), kc)
        o = o + diag[..., None] * vc
        # state update: h' = exp(L_end)*h + sum_s exp(L_end - L_s) k_s v_s^T
        L_end = L[:, -1]                              # (B,H,hs)
        kdec = kc * jnp.exp(L_end[:, None] - L)
        h_new = jnp.exp(L_end)[..., None] * h + jnp.einsum(
            "bshi,bshj->bhij", kdec, vc)
        return h_new, o

    body = jax.checkpoint(body)   # nested remat: see ssm.py chunk_body note
    if n == 1:
        h_last, out = body(h0, 0)
    else:
        h_last, outs = jax.lax.scan(body, h0, jnp.arange(n))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hs)
    return out.astype(r.dtype), h_last


def rwkv_time_mix(p, cfg: ArchConfig, x: jax.Array,
                  state: Optional[RWKVState] = None,
                  impl: str = "chunked"
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, new_tm_shift, new_wkv_state)."""
    c = cfg.rwkv
    B, S, D = x.shape
    H, hs = cfg.n_heads, c.head_size

    xx = _token_shift(x, state.tm_shift if state else None)
    dx = xx - x
    xxx = x + dx * p["mu_x"].astype(x.dtype)
    lo = jnp.tanh(xxx @ p["mix_w1"].astype(x.dtype))
    lo = lo.reshape(B, S, 5, c.mix_lora)
    deltas = jnp.einsum("bsrm,rmd->bsrd", lo, p["mix_w2"].astype(x.dtype))
    mixed = {name: x + dx * (p["mu"][i].astype(x.dtype) + deltas[:, :, i])
             for i, name in enumerate(_MIX_NAMES)}

    r = (mixed["r"] @ p["wr"].astype(x.dtype)).reshape(B, S, H, hs)
    k = (mixed["k"] @ p["wk"].astype(x.dtype)).reshape(B, S, H, hs)
    v = (mixed["v"] @ p["wv"].astype(x.dtype)).reshape(B, S, H, hs)
    g = jax.nn.silu(mixed["g"] @ p["wg"].astype(x.dtype))
    r = shard(r, "batch", None, "heads", None)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)

    dec = jnp.tanh(mixed["w"] @ p["dec_w1"].astype(x.dtype)) @ p["dec_w2"].astype(x.dtype)
    lw = -jnp.exp((p["w0"].astype(jnp.float32) + dec.astype(jnp.float32)))
    lw = lw.reshape(B, S, H, hs)                       # log decay, < 0

    h0 = state.wkv if state is not None else jnp.zeros((B, H, hs, hs), jnp.float32)
    if impl == "pallas":
        from repro.kernels import ops as kops
        o, h_last = kops.rwkv6_wkv(r, k, v, lw, p["u"], h0, chunk=c.chunk)
    else:
        o, h_last = wkv_chunked(r, k, v, lw, p["u"], h0, c.chunk)

    o = groupnorm_heads(p["lnx_scale"], p["lnx_bias"], o.reshape(B, S, D),
                        H, cfg.norm_eps)
    o = o * g
    out = o @ p["wo"].astype(x.dtype)
    return out, x[:, -1, :], h_last


def rwkv_channel_mix(p, cfg: ArchConfig, x: jax.Array,
                     state: Optional[RWKVState] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    xx = _token_shift(x, state.cm_shift if state else None)
    dx = xx - x
    xk = x + dx * p["mu_k"].astype(x.dtype)
    xr = x + dx * p["mu_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["wk"].astype(x.dtype)))
    kk = shard(kk, "batch", None, "ff")
    vv = kk @ p["wv"].astype(x.dtype)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(x.dtype)) * vv
    return out, x[:, -1, :]


def init_rwkv_state(cfg: ArchConfig, batch: int) -> RWKVState:
    H, hs = cfg.n_heads, cfg.rwkv.head_size
    return RWKVState(
        tm_shift=jnp.zeros((batch, cfg.d_model), jnp.float32),
        cm_shift=jnp.zeros((batch, cfg.d_model), jnp.float32),
        wkv=jnp.zeros((batch, H, hs, hs), jnp.float32),
    )
