"""Unified LM assembly for every assigned architecture family.

A config compiles to a *layer plan*: a short unscanned prefix plus a
periodic pattern of per-layer "slots" scanned over stacked parameters
(keeps HLO size independent of depth — essential for 88–100-layer dry-run
compiles). Slot mixers: attn | mla | cross | attn_cross | mamba | rwkv;
slot MLPs: dense | moe | rwkv_cm | none.

Families:
  dense/moe      -> decoder-only stack
  rwkv/ssm       -> recurrent mixers, O(1) decode state
  hybrid (jamba) -> periodic (7 mamba + 1 attn), alternating MoE
  vlm            -> gated cross-attention layer every N (image stub memory)
  encdec         -> bidirectional encoder stack + decoder with cross-attn
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_mlp, apply_norm, cot_cast, dtype_of, embed_specs, embed_tokens,
    lm_logits, mlp_specs, norm_specs, sincos_pos_embed,
)
from repro.models.params import Spec


# ---------------------------------------------------------------------------
# Layer plans
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Slot:
    mixer: str            # attn|mla|cross|attn_cross|mamba|rwkv
    mlp: str              # dense|moe|rwkv_cm|none
    causal: bool = True
    gated: bool = False   # vlm-style gated cross layer


def _slot_list(cfg: ArchConfig, n_layers: int, decoder: bool = True):
    moe_mask = cfg.moe_layer_mask(n_layers)
    attn_mask = cfg.attn_layer_mask() if cfg.family == "hybrid" else None
    cross_mask = cfg.cross_layer_mask() if cfg.family == "vlm" else None
    slots = []
    for i in range(n_layers):
        mlp = "moe" if (moe_mask[i] and cfg.moe.num_experts) else "dense"
        if cfg.family == "rwkv":
            slots.append(Slot("rwkv", "rwkv_cm"))
        elif cfg.family == "ssm":
            slots.append(Slot("mamba", mlp))
        elif cfg.family == "hybrid":
            slots.append(Slot("attn" if attn_mask[i] else "mamba", mlp))
        elif cfg.family == "vlm":
            slots.append(Slot("cross", mlp, gated=True) if cross_mask[i]
                         else Slot("attn", mlp))
        elif cfg.family == "encdec" and decoder:
            slots.append(Slot("attn_cross", mlp))
        elif cfg.family == "encdec":
            slots.append(Slot("attn", mlp, causal=False))
        else:
            slots.append(Slot("mla" if cfg.mla is not None else "attn", mlp))
    return slots


def layer_plan(cfg: ArchConfig, n_layers: int, decoder: bool = True):
    """-> (prefix_slots, repeat, pattern_slots)."""
    slots = _slot_list(cfg, n_layers, decoder)
    for prefix in range(0, min(4, n_layers)):
        rest = slots[prefix:]
        if not rest:
            continue
        for period in range(1, min(len(rest), 16) + 1):
            if len(rest) % period:
                continue
            if all(rest[i] == rest[i % period] for i in range(len(rest))):
                if len(rest) // period == 1 and period > 1:
                    continue  # prefer true repetition over one fat block
                return tuple(slots[:prefix]), len(rest) // period, tuple(rest[:period])
    return tuple(slots), 0, ()


# ---------------------------------------------------------------------------
# Per-slot specs
# ---------------------------------------------------------------------------

def _mixer_specs(cfg: ArchConfig, slot: Slot):
    if slot.mixer in ("attn", "cross"):
        return attn.attn_specs(cfg)
    if slot.mixer == "mla":
        return attn.mla_specs(cfg)
    if slot.mixer == "attn_cross":
        return {"self": attn.attn_specs(cfg), "cross": attn.attn_specs(cfg)}
    if slot.mixer == "mamba":
        return ssm_mod.mamba_specs(cfg)
    if slot.mixer == "rwkv":
        return rwkv_mod.rwkv_time_mix_specs(cfg)
    raise ValueError(slot.mixer)


def _mlp_specs(cfg: ArchConfig, slot: Slot):
    if slot.mlp == "dense":
        return mlp_specs(cfg)
    if slot.mlp == "moe":
        return moe_mod.moe_specs(cfg)
    if slot.mlp == "rwkv_cm":
        return rwkv_mod.rwkv_channel_mix_specs(cfg)
    return {}


def slot_specs(cfg: ArchConfig, slot: Slot):
    sp = {"norm1": norm_specs(cfg), "mixer": _mixer_specs(cfg, slot)}
    if slot.mixer == "attn_cross":
        sp["norm_cross"] = norm_specs(cfg)
    if slot.mlp != "none":
        sp["norm2"] = norm_specs(cfg)
        sp["mlp"] = _mlp_specs(cfg, slot)
    if slot.gated:
        sp["gate_attn"] = Spec((), (), "zeros")
        sp["gate_mlp"] = Spec((), (), "zeros")
    return sp


def _stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale, s.const),
        tree, is_leaf=lambda x: isinstance(x, Spec))


def model_specs(cfg: ArchConfig):
    sp: dict = {"embed": embed_specs(cfg), "final_norm": norm_specs(cfg)}
    if cfg.family == "encdec":
        pre_e, rep_e, pat_e = layer_plan(cfg, cfg.enc_layers, decoder=False)
        pre_d, rep_d, pat_d = layer_plan(cfg, cfg.dec_layers, decoder=True)
        sp["enc"] = {
            "prefix": [slot_specs(cfg, s) for s in pre_e],
            "stack": _stack_specs([slot_specs(cfg, s) for s in pat_e], rep_e),
            "final_norm": norm_specs(cfg),
        }
        sp["dec"] = {
            "prefix": [slot_specs(cfg, s) for s in pre_d],
            "stack": _stack_specs([slot_specs(cfg, s) for s in pat_d], rep_d),
        }
    else:
        pre, rep, pat = layer_plan(cfg, cfg.n_layers)
        sp["prefix"] = [slot_specs(cfg, s) for s in pre]
        sp["stack"] = _stack_specs([slot_specs(cfg, s) for s in pat], rep)
    if cfg.frontend != "none":
        sp["frontend_proj"] = Spec((cfg.frontend_dim, cfg.d_model),
                                   ("embed", None))
    return sp


# ---------------------------------------------------------------------------
# Slot application
# ---------------------------------------------------------------------------

def apply_slot(p, cfg: ArchConfig, slot: Slot, x, *, positions, memory,
               cache, impl: str):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    # norm on the seq-sharded residual (fp32 internals stay 1/16-seq),
    # then gather the bf16 norm output for the TP matmuls
    h = apply_norm(p["norm1"], cfg, x)
    h = shard(h, "batch", None, "embed")
    new_cache = cache

    if slot.mixer == "attn":
        o, kv = attn.self_attention(
            p["mixer"], cfg, h, positions=positions,
            cache=cache.get("kv") if cache else None,
            causal=slot.causal, impl=impl)
        new_cache = {"kv": kv} if cache else None
    elif slot.mixer == "mla":
        o, kv = attn.mla_attention(
            p["mixer"], cfg, h, positions=positions,
            cache=cache.get("kv") if cache else None, impl=impl)
        new_cache = {"kv": kv} if cache else None
    elif slot.mixer == "cross":
        o, cc = attn.cross_attention(
            p["mixer"], cfg, h, memory=memory,
            cache=cache.get("cross") if cache and cache.get("cross") is not None else None,
            impl=impl)
        new_cache = {"cross": cc} if cache else None
    elif slot.mixer == "attn_cross":
        o, kv = attn.self_attention(
            p["mixer"]["self"], cfg, h, positions=positions,
            cache=cache.get("kv") if cache else None,
            causal=slot.causal, impl=impl)
        o = shard(o, "batch", "seq_sp", "embed")   # reduce-scatter form
        x = x + o
        h2 = apply_norm(p["norm_cross"], cfg, x)
        h2 = shard(h2, "batch", None, "embed")
        o, cc = attn.cross_attention(
            p["mixer"]["cross"], cfg, h2, memory=memory,
            cache=cache.get("cross") if cache and cache.get("cross") is not None else None,
            impl=impl)
        new_cache = {"kv": kv, "cross": cc} if cache else None
    elif slot.mixer == "mamba":
        st = cache.get("mamba") if cache else None
        if st is not None and x.shape[1] == 1:
            o, st = ssm_mod.mamba_decode_step(p["mixer"], cfg, h, st)
        else:
            o, st = ssm_mod.mamba_mixer(p["mixer"], cfg, h, st)
        new_cache = {"mamba": st} if cache else None
    elif slot.mixer == "rwkv":
        st = cache.get("rwkv") if cache else None
        o, tm_shift, wkv = rwkv_mod.rwkv_time_mix(p["mixer"], cfg, h, st, impl=impl)
        cm_prev = st.cm_shift if st is not None else None
    else:
        raise ValueError(slot.mixer)

    if slot.gated:
        o = o * jnp.tanh(p["gate_attn"].astype(o.dtype))
    if slot.mixer == "rwkv":
        o = shard(o, "batch", "seq_sp", "embed")
        x = x + o
        h = apply_norm(p["norm2"], cfg, x)
        h = shard(h, "batch", None, "embed")
        st_in = st if st is not None else None
        o2, cm_shift = rwkv_mod.rwkv_channel_mix(
            p["mlp"], cfg, h,
            rwkv_mod.RWKVState(tm_shift, cm_prev, wkv) if st_in is not None else None)
        x = x + o2
        if cache:
            new_cache = {"rwkv": rwkv_mod.RWKVState(tm_shift, cm_shift, wkv)}
        x = shard(cot_cast(x), "batch", "seq_sp", "embed")
        return x, new_cache, aux

    o = shard(o, "batch", "seq_sp", "embed")       # reduce-scatter form
    x = x + o
    if slot.mlp != "none":
        h = apply_norm(p["norm2"], cfg, x)
        h = shard(h, "batch", None, "embed")
        if slot.mlp == "moe":
            o2, a = moe_mod.apply_moe(p["mlp"], cfg, h)
            aux = aux + a
        else:
            o2 = apply_mlp(p["mlp"], cfg, h)
        if slot.gated:
            o2 = o2 * jnp.tanh(p["gate_mlp"].astype(o2.dtype))
        o2 = shard(o2, "batch", "seq_sp", "embed")
        x = x + o2
    x = shard(cot_cast(x), "batch", "seq_sp", "embed")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stack runner (scan over stacked params / caches)
# ---------------------------------------------------------------------------

def _remat_wrap(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


def _constrain_layer_params(lp, axes):
    """Pin each sliced per-layer param to its sharded layout inside the scan
    body. Without this the SPMD partitioner may reshard (all-gather) the
    ENTIRE stacked parameter tree at the while-loop boundary — 100s of GB
    for frontier-scale stacks (observed on jamba-398B, see EXPERIMENTS.md)."""
    if axes is None:
        return lp
    from repro.dist import shard_param
    return jax.tree.map(
        lambda x, ax: shard_param(x, ax[1:]) if hasattr(x, "ndim") and
        x.ndim + 1 == len(ax) else x, lp, axes)


@jax.custom_vjp
def _diff_barrier(tree):
    """optimization_barrier with a differentiation rule (jax<=0.4.37 has
    none): barrier the primals forward and the cotangents backward, so the
    gather-serialization effect holds in both passes."""
    return jax.lax.optimization_barrier(tree)


def _diff_barrier_fwd(tree):
    return _diff_barrier(tree), None


def _diff_barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


_diff_barrier.defvjp(_diff_barrier_fwd, _diff_barrier_bwd)


def run_stack(params, cfg: ArchConfig, pattern, x, *, positions, memory,
              caches, impl, stack_axes=None):
    """params: stacked slot-param list; caches: stacked cache trees or None."""
    n_slots = len(pattern)

    def body(x, layer_params, layer_caches):
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i, slot in enumerate(pattern):
            if i:
                # serialize weight-gathers across unrolled slots: slot i+1's
                # FSDP all-gather must wait for slot i's output, otherwise
                # every slot's full weights are live simultaneously
                x, layer_params = _diff_barrier((x, layer_params))
            c = layer_caches[i] if layer_caches is not None else None
            x, nc, a = apply_slot(layer_params[i], cfg, slot, x,
                                  positions=positions, memory=memory,
                                  cache=c, impl=impl)
            aux = aux + a
            new_caches.append(nc)
        return x, new_caches, aux

    body = _remat_wrap(body, cfg)

    if caches is None:
        def scan_body(x, lp):
            # barrier + per-leaf constraints pin the per-layer param slice
            # inside the loop so XLA cannot hoist FSDP all-gathers of the
            # whole stack out of the scan
            lp = _diff_barrier(lp)
            lp = _constrain_layer_params(lp, stack_axes)
            x, _, aux = body(x, lp, None)
            return x, aux
        x, auxs = jax.lax.scan(scan_body, x, params)
        return x, None, jnp.sum(auxs)

    def scan_body(x, xs):
        lp, lc = xs
        lp = _diff_barrier(lp)
        lp = _constrain_layer_params(lp, stack_axes)
        x, nc, aux = body(x, lp, lc)
        return x, (nc, aux)
    x, (new_caches, auxs) = jax.lax.scan(scan_body, x, (params, caches))
    return x, new_caches, jnp.sum(auxs)


def run_prefix(params, cfg: ArchConfig, slots, x, *, positions, memory,
               caches, impl):
    aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, slot in enumerate(slots):
        c = caches[i] if caches is not None else None
        x, nc, a = apply_slot(params[i], cfg, slot, x, positions=positions,
                              memory=memory, cache=c, impl=impl)
        new_caches.append(nc)
        aux = aux + a
    return x, (new_caches if caches is not None else None), aux


# ---------------------------------------------------------------------------
# Frontend stubs
# ---------------------------------------------------------------------------

def stack_axes_for(cfg: ArchConfig, which: str = "stack"):
    """Logical-axes tree for the scanned layer stack (sharding pins)."""
    from repro.models import params as pmod
    sp = model_specs(cfg)
    node = sp
    for k in which.split("/"):
        node = node[k]
    return pmod.axes_of(node)


def frontend_memory(params, cfg: ArchConfig, batch: dict):
    """Project stubbed modality embeddings into d_model memory tokens."""
    if cfg.frontend == "none":
        return None
    key = "frames" if cfg.frontend == "audio_frames" else "patches"
    emb = batch[key]
    mem = emb.astype(dtype_of(cfg.compute_dtype)) @ params["frontend_proj"].astype(
        dtype_of(cfg.compute_dtype))
    return shard(mem, "batch", None, "embed")


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _positions(B, S, offset=0):
    return jnp.arange(S)[None, :] + jnp.asarray(offset).reshape(-1, 1)


def forward_lm(params, cfg: ArchConfig, batch: dict, *, impl: str = "chunked"):
    """Training/eval forward. Returns (logits fp32, aux_loss)."""
    if cfg.family == "encdec":
        return _forward_encdec(params, cfg, batch, impl=impl)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params["embed"], cfg, tokens)
    if cfg.pos_embed == "sincos":
        x = x + sincos_pos_embed(S, cfg.d_model).astype(x.dtype)[None]
    memory = frontend_memory(params, cfg, batch)
    pre, rep, pat = layer_plan(cfg, cfg.n_layers)
    positions = _positions(B, S)
    x, _, aux1 = run_prefix(params["prefix"], cfg, pre, x,
                            positions=positions, memory=memory, caches=None,
                            impl=impl)
    aux2 = jnp.zeros((), jnp.float32)
    if rep:
        x, _, aux2 = run_stack(params["stack"], cfg, pat, x,
                               positions=positions, memory=memory,
                               caches=None, impl=impl,
                               stack_axes=stack_axes_for(cfg))
    x = apply_norm(params["final_norm"], cfg, x)
    return lm_logits(params["embed"], cfg, x), aux1 + aux2


def _forward_encdec(params, cfg: ArchConfig, batch: dict, *, impl="chunked"):
    mem_in = frontend_memory(params, cfg, batch)        # (B,Se,D)
    Se = mem_in.shape[1]
    enc_x = mem_in + sincos_pos_embed(Se, cfg.d_model).astype(mem_in.dtype)[None]
    pre, rep, pat = layer_plan(cfg, cfg.enc_layers, decoder=False)
    pos_e = _positions(enc_x.shape[0], Se)
    enc_x, _, _ = run_prefix(params["enc"]["prefix"], cfg, pre, enc_x,
                             positions=pos_e, memory=None, caches=None, impl=impl)
    if rep:
        enc_x, _, _ = run_stack(params["enc"]["stack"], cfg, pat, enc_x,
                                positions=pos_e, memory=None, caches=None,
                                impl=impl,
                                stack_axes=stack_axes_for(cfg, "enc/stack"))
    memory = apply_norm(params["enc"]["final_norm"], cfg, enc_x)

    tgt = batch["tokens"]
    B, Sd = tgt.shape
    x = embed_tokens(params["embed"], cfg, tgt)
    if cfg.pos_embed == "sincos":
        x = x + sincos_pos_embed(Sd, cfg.d_model).astype(x.dtype)[None]
    pre, rep, pat = layer_plan(cfg, cfg.dec_layers, decoder=True)
    pos_d = _positions(B, Sd)
    x, _, aux1 = run_prefix(params["dec"]["prefix"], cfg, pre, x,
                            positions=pos_d, memory=memory, caches=None,
                            impl=impl)
    aux2 = jnp.zeros((), jnp.float32)
    if rep:
        x, _, aux2 = run_stack(params["dec"]["stack"], cfg, pat, x,
                               positions=pos_d, memory=memory, caches=None,
                               impl=impl,
                               stack_axes=stack_axes_for(cfg, "dec/stack"))
    x = apply_norm(params["final_norm"], cfg, x)
    return lm_logits(params["embed"], cfg, x), aux1 + aux2


def lm_loss(params, cfg: ArchConfig, batch: dict, *, impl: str = "chunked"):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, aux = forward_lm(params, cfg, batch, impl=impl)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    mask = mask[:, 1:].astype(jnp.float32) if mask is not None else jnp.ones_like(nll)
    ce = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}
