"""Parameter specs: declare once, materialize or reflect.

A model defines a pytree of :class:`Spec` leaves (shape + logical axes +
initializer). The same tree yields:
  * real parameters          (:func:`materialize`)
  * shape stand-ins          (:func:`shape_tree`, for .lower() dry-runs)
  * logical-axes tree        (:func:`axes_of`, consumed by dist.sharding)
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal|zeros|ones|constant|uniform
    scale: Optional[float] = None  # stddev for normal (default: fan-in)
    const: float = 0.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"spec rank mismatch: {self.shape} vs {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 0:
        return 1
    if len(shape) == 1:
        return shape[0]
    # convention: last dim is output; everything else is fan-in
    n = 1
    for s in shape[:-1]:
        n *= s
    return max(n, 1)


def _init_leaf(spec: Spec, key, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.const, dtype)
    if spec.init == "uniform":
        s = spec.scale if spec.scale is not None else 1.0 / np.sqrt(_fan_in(spec.shape))
        return jax.random.uniform(key, spec.shape, dtype, -s, s)
    if spec.init == "normal":
        s = spec.scale if spec.scale is not None else 1.0 / np.sqrt(_fan_in(spec.shape))
        return (jax.random.normal(key, spec.shape, jnp.float32) * s).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def materialize(tree, key, dtype=jnp.float32):
    """Materialize a Spec tree into parameters (deterministic per path)."""
    def leaf(path, spec):
        sub = jax.random.fold_in(key, zlib.crc32(_path_str(path).encode()))
        return _init_leaf(spec, sub, dtype)
    return jax.tree_util.tree_map_with_path(leaf, tree, is_leaf=is_spec)


def shape_tree(tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree, is_leaf=is_spec)


def axes_of(tree):
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def param_count(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        tree, is_leaf=is_spec) if isinstance(s, Spec))


def stack_specs(spec_tree, n: int, axis_name: Optional[str] = "layers"):
    """Prepend a stacked `layers` dim of size n to every Spec in the tree."""
    def leaf(s: Spec) -> Spec:
        return Spec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale, s.const)
    return jax.tree.map(leaf, spec_tree, is_leaf=is_spec)
