"""Public model API: build/init any assigned architecture, run train /
prefill / decode, and produce ShapeDtypeStruct input specs for dry-runs.

Cache layout mirrors the layer plan: ``{"prefix": [slot_cache...],
"stack": stacked_slot_caches}`` (+ ``"memory"`` for enc-dec / VLM).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models import attention as attn_mod
from repro.models import params as pmod
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import apply_norm, dtype_of, embed_tokens, lm_logits, sincos_pos_embed
from repro.models.transformer import (
    Slot, forward_lm, layer_plan, lm_loss, model_specs, run_prefix, run_stack,
)

__all__ = [
    "model_specs", "init_params", "param_axes", "param_shapes", "forward_lm",
    "lm_loss", "init_caches", "prefill", "decode_step", "input_specs",
]


def init_params(cfg: ArchConfig, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    return pmod.materialize(model_specs(cfg), key, dtype_of(cfg.param_dtype))


def param_axes(cfg: ArchConfig):
    return pmod.axes_of(model_specs(cfg))


def param_shapes(cfg: ArchConfig):
    return pmod.shape_tree(model_specs(cfg), dtype_of(cfg.param_dtype))


def param_count(cfg: ArchConfig) -> int:
    return pmod.param_count(model_specs(cfg))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _slot_cache(cfg: ArchConfig, slot: Slot, batch: int, max_len: int,
                src_len: int, dtype):
    if slot.mixer == "attn":
        return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, dtype)}
    if slot.mixer == "mla":
        return {"kv": attn_mod.init_mla_cache(cfg, batch, max_len, dtype)}
    if slot.mixer == "cross":
        return {"cross": attn_mod.CrossCache(
            k=jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.d_head), dtype),
            v=jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.d_head), dtype))}
    if slot.mixer == "attn_cross":
        return {"kv": attn_mod.init_kv_cache(cfg, batch, max_len, dtype),
                "cross": attn_mod.CrossCache(
                    k=jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.d_head), dtype),
                    v=jnp.zeros((batch, src_len, cfg.n_kv_heads, cfg.d_head), dtype))}
    if slot.mixer == "mamba":
        return {"mamba": ssm_mod.init_mamba_state(cfg, batch)}
    if slot.mixer == "rwkv":
        return {"rwkv": rwkv_mod.init_rwkv_state(cfg, batch)}
    raise ValueError(slot.mixer)


def _stack_cache(cfg, pattern, rep, batch, max_len, src_len, dtype):
    per_slot = [_slot_cache(cfg, s, batch, max_len, src_len, dtype)
                for s in pattern]
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (rep,) + x.shape).copy(), per_slot)


def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                src_len: int = 0, dtype=None):
    dtype = dtype or dtype_of(cfg.kv_cache_dtype)
    if cfg.family == "encdec":
        pre, rep, pat = layer_plan(cfg, cfg.dec_layers, decoder=True)
    else:
        pre, rep, pat = layer_plan(cfg, cfg.n_layers)
    out = {
        "prefix": [_slot_cache(cfg, s, batch, max_len, src_len, dtype) for s in pre],
        "stack": _stack_cache(cfg, pat, rep, batch, max_len, src_len, dtype) if rep else [],
    }
    if cfg.family in ("encdec", "vlm"):
        out["memory"] = jnp.zeros(
            (batch, src_len, cfg.d_model), dtype_of(cfg.compute_dtype))
    return out


# ---------------------------------------------------------------------------
# Cached forward (prefill and decode share this)
# ---------------------------------------------------------------------------

def _encode(params, cfg: ArchConfig, batch: dict, impl: str):
    mem_in = tfm.frontend_memory(params, cfg, batch)
    Se = mem_in.shape[1]
    x = mem_in + sincos_pos_embed(Se, cfg.d_model).astype(mem_in.dtype)[None]
    pre, rep, pat = layer_plan(cfg, cfg.enc_layers, decoder=False)
    pos = tfm._positions(x.shape[0], Se)
    x, _, _ = run_prefix(params["enc"]["prefix"], cfg, pre, x, positions=pos,
                         memory=None, caches=None, impl=impl)
    if rep:
        x, _, _ = run_stack(params["enc"]["stack"], cfg, pat, x, positions=pos,
                            memory=None, caches=None, impl=impl,
                            stack_axes=tfm.stack_axes_for(cfg, "enc/stack"))
    return apply_norm(params["enc"]["final_norm"], cfg, x)


def forward_cached(params, cfg: ArchConfig, tokens, caches, *, offset,
                   memory=None, impl: str = "chunked"):
    """tokens: (B,S) starting at absolute position `offset` (scalar)."""
    B, S = tokens.shape
    x = embed_tokens(params["embed"], cfg, tokens)
    if cfg.pos_embed == "sincos":
        x = x + _sincos_at(cfg, S, offset).astype(x.dtype)[None]
    positions = tfm._positions(B, S, offset)
    if cfg.family == "encdec":
        pre, rep, pat = layer_plan(cfg, cfg.dec_layers, decoder=True)
        prefix_params, stack_params = params["dec"]["prefix"], params["dec"]["stack"]
    else:
        pre, rep, pat = layer_plan(cfg, cfg.n_layers)
        prefix_params, stack_params = params["prefix"], params["stack"]
    new = dict(caches)
    x, pc, _ = run_prefix(prefix_params, cfg, pre, x, positions=positions,
                          memory=memory, caches=caches["prefix"], impl=impl)
    new["prefix"] = pc
    if rep:
        which = "dec/stack" if cfg.family == "encdec" else "stack"
        x, sc, _ = run_stack(stack_params, cfg, pat, x, positions=positions,
                             memory=memory,
                             caches=caches["stack"] if caches["stack"] != [] else None,
                             impl=impl, stack_axes=tfm.stack_axes_for(cfg, which))
        new["stack"] = sc
    x = apply_norm(params["final_norm"], cfg, x)
    return lm_logits(params["embed"], cfg, x[:, -1:, :]), new


def _sincos_at(cfg, S, offset):
    pos = (jnp.arange(S) + offset).astype(jnp.float32)[:, None]
    d = cfg.d_model
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((S, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def constrain_caches(caches):
    """Apply logical-axis sharding constraints to a cache tree (no-op
    without an active mesh)."""
    from repro.dist import shard
    axes = cache_axes(caches)
    return jax.tree.map(lambda x, ax: shard(x, *ax), caches, axes)


def prefill(params, cfg: ArchConfig, batch: dict, max_len: int,
            impl: str = "chunked"):
    """Fill caches from a prompt. Returns (last-token logits, caches)."""
    tokens = batch["tokens"]
    B = tokens.shape[0]
    memory = None
    src_len = 0
    if cfg.family == "encdec":
        memory = _encode(params, cfg, batch, impl)
        src_len = memory.shape[1]
    elif cfg.family == "vlm":
        memory = tfm.frontend_memory(params, cfg, batch)
        src_len = memory.shape[1]
    caches = constrain_caches(init_caches(cfg, B, max_len, src_len))
    # cross caches start empty -> computed from memory on first pass
    caches = _clear_cross(caches)
    logits, caches = forward_cached(params, cfg, tokens, caches, offset=0,
                                    memory=memory, impl=impl)
    if memory is not None:
        caches["memory"] = memory
    return logits, caches


def _clear_cross(caches):
    def clear(tree):
        if isinstance(tree, dict):
            return {k: (None if k == "cross" else clear(v)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [clear(v) for v in tree]
        return tree
    return clear(caches)


def decode_step(params, cfg: ArchConfig, caches, tokens, *,
                impl: str = "chunked"):
    """One decode step. tokens: (B,1). Offset derives from cache lengths."""
    offset = _cache_length(caches)
    memory = caches.get("memory")
    return forward_cached(params, cfg, tokens, caches, offset=offset,
                          memory=memory, impl=impl)


def _cache_length(caches) -> jax.Array:
    leaves = []

    def visit(t):
        if isinstance(t, dict):
            [visit(v) for v in t.values()]
        elif isinstance(t, list):
            [visit(v) for v in t]
        elif isinstance(t, (attn_mod.KVCache, attn_mod.MLACache)):
            leaves.append(t.length)
    visit({k: v for k, v in caches.items() if k != "memory"})
    if not leaves:
        return jnp.zeros((), jnp.int32)
    l0 = leaves[0]
    return l0.reshape(-1)[0] if l0.ndim else l0


_CACHE_FIELD_AXES = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "c_kv": ("batch", "kv_seq", None),
    "k_rope": ("batch", "kv_seq", None),
    "length": (),
    "conv": ("batch", None, "dinner"),
    "h": ("batch", "dinner", None),
    "tm_shift": ("batch", None),
    "cm_shift": ("batch", None),
    "wkv": ("batch", "heads", None, None),
    "memory": ("batch", None, None),
}


def cache_axes(caches):
    """Logical-axes tree mirroring a cache pytree (for dry-run shardings)."""
    def leaf(path, x):
        name = None
        for p in reversed(path):
            n = getattr(p, "name", None)
            if n is None:
                kk = getattr(p, "key", None)
                n = kk if isinstance(kk, str) else None
            if n in _CACHE_FIELD_AXES:
                name = n
                break
        base = _CACHE_FIELD_AXES[name]
        rank = len(x.shape)
        if rank == len(base) + 1:
            base = ("layers",) + base
        assert rank == len(base), f"cache leaf {path}: rank {rank} vs {base}"
        return base
    return jax.tree_util.tree_map_with_path(leaf, caches)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """Global-shape inputs for a (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = dtype_of(cfg.compute_dtype)
    tok = jax.ShapeDtypeStruct((B, S), i32)

    if shape.kind == "train":
        out = {"tokens": tok}
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), f)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), f)
        return out
    if shape.kind == "prefill":
        out = {"tokens": tok}
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.frontend_len, cfg.frontend_dim), f)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frontend_dim), f)
        return out
    # decode: one new token against caches of length S
    out = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    src_len = cfg.frontend_len if cfg.family in ("encdec", "vlm") else 0
    out["caches"] = jax.eval_shape(
        lambda: init_caches(cfg, B, S, src_len))   # no allocation
    return out
