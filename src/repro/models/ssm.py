"""Mamba (S6) selective-state-space mixer, chunked for TPU.

The selective scan h_t = a_t * h_{t-1} + b_t is evaluated chunk-by-chunk
(sequential lax.scan over chunks, parallel associative scan within a chunk)
so the (B, Lc, d_inner, N) working set stays bounded — the same shape the
Pallas kernel (:mod:`repro.kernels.mamba_scan`) tiles into VMEM.

State for decoding: (conv_state (B, d_conv-1, dI), h (B, dI, N)).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models.params import Spec


class MambaState(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, dI)
    h: jax.Array       # (B, dI, N) fp32


def mamba_specs(cfg: ArchConfig):
    m = cfg.mamba
    d, dI, N, R = cfg.d_model, cfg.d_inner_mamba, m.d_state, cfg.dt_rank
    return {
        "in_proj": Spec((d, 2 * dI), ("embed", "dinner")),
        "conv_w": Spec((m.d_conv, dI), (None, "dinner"), scale=0.5),
        "conv_b": Spec((dI,), ("dinner",), "zeros"),
        "w_xdbc": Spec((dI, R + 2 * N), ("dinner", None)),
        "dt_proj": Spec((R, dI), (None, "dinner")),
        "dt_bias": Spec((dI,), ("dinner",), "constant", const=-4.6),  # softplus ~= 0.01
        "A_log": Spec((dI, N), ("dinner", None), "zeros"),            # A = -1
        "D": Spec((dI,), ("dinner",), "ones"),
        "out_proj": Spec((dI, d), ("dinner", "embed")),
    }


def _causal_conv(p, x: jax.Array, prev: Optional[jax.Array]):
    """Depthwise causal conv1d. x:(B,S,dI); prev:(B,dc-1,dI) or None."""
    dc = p["conv_w"].shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, j:j + S, :] * p["conv_w"][j].astype(x.dtype) for j in range(dc))
    new_prev = xp[:, -(dc - 1):, :].astype(jnp.float32) if dc > 1 else prev
    return y + p["conv_b"].astype(x.dtype), new_prev


def _ssm_chunk(a, bx, h0):
    """Associative scan within one chunk. a,bx: (B,Lc,dI,N) fp32; h0:(B,dI,N)."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br
    A_cum, B_cum = jax.lax.associative_scan(comb, (a, bx), axis=1)
    h = A_cum * h0[:, None] + B_cum
    return h, h[:, -1]


def mamba_mixer(p, cfg: ArchConfig, x: jax.Array,
                state: Optional[MambaState] = None
                ) -> Tuple[jax.Array, Optional[MambaState]]:
    """x: (B,S,D) -> (out (B,S,D), new_state)."""
    m = cfg.mamba
    B, S, D = x.shape
    dI, N, R = cfg.d_inner_mamba, m.d_state, cfg.dt_rank

    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_in = shard(x_in, "batch", None, "dinner")
    x_conv, conv_state = _causal_conv(p, x_in, state.conv if state else None)
    x_conv = jax.nn.silu(x_conv)

    xdbc = x_conv @ p["w_xdbc"].astype(x.dtype)
    dt_in, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype))        # (B,S,dI)
    dt = dt.astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (dI,N)

    h0 = state.h if state is not None else jnp.zeros((B, dI, N), jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    xf = x_conv.astype(jnp.float32)

    chunk = min(m.chunk, S)
    if S % chunk:
        chunk = S  # fall back to single chunk for ragged smoke shapes
    nchunk = S // chunk

    def chunk_body(h, idx):
        sl = lambda t: jax.lax.dynamic_slice_in_dim(t, idx * chunk, chunk, 1)
        dt_c, B_c, C_c, x_c = sl(dt), sl(Bf), sl(Cf), sl(xf)
        a = jnp.exp(dt_c[..., None] * A)                        # (B,Lc,dI,N)
        bx = (dt_c * x_c)[..., None] * B_c[:, :, None, :]
        h_all, h_last = _ssm_chunk(a, bx, h)
        y_c = jnp.einsum("blin,bln->bli", h_all, C_c)           # (B,Lc,dI)
        return h_last, y_c

    # nested remat: without it the layer-level checkpoint still stashes the
    # full (chunks, B, Lc, dI, N) fp32 scan residuals for backward — the
    # dominant memory term at frontier scale (see EXPERIMENTS.md §Perf)
    chunk_body_ckpt = jax.checkpoint(chunk_body)
    if nchunk == 1:
        h_last, y = chunk_body_ckpt(h0, 0)
    else:
        h_last, ys = jax.lax.scan(chunk_body_ckpt, h0, jnp.arange(nchunk))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, dI)

    y = (y + xf * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = shard(y, "batch", None, "dinner")
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = MambaState(conv_state, h_last)
    return out, new_state


def mamba_decode_step(p, cfg: ArchConfig, x: jax.Array, state: MambaState
                      ) -> Tuple[jax.Array, MambaState]:
    """Single-token step. x: (B,1,D)."""
    m = cfg.mamba
    R, N = cfg.dt_rank, m.d_state
    xz = x @ p["in_proj"].astype(x.dtype)
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv, conv_state = _causal_conv(p, x_in, state.conv)
    x_conv = jax.nn.silu(x_conv)
    xdbc = x_conv @ p["w_xdbc"].astype(x.dtype)
    dt_in, Bm, Cm = jnp.split(xdbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"].astype(x.dtype)
                         + p["dt_bias"].astype(x.dtype)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[:, 0, :, None] * A)                          # (B,dI,N)
    bx = (dt[:, 0] * x_conv.astype(jnp.float32)[:, 0])[..., None] \
        * Bm.astype(jnp.float32)[:, 0, :, None].transpose(0, 2, 1)
    h = a * state.h + bx
    y = jnp.einsum("bin,bn->bi", h, Cm.astype(jnp.float32)[:, 0])[:, None, :]
    y = (y + x_conv.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(x.dtype), MambaState(conv_state, h)


def init_mamba_state(cfg: ArchConfig, batch: int) -> MambaState:
    m = cfg.mamba
    dI = cfg.d_inner_mamba
    return MambaState(
        conv=jnp.zeros((batch, m.d_conv - 1, dI), jnp.float32),
        h=jnp.zeros((batch, dI, m.d_state), jnp.float32),
    )
