"""Core layers: norms, rotary/sinusoidal positions, MLPs, embeddings.

All functions are pure; parameters are plain dicts materialized from Spec
trees (:mod:`repro.models.params`). Activation sharding annotations use
logical axes via :func:`repro.dist.shard`.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist import shard
from repro.models.params import Spec


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16, "int8": jnp.int8}[name]


@jax.custom_vjp
def cot_cast(x):
    """Identity whose BACKWARD casts the cotangent to the primal dtype.
    Without it, one fp32 contribution (e.g. a norm VJP) promotes the whole
    residual-stream cotangent chain to fp32 — 2x bytes on every backward
    collective and 2x bwd matmul width (EXPERIMENTS.md §Perf)."""
    return x


def _cot_cast_fwd(x):
    return x, jnp.zeros((0,), x.dtype)   # dtype token (residuals must be arrays)


def _cot_cast_bwd(token, ct):
    return (ct.astype(token.dtype),)


cot_cast.defvjp(_cot_cast_fwd, _cot_cast_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ArchConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": Spec((d,), ("embed",), "ones"),
                "bias": Spec((d,), ("embed",), "zeros")}
    return {"scale": Spec((d,), ("embed",), "ones")}


def apply_norm(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Reductions in fp32; the normalized product drops to x.dtype BEFORE the
    scale multiply, so no fp32 tensor feeds downstream collectives (XLA-CPU
    does not sink converts below all-gathers; see EXPERIMENTS.md §Perf)."""
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = ((xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)
        y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = (xf * jax.lax.rsqrt(ms + cfg.norm_eps)).astype(x.dtype)
        y = y * p["scale"].astype(x.dtype)
    return y


def groupnorm_heads(scale, bias, x: jax.Array, n_heads: int, eps: float) -> jax.Array:
    """GroupNorm with one group per head over (..., H, hs) flattened input."""
    *lead, d = x.shape
    hs = d // n_heads
    xf = x.astype(jnp.float32).reshape(*lead, n_heads, hs)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*lead, d) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))              # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sincos_pos_embed(seq: int, d: int, offset: int = 0) -> jax.Array:
    pos = jnp.arange(offset, offset + seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-np.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# MLP (dense feed-forward)
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act.endswith("_glu"):
        return {
            "w_gate": Spec((d, f), ("embed", "ff")),
            "w_up": Spec((d, f), ("embed", "ff")),
            "w_down": Spec((f, d), ("ff", "embed")),
        }
    return {
        "w_up": Spec((d, f), ("embed", "ff")),
        "b_up": Spec((f,), ("ff",), "zeros"),
        "w_down": Spec((f, d), ("ff", "embed")),
        "b_down": Spec((d,), ("embed",), "zeros"),
    }


def _act(name: str, x: jax.Array) -> jax.Array:
    if name.startswith("silu"):
        return jax.nn.silu(x)
    if name.startswith("gelu"):
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def apply_mlp(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D)."""
    if cfg.mlp_act.endswith("_glu"):
        h = _act(cfg.mlp_act, x @ p["w_gate"]) * (x @ p["w_up"])
        h = shard(h, "batch", None, "ff")
        return h @ p["w_down"]
    h = _act(cfg.mlp_act, x @ p["w_up"] + p["b_up"].astype(x.dtype))
    h = shard(h, "batch", None, "ff")
    return h @ p["w_down"] + p["b_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ArchConfig):
    V, d = cfg.padded_vocab, cfg.d_model
    sp = {"tok": Spec((V, d), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        sp["head"] = Spec((d, V), ("embed", "vocab"))
    return sp


def embed_tokens(p, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = p["tok"].astype(dtype_of(cfg.compute_dtype))[tokens]
    return shard(x, "batch", None, "embed")


def lm_logits(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Final-norm'ed hidden -> (B, S, padded_vocab) fp32 logits (pads masked)."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["tok"].astype(x.dtype))
    else:
        logits = x @ p["head"]
    logits = logits.astype(jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        mask = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32),
                                jnp.full((pad,), -1e30, jnp.float32)])
        logits = logits + mask
    return shard(logits, "batch", None, "vocab")
