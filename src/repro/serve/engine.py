"""Serving engine: batched prefill + autoregressive decode over any
assigned architecture, with request slots (lightweight continuous
batching: finished slots are refilled between steps; uniform cache stride).

The decode step is a single jit'd function reused across steps; caches are
donated so decoding is allocation-stable. KV caches can be held in int8
(``cfg.kv_cache_dtype="int8"``) with per-tensor scale — a serving-memory
optimization recorded in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model_zoo as zoo
from repro.serve.sampling import SamplingParams, sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 max_len: int = 256, impl: str = "chunked",
                 sampling: SamplingParams = SamplingParams(greedy=True),
                 seed: int = 0,
                 clock: Callable[[], float] = time.perf_counter):
        self.cfg, self.params = cfg, params
        self.batch_size, self.max_len = batch_size, max_len
        self.impl, self.sampling = impl, sampling
        # injectable so serving metrics are deterministic under a sim
        # clock (tests advance it by hand); default unchanged wall clock
        self._clock = clock
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))
        self._prefill = jax.jit(self._prefill_fn)
        self.metrics = {"prefill_tokens": 0, "decode_tokens": 0,
                        "prefill_s": 0.0, "decode_s": 0.0}

    # -- jitted bodies ----------------------------------------------------
    def _prefill_fn(self, params, batch):
        return zoo.prefill(params, self.cfg, batch, max_len=self.max_len,
                           impl=self.impl)

    def _decode_fn(self, params, caches, tokens, rng):
        logits, caches = zoo.decode_step(params, self.cfg, caches, tokens,
                                         impl=self.impl)
        rng, sub = jax.random.split(rng)
        next_tok = sample(logits[:, 0, :self.cfg.vocab_size], sub,
                          self.sampling)
        return next_tok, caches, rng

    # -- public API -------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests with slot-based batching."""
        pending = list(requests)
        done: List[Request] = []
        while pending:
            wave = pending[:self.batch_size]
            pending = pending[self.batch_size:]
            self._serve_wave(wave)
            done.extend(wave)
        return done

    def _serve_wave(self, wave: List[Request]):
        cfg = self.cfg
        B = len(wave)
        S = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(wave):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((B, S, cfg.frontend_dim), jnp.float32)

        t0 = self._clock()
        logits, caches = self._prefill(self.params, batch)
        self.rng, sub = jax.random.split(self.rng)
        tok = sample(logits[:, 0, :cfg.vocab_size], sub, self.sampling)
        jax.block_until_ready(tok)
        self.metrics["prefill_s"] += self._clock() - t0
        self.metrics["prefill_tokens"] += B * S
        for i, r in enumerate(wave):
            r.out_tokens.append(int(tok[i]))

        steps = max(r.max_new_tokens for r in wave) - 1
        t1 = self._clock()
        for _ in range(steps):
            tok, caches, self.rng = self._decode(
                self.params, caches, tok[:, None], self.rng)
            for i, r in enumerate(wave):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(tok[i]))
        jax.block_until_ready(tok)
        self.metrics["decode_s"] += self._clock() - t1
        self.metrics["decode_tokens"] += B * steps
        for r in wave:
            r.done = True

    def throughput(self) -> dict:
        m = self.metrics
        return {
            "prefill_tok_per_s": m["prefill_tokens"] / max(m["prefill_s"], 1e-9),
            "decode_tok_per_s": m["decode_tokens"] / max(m["decode_s"], 1e-9),
        }
