"""Token sampling: greedy / temperature / top-k / top-p (nucleus)."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 1.0
    top_k: int = 0          # 0 = off
    top_p: float = 1.0      # 1 = off
    greedy: bool = False


def sample(logits: jax.Array, rng: jax.Array,
           p: SamplingParams = SamplingParams()) -> jax.Array:
    """logits: (B, V) fp32 -> token ids (B,) int32."""
    if p.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / jnp.maximum(p.temperature, 1e-6)
    if p.top_k:
        kth = jax.lax.top_k(logits, p.top_k)[0][:, -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    if p.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative mass >= top_p
        cutoff_idx = jnp.sum(cum < p.top_p, axis=-1, keepdims=True)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx, axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
