"""Serving as a placement-priced operator graph: ``prefill -> decode``
over the pipeline substrate, so the prefill->decode crossing is a real
:class:`~repro.core.costmodel.Link` hop and the KV cache is the state
the placement DP prices against ``mem_cap``.

Both ops are *host ops* (``Op.jit=False``) built around one
:class:`~repro.serve.engine.ServeEngine`: they call the engine's own
jitted ``_prefill``/``_decode`` executables, so the graph path is
bitwise-identical to ``ServeEngine._serve_wave`` (same executables, same
rng threading, same donated decode buffers) — the differential contract
``tests`` pin down. The KV cache crosses between them as the ``"kv"``
batch channel (a cache pytree, not a flat array): under a cloud-prefill/
edge-decode placement the orchestrator's wire round-trip compresses
exactly that channel with the KV codec ladder (``kv_int8`` /
``kv_latent``), which is what makes KV compression SLA-governed uplink
state.

``decode`` declares ``OperatorCost.downlink_ok``: its flow parent may
legitimately sit in the cloud and ship the cache *down* — the relaxed
closure relation (``OpGraph.closure_parent_indices``) admits the
``{decode}`` frontier and the evaluator prices the crossing instead of
marking it backhaul.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import OperatorCost
from repro.core.pipeline import Op, OpGraph
from repro.launch.roofline import dl_operator_cost
from repro.models import model_zoo as zoo
from repro.serve.engine import ServeEngine
from repro.serve.sampling import sample


def _shape_tree_bytes(tree) -> float:
    return float(sum(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(tree)))


def param_bytes(cfg) -> float:
    """Resident bytes of the model weights (no materialization)."""
    return _shape_tree_bytes(zoo.param_shapes(cfg))


def kv_cache_bytes(cfg, batch: int, max_len: int, src_len: int = 0) -> float:
    """Resident bytes of a full KV-cache pytree at ``(batch, max_len)``
    — the decode op's placement-priced state, computed from shapes only
    (``jax.eval_shape``), never allocated here."""
    shapes = jax.eval_shape(
        lambda: zoo.init_caches(cfg, batch, max_len, src_len))
    return _shape_tree_bytes(shapes)


def _model_extra_keys(cfg) -> Tuple[str, ...]:
    if cfg.family == "vlm":
        return ("patches",)
    if cfg.family == "encdec":
        return ("frames",)
    return ()


def prefill_op(engine: ServeEngine, *, prompt_len: int,
               cost: Optional[OperatorCost] = None) -> Op:
    """The prefill stage as a host op: run the engine's jitted prefill,
    sample the first token (identical rng threading to
    ``ServeEngine._serve_wave``), and emit the KV cache on the ``"kv"``
    channel — the state the downlink ships."""
    cfg = engine.cfg
    extras = _model_extra_keys(cfg)

    def fn(state, batch):
        model_in = {"tokens": batch["tokens"],
                    **{k: batch[k] for k in extras}}
        logits, caches = engine._prefill(engine.params, model_in)
        rng, sub = jax.random.split(batch["rng"])
        tok = sample(logits[:, 0, :cfg.vocab_size], sub, engine.sampling)
        return state, {"kv": caches, "tok": tok, "rng": rng}

    if cost is None:
        B = engine.batch_size
        kvb = kv_cache_bytes(cfg, B, engine.max_len)
        cost = dl_operator_cost(
            "prefill", cfg, phase="prefill", batch=B, seq_len=prompt_len,
            param_bytes=param_bytes(cfg),
            # the KV cache is what this op emits downstream, per event
            out_bytes_per_event=kvb / B,
            state_bytes=param_bytes(cfg))
    return Op("prefill", fn, cost, jit=False,
              reads=("tokens", "rng") + extras,
              writes=("kv", "tok", "rng"))


def decode_op(engine: ServeEngine, *, max_new_tokens: int,
              cost: Optional[OperatorCost] = None) -> Op:
    """The decode loop as a host op: consume the ``"kv"`` channel and the
    first sampled token, loop the engine's donated-buffer jitted decode
    step ``max_new_tokens - 1`` times, and emit every request's token
    row as ``"out_tokens"`` (B, max_new_tokens).

    Declares ``downlink_ok`` (the KV cache may arrive over the
    cloud->edge downlink) and deletes its inputs: the decode executable
    donates the cache buffers, so the stale references must not survive
    in the channel env."""
    cfg = engine.cfg
    steps = max_new_tokens - 1

    def fn(state, batch):
        caches, tok, rng = batch["kv"], batch["tok"], batch["rng"]
        toks = [tok]
        for _ in range(steps):
            tok, caches, rng = engine._decode(
                engine.params, caches, tok[:, None], rng)
            toks.append(tok)
        out = jnp.stack(toks, axis=1).astype(jnp.int32)
        return state, {"out_tokens": out, "rng": rng}

    if cost is None:
        B = engine.batch_size
        pb = param_bytes(cfg)
        kvb = kv_cache_bytes(cfg, B, engine.max_len)
        cost = dl_operator_cost(
            "decode", cfg, phase="decode", batch=B, seq_len=0,
            new_tokens=max_new_tokens, param_bytes=pb,
            out_bytes_per_event=4.0 * max_new_tokens,
            # the decode-resident state the DP prices against mem_cap:
            # the weights AND the live KV cache
            state_bytes=pb + kvb, downlink_ok=True)
    return Op("decode", fn, cost, jit=False,
              reads=("kv", "tok", "rng"),
              writes=("out_tokens", "rng"), deletes=("kv", "tok"))


def serving_graph(engine: ServeEngine, *, prompt_len: int,
                  max_new_tokens: int) -> OpGraph:
    """The split serving graph ``prefill -> decode`` (one flow edge —
    the KV-cache hop placement prices per link). Frontiers are ``{}``,
    ``{prefill, decode}``, ``{prefill}`` and — via decode's
    ``downlink_ok`` — ``{decode}``: the cloud-prefill/edge-decode split."""
    return OpGraph([
        prefill_op(engine, prompt_len=prompt_len),
        decode_op(engine, max_new_tokens=max_new_tokens),
    ])


def serve_wave_batch(engine: ServeEngine, prompts, *, seed: int = 0):
    """The channel env for one wave of ``prompts`` (list of int 1-D
    arrays): left-padded tokens exactly as ``ServeEngine._serve_wave``
    builds them, family extras, and the wave rng."""
    cfg = engine.cfg
    B = len(prompts)
    S = max(len(p) for p in prompts)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, S - len(p):] = np.asarray(p, np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "rng": jax.random.PRNGKey(seed)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros(
            (B, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.zeros((B, S, cfg.frontend_dim), jnp.float32)
    return batch
