"""Training as a placement-priced operator: :func:`dl_train_op` wraps
the :func:`~repro.train.train_step.make_train_step` factory as a
pipeline :class:`~repro.core.pipeline.Op` whose state is the
``(params, opt_state, step)`` triple and whose
:class:`~repro.core.costmodel.OperatorCost` comes from the roofline 6ND
rule (:func:`repro.launch.roofline.dl_operator_cost`) — refined to the
compiled artifact's numbers by
:func:`repro.core.selftune.measure_operator_costs` where the backend
supports cost analysis. An assigned zoo architecture is then placed by
the frontier DP like any other operator: ``state_bytes`` (the full
param + optimizer pytree) prices it against ``mem_cap``, and
``edge_capable=False`` (the default, S2CE's "full DL training is a
cloud concern") anchors it on a pod.

The op fn is the *unmodified* train step applied to the channel env —
under the identity codec the pipeline-wrapped step is numerically
identical to calling the standalone ``train_step`` (the differential
contract in the tests).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import OperatorCost
from repro.core.pipeline import Op
from repro.launch.roofline import dl_operator_cost
from repro.models import model_zoo as zoo
from repro.train.optim import Optimizer
from repro.train.train_step import make_train_step


def _shape_tree_bytes(tree) -> float:
    return float(sum(
        int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(tree)))


def train_state_bytes(cfg, optimizer: Optimizer) -> float:
    """Resident bytes of the train op's state (params + optimizer
    moments), from shapes only — never materialized here."""
    pshapes = zoo.param_shapes(cfg)
    params = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, l.dtype), pshapes)
    opt_shapes = jax.eval_shape(optimizer.init, params)
    return _shape_tree_bytes(pshapes) + _shape_tree_bytes(opt_shapes)


def dl_train_op(cfg, optimizer: Optimizer, *, batch_size: int,
                seq_len: int, name: str = "dl_train",
                impl: str = "chunked", seed: int = 0,
                clip_norm: float = 1.0,
                microbatches: Optional[int] = None,
                grad_compression: Optional[str] = None,
                edge_capable: bool = False,
                cost: Optional[OperatorCost] = None,
                extra_reads: Tuple[str, ...] = ()) -> Op:
    """The zoo train step as a pipeline op.

    * state: ``(params, opt_state, step)`` — initialized from
      ``zoo.init_params(cfg, seed)`` / ``optimizer.init``;
    * channels: reads ``("tokens",)`` (plus family extras /
      ``extra_reads``), writes per-step ``("loss", "grad_norm")``;
    * cost: roofline-declared (6ND per sequence event, weight-stream
      HBM traffic, full state residency) unless ``cost`` is given.
    """
    extras = tuple(extra_reads)
    if cfg.family == "vlm" and "patches" not in extras:
        extras += ("patches",)
    if cfg.family == "encdec" and "frames" not in extras:
        extras += ("frames",)
    train_step = make_train_step(
        cfg, optimizer, impl=impl, clip_norm=clip_norm,
        microbatches=microbatches, grad_compression=grad_compression)
    model_keys = ("tokens",) + extras

    def fn(state, batch):
        params, opt_state, step = state
        model_in = {k: batch[k] for k in model_keys if k in batch}
        params, opt_state, step, metrics = train_step(
            params, opt_state, step, model_in)
        out = {"loss": metrics["loss"], "grad_norm": metrics["grad_norm"]}
        return (params, opt_state, step), out

    def init():
        params = zoo.init_params(cfg, seed)
        return params, optimizer.init(params), jnp.zeros((), jnp.int32)

    if cost is None:
        pb = _shape_tree_bytes(zoo.param_shapes(cfg))
        cost = dl_operator_cost(
            name, cfg, phase="train", batch=batch_size, seq_len=seq_len,
            param_bytes=pb, out_bytes_per_event=8.0,
            state_bytes=train_state_bytes(cfg, optimizer),
            edge_capable=edge_capable)
    else:
        from dataclasses import replace
        cost = replace(cost, name=name)
    return Op(name, fn, cost, init=init,
              reads=model_keys, writes=("loss", "grad_norm"))
