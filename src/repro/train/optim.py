"""Optimizers built from scratch (no optax): AdamW, Adafactor, Lion, SGD.

Each optimizer is a pair of pure functions plus a *state-axes* reflector so
distributed launchers can shard optimizer state exactly like parameters
(ZeRO). States respect ``cfg.opt_state_dtype`` and optionally carry fp32
master weights (``cfg.fp32_master``) when params live in bf16.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]                    # params -> state
    update: Callable[[Any, Any, Any, jax.Array], tuple]  # (grads, state, params, step) -> (new_params, new_state)
    state_axes: Callable[[Any], Any]              # param_axes -> state_axes


def _cast_tree(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def _zeros_like_tree(tree, dtype):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype), tree)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(lr_val: float):
    return lambda step: jnp.asarray(lr_val, jnp.float32)


def _as_schedule(lr):
    return lr if callable(lr) else constant_schedule(lr)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32,
          fp32_master: bool = False) -> Optimizer:
    lr = _as_schedule(lr)

    def init(params):
        st = {"m": _zeros_like_tree(params, state_dtype),
              "v": _zeros_like_tree(params, state_dtype)}
        if fp32_master:
            st["master"] = _cast_tree(params, jnp.float32)
        return st

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        bc1 = 1.0 - jnp.power(b1, stepf)
        bc2 = 1.0 - jnp.power(b2, stepf)
        lr_t = lr(step)
        base = state.get("master", params)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
            m_new = b1 * m32 + (1 - b1) * g
            v_new = b2 * v32 + (1 - b2) * jnp.square(g)
            mhat = m_new / bc1
            vhat = v_new / bc2
            p32 = p.astype(jnp.float32)
            p_new = p32 - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                                  + weight_decay * p32)
            return p_new, m_new.astype(state_dtype), v_new.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], base)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": m_new, "v": v_new}
        if "master" in state:
            new_state["master"] = p_new
        params_dtype = jax.tree.leaves(params)[0].dtype
        return _cast_tree(p_new, params_dtype), new_state

    def state_axes(param_axes):
        st = {"m": param_axes, "v": param_axes}
        if fp32_master:
            st["master"] = param_axes
        return st

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# Lion (memory-light: single momentum)
# ---------------------------------------------------------------------------

def lion(lr, b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1,
         state_dtype=jnp.bfloat16) -> Optimizer:
    lr = _as_schedule(lr)

    def init(params):
        return {"m": _zeros_like_tree(params, state_dtype)}

    def update(grads, state, params, step):
        lr_t = lr(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32)
            d = jnp.sign(b1 * m32 + (1 - b1) * g)
            p32 = p.astype(jnp.float32)
            p_new = p32 - lr_t * (d + weight_decay * p32)
            m_new = b2 * m32 + (1 - b2) * g
            return p_new.astype(p.dtype), m_new.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], params)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return p_new, {"m": m_new}

    return Optimizer(init, update, lambda ax: {"m": ax})


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — frontier-scale memory)
# ---------------------------------------------------------------------------

def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    lr = _as_schedule(lr)

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(leaf, params)}

    def update(grads, state, params, step):
        stepf = jnp.asarray(step, jnp.float32) + 1.0
        rho = jnp.minimum(1e-2, 1.0 / jnp.power(stepf, decay))
        beta = 1.0 - rho
        lr_t = lr(step)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(vr / jnp.mean(vr, axis=-1, keepdims=True) + eps)
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                nv = {"vr": vr, "vc": vc}
            else:
                v2 = beta * v["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v2 + eps)
                nv = {"v": v2}
            # update clipping
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            p32 = p.astype(jnp.float32)
            p_new = p32 - lr_t * (u + weight_decay * p32)
            return p_new.astype(p.dtype), nv

        out = jax.tree.map(upd, grads, state["v"], params,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("vr" in x or "v" in x))
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        v_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return p_new, {"v": v_new}

    def state_axes(param_axes):
        def leaf(ax):
            if len(ax) >= 2:
                return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
            return {"v": ax}
        return {"v": jax.tree.map(
            leaf, param_axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                a is None or isinstance(a, str) for a in x))}

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# SGD (momentum)
# ---------------------------------------------------------------------------

def sgd(lr, momentum: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr = _as_schedule(lr)

    def init(params):
        return {"m": _zeros_like_tree(params, jnp.float32)}

    def update(grads, state, params, step):
        lr_t = lr(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            m_new = momentum * m + g
            d = g + momentum * m_new if nesterov else m_new
            return (p.astype(jnp.float32) - lr_t * d).astype(p.dtype), m_new

        out = jax.tree.map(upd, grads, state["m"], params)
        p_new = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m_new = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return p_new, {"m": m_new}

    return Optimizer(init, update, lambda ax: {"m": ax})


def make_optimizer(cfg, name: str = "adamw", lr=3e-4, total_steps: int = 10000,
                   warmup: int = 200) -> Optimizer:
    from repro.models.layers import dtype_of
    sched = cosine_schedule(lr, warmup, total_steps) if not callable(lr) else lr
    if name == "adamw":
        return adamw(sched, state_dtype=dtype_of(cfg.opt_state_dtype),
                     fp32_master=cfg.fp32_master and cfg.param_dtype != "float32")
    if name == "lion":
        return lion(sched)
    if name == "adafactor":
        return adafactor(sched)
    if name == "sgd":
        return sgd(sched)
    raise KeyError(name)
