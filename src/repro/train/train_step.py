"""Train-step factory: microbatched gradient accumulation (lax.scan),
global-norm clipping, optimizer update, metrics. Pure function of
(params, opt_state, step, batch) suitable for pjit with donated buffers.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import pin_params
from repro.models.transformer import lm_loss
from repro.train.optim import Optimizer


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), n


def _split_microbatches(batch: dict, m: int) -> dict:
    def split(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return x.reshape(m, b // m, *x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, *,
                    impl: str = "chunked", clip_norm: float = 1.0,
                    loss_fn: Optional[Callable] = None,
                    microbatches: Optional[int] = None,
                    grad_compression: Optional[str] = None) -> Callable:
    """Returns train_step(params, opt_state, step, batch) ->
    (params, opt_state, step+1, metrics).

    ``grad_compression="int8"`` passes the accumulated gradients through
    the edge-uplink int8 wire format (dist/compression) before clipping —
    what an edge worker's sync sees on a constrained uplink."""
    if grad_compression not in (None, "int8"):
        raise ValueError(f"unknown grad_compression {grad_compression!r}")
    loss_fn = loss_fn or (lambda p, b: lm_loss(p, cfg, b, impl=impl))
    M = microbatches if microbatches is not None else cfg.microbatches
    try:
        from repro.models import model_zoo as _zoo
        _axes = _zoo.param_axes(cfg)
    except Exception:  # custom loss over non-model params
        _axes = None

    def grads_of(params, batch):
        if _axes is not None:
            # pin the (possibly stacked) weights to their sharded layout so
            # the partitioner cannot hoist whole-stack all-gathers out of
            # the microbatch/layer loops (observed 100+ GiB/dev on jamba)
            params = pin_params(params, _axes)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def train_step(params, opt_state, step, batch):
        if M <= 1:
            loss, metrics, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
            if _axes is not None:
                grads = pin_params(grads, _axes)
        else:
            mb = _split_microbatches(batch, M)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if _axes is not None:
                zeros = pin_params(zeros, _axes)

            def body(acc, one):
                l, m, g = grads_of(params, one)
                # pin per-microbatch grads to the PARAM shardings: the
                # cross-data reduction becomes a per-layer reduce-scatter
                # instead of a full-tree all-reduce every microbatch
                # (§Perf iteration 1: ~8x collective-byte cut on mistral)
                if _axes is not None:
                    g = pin_params(g, _axes)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / M, acc, g)
                return acc, l
            grads, losses = jax.lax.scan(body, zeros, mb)
            loss = jnp.mean(losses)
            metrics = {}
        if grad_compression == "int8":
            from repro.dist.compression import int8_roundtrip
            grads = jax.tree.map(int8_roundtrip, grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, new_opt = optimizer.update(grads, opt_state, params, step)
        out_metrics = {"loss": loss.astype(jnp.float32),
                       "grad_norm": gnorm.astype(jnp.float32)}
        for k, v in (metrics or {}).items():
            out_metrics[k] = v.astype(jnp.float32)
        return new_params, new_opt, step + 1, out_metrics

    return train_step
