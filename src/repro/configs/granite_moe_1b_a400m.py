"""granite-moe-1b-a400m — small MoE, 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  24L, d_model=1024,
16H (GQA kv=8), expert d_ff=512, vocab=49155, every layer MoE, tied
embeddings. Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, MoEConfig, register

FULL = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,
    vocab_pad_multiple=256,
    mlp_act="silu_glu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512,
                  layer_period=1, capacity_factor=1.25),
    recipe="ep_fsdp",
    remat="full",
    microbatches=1,
)

SMOKE = ArchConfig(
    name="granite-moe-1b-a400m-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=64,
    vocab_size=499,
    vocab_pad_multiple=16,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                  layer_period=1, capacity_factor=2.0),
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("granite-moe-1b-a400m", FULL, SMOKE)
