"""qwen2-1.5b — dense decoder-only, aggressive GQA (kv=2), QKV bias.

[arXiv:2407.10671; hf]  28L, d_model=1536, 12H (GQA kv=2), d_ff=8960,
vocab=151936. 12 heads not divisible by model axis 16 -> FSDP recipe.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_head=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=True,
    recipe="fsdp",
    remat="full",
    microbatches=1,
)

SMOKE = ArchConfig(
    name="qwen2-1.5b-smoke",
    family="dense",
    n_layers=3,
    d_model=48,
    n_heads=3,
    n_kv_heads=1,
    d_head=16,
    d_ff=112,
    vocab_size=512,
    vocab_pad_multiple=16,
    qkv_bias=True,
    mlp_act="silu_glu",
    tie_embeddings=True,
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("qwen2-1.5b", FULL, SMOKE)
