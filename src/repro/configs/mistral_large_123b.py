"""mistral-large-123b — dense decoder-only transformer.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]  88L, d_model=12288,
96H (GQA kv=8), d_ff=28672, vocab=32768. Full attention -> long_500k skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=32768,
    mlp_act="silu_glu",
    rope_theta=1_000_000.0,
    recipe="tp_fsdp",
    remat="full",
    microbatches=8,
)

SMOKE = ArchConfig(
    name="mistral-large-123b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_head=16,
    d_ff=224,
    vocab_size=512,
    vocab_pad_multiple=16,
    mlp_act="silu_glu",
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("mistral-large-123b", FULL, SMOKE)
