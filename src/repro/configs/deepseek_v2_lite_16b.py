"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention (MLA).

[arXiv:2405.04434; hf]  27L, d_model=2048, 16H, MLA kv_lora=512,
rope/nope head dims 64/128; layer 0 dense (d_ff=10944), layers 1..26 MoE
with 64 routed experts (d_ff=1408, top-6) + 2 shared experts.
MLA is full attention -> long_500k skipped; its compressed KV cache is a
first-class serving feature (kv cache = kv_lora + rope dims per token).
"""

from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

FULL = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,               # dense layers (layer 0)
    vocab_size=102400,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared=2, d_ff_shared=2816,
                  layer_period=1, first_dense=1, capacity_factor=1.25),
    recipe="ep_fsdp",
    remat="full",
    microbatches=1,
)

SMOKE = ArchConfig(
    name="deepseek-v2-lite-16b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    vocab_size=512,
    vocab_pad_multiple=16,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                  rope_head_dim=8, nope_head_dim=16, v_head_dim=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=48,
                  num_shared=1, d_ff_shared=48,
                  layer_period=1, first_dense=1, capacity_factor=2.0),
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("deepseek-v2-lite-16b", FULL, SMOKE)
