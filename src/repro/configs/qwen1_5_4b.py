"""qwen1.5-4b — dense decoder-only with QKV bias.

[hf:Qwen/Qwen1.5-4B; hf-tier family config]  40L, d_model=2560, 20H (kv=20),
d_ff=6912, vocab=151936. 20 heads are not divisible by the 16-wide model
axis -> FSDP recipe (no head-TP); see DESIGN.md §Arch-applicability.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-4B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    mlp_act="silu_glu",
    recipe="fsdp",
    remat="full",
    microbatches=1,
)

SMOKE = ArchConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    n_layers=3,
    d_model=80,
    n_heads=5,
    n_kv_heads=5,
    d_head=16,
    d_ff=192,
    vocab_size=512,
    vocab_pad_multiple=16,
    qkv_bias=True,
    mlp_act="silu_glu",
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("qwen1.5-4b", FULL, SMOKE)
