"""seamless-m4t-medium — encoder-decoder multimodal (audio) transformer.

[arXiv:2308.11596; hf]  12L enc + 12L dec, d_model=1024, 16H (GQA kv=16),
d_ff=4096, vocab=256206. The audio frontend (speech encoder conv stack) is a
STUB: ``input_specs()`` provides precomputed frame embeddings at d=1024.
Full attention -> long_500k skipped (see DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    source="arXiv:2308.11596; hf",
    n_layers=24,            # 12 enc + 12 dec
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=256206,
    qkv_bias=True,
    mlp_act="relu",
    norm_type="layernorm",
    pos_embed="sincos",
    frontend="audio_frames",
    frontend_len=4096,
    frontend_dim=1024,
    recipe="tp_fsdp",
    remat="full",
    microbatches=4,
)

SMOKE = ArchConfig(
    name="seamless-m4t-medium-smoke",
    family="encdec",
    n_layers=4,
    enc_layers=2,
    dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=503,
    vocab_pad_multiple=16,
    qkv_bias=True,
    mlp_act="relu",
    norm_type="layernorm",
    pos_embed="sincos",
    frontend="audio_frames",
    frontend_len=16,
    frontend_dim=64,
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
    attn_chunk=64,
)

register("seamless-m4t-medium", FULL, SMOKE)
