"""nemotron-4-15b — dense decoder-only with squared-ReLU MLP.

[arXiv:2402.16819; unverified]  32L, d_model=6144, 48H (GQA kv=8),
d_ff=24576 (non-gated, squared ReLU), vocab=256000. Full attention ->
long_500k skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    source="arXiv:2402.16819",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="relu2",
    norm_type="layernorm",
    recipe="tp_fsdp",
    remat="full",
    microbatches=4,
)

SMOKE = ArchConfig(
    name="nemotron-4-15b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_head=16,
    d_ff=384,
    vocab_size=512,
    vocab_pad_multiple=16,
    mlp_act="relu2",
    norm_type="layernorm",
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("nemotron-4-15b", FULL, SMOKE)
