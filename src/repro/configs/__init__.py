from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    ARCH_IDS,
    ArchConfig,
    InputShape,
    SHAPES_BY_NAME,
    all_configs,
    get_config,
    shapes_for,
    skipped_shapes_for,
)
