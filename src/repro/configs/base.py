"""Base configuration system for S2CE-JAX.

Every assigned architecture is expressed as an :class:`ArchConfig`. Configs are
plain frozen dataclasses (hashable, usable as jit static args). A registry maps
``--arch <id>`` strings to builder functions; each ``src/repro/configs/<id>.py``
registers exactly one full-size config plus a reduced "smoke" variant used by
CPU tests.

Input shapes are global (pre-sharding) and defined once here so that every
(arch x shape) dry-run cell is well defined.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field, replace
from typing import Optional


# ---------------------------------------------------------------------------
# Input shapes (assigned): seq_len x global_batch
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0          # routed experts (0 => dense MLP)
    top_k: int = 0
    d_ff_expert: int = 0
    num_shared: int = 0           # shared (always-on) experts
    layer_period: int = 1         # MoE every `period` layers (1 = all)
    first_dense: int = 0          # leading dense layers before MoE starts
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    d_ff_shared: int = 0          # shared-expert hidden (default = d_ff_expert * num_shared)


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank q projection
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 => ceil(d_model / 16)
    chunk: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64          # rank of the data-dependent decay LoRA
    mix_lora: int = 32            # rank of token-shift mix LoRA
    chunk: int = 64


@dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"         # dense|moe|ssm|hybrid|encdec|vlm|rwkv
    source: str = ""

    # core dims
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_head: int = 64
    d_ff: int = 256
    vocab_size: int = 1024
    vocab_pad_multiple: int = 256

    # flavour knobs
    qkv_bias: bool = False
    mlp_act: str = "silu_glu"     # silu_glu|gelu_glu|relu2|relu|gelu
    norm_type: str = "rmsnorm"    # rmsnorm|layernorm
    norm_eps: float = 1e-5
    pos_embed: str = "rope"       # rope|sincos|none
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # family extensions
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None

    # hybrid (jamba): within each period of `attn_period` layers, 1 is attention
    attn_period: int = 0          # 0 => all layers are attention (or none for ssm/rwkv)
    # vlm (llama-vision): a cross-attn layer every `cross_attn_period` layers
    cross_attn_period: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    # frontend stubs: "none"|"audio_frames"|"image_patches"
    frontend: str = "none"
    frontend_len: int = 0         # tokens produced by the stub frontend
    frontend_dim: int = 0

    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    fp32_master: bool = True      # keep fp32 master weights in the optimizer
    # distribution defaults
    recipe: str = "tp_fsdp"       # dist/sharding.py recipe name
    remat: str = "full"           # none|dots|full
    microbatches: int = 1
    seq_shard: bool = True        # sequence-parallel residual stream
    attn_chunk: int = 1024        # kv-block size for chunked attention
    scan_layers: bool = True

    # serving
    kv_cache_dtype: str = "bfloat16"   # or "int8"

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return int(math.ceil(self.vocab_size / m) * m)

    @property
    def is_attention_free(self) -> bool:
        return self.family in ("ssm", "rwkv")

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear-attn / hybrid)."""
        return self.family in ("ssm", "rwkv", "hybrid")

    @property
    def d_inner_mamba(self) -> int:
        assert self.mamba is not None
        return self.mamba.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        assert self.mamba is not None
        return self.mamba.dt_rank or max(1, math.ceil(self.d_model / 16))

    def moe_layer_mask(self, n_layers: Optional[int] = None) -> tuple:
        """True per layer index if that layer uses MoE."""
        n = n_layers if n_layers is not None else self.n_layers
        if self.moe.num_experts == 0:
            return tuple(False for _ in range(n))
        out = []
        for i in range(n):
            if i < self.moe.first_dense:
                out.append(False)
            else:
                out.append((i - self.moe.first_dense) % self.moe.layer_period == 0)
        return tuple(out)

    def attn_layer_mask(self) -> tuple:
        """True per layer index if that layer is (self-)attention (hybrid)."""
        if self.attn_period <= 0:
            return tuple(True for _ in range(self.n_layers))
        # jamba convention: within each period, the middle-ish layer is attention
        out = []
        for i in range(self.n_layers):
            out.append(i % self.attn_period == self.attn_period // 2)
        return tuple(out)

    def cross_layer_mask(self) -> tuple:
        if self.cross_attn_period <= 0:
            return tuple(False for _ in range(self.n_layers))
        return tuple((i + 1) % self.cross_attn_period == 0 for i in range(self.n_layers))

    # --- parameter counting (for roofline MODEL_FLOPS) -----------------
    def param_counts(self) -> dict:
        """Returns dict with total and active parameter counts (approx, exact
        enough for 6ND roofline accounting)."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = emb
        active = emb

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qdim = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                p = d * m.kv_lora_rank + d * m.rope_head_dim  # kv down + rope k
                p += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                if m.q_lora_rank:
                    p += d * m.q_lora_rank + m.q_lora_rank * qdim
                else:
                    p += d * qdim
                p += self.n_heads * m.v_head_dim * d  # out proj
                return p
            hq = self.n_heads * self.d_head
            hkv = self.n_kv_heads * self.d_head
            return d * hq + 2 * d * hkv + hq * d

        def mlp_params(hidden: int) -> int:
            mult = 3 if self.mlp_act.endswith("_glu") else 2
            return mult * d * hidden

        def mamba_params() -> int:
            di = self.d_inner_mamba
            n = self.mamba.d_state
            r = self.dt_rank
            p = d * 2 * di              # in_proj (x and z)
            p += di * self.mamba.d_conv  # conv
            p += di * (r + 2 * n)        # x -> dt, B, C
            p += r * di                  # dt proj
            p += di * n + di             # A_log, D
            p += di * d                  # out proj
            return p

        def rwkv_params() -> int:
            c = self.rwkv
            p = 4 * d * d + d * d        # r,k,v,g + output
            p += 2 * (d * c.decay_lora + c.decay_lora * d)  # decay + dt LoRAs
            p += 6 * (d * c.mix_lora + c.mix_lora * d)      # token-shift mix LoRAs
            p += 2 * d                   # u (bonus), ln_x
            p += 2 * d * ff              # channel-mix key/value mats
            return p

        n_layers = self.n_layers if self.family != "encdec" else (self.enc_layers + self.dec_layers)
        attn_mask = self.attn_layer_mask() if self.family == "hybrid" else None
        moe_mask = self.moe_layer_mask(self.n_layers)

        for i in range(n_layers):
            if self.family == "rwkv":
                total += rwkv_params(); active += rwkv_params(); continue
            if self.family == "ssm":
                total += mamba_params(); active += mamba_params(); continue
            if self.family == "hybrid" and attn_mask is not None and not attn_mask[i % self.n_layers]:
                layer_attn = mamba_params()
            else:
                layer_attn = attn_params()
            if self.family == "encdec" and i >= self.enc_layers:
                layer_attn += attn_params()  # cross attention in decoder
            if self.family == "vlm" and self.cross_layer_mask()[i % self.n_layers]:
                layer_attn += attn_params()  # cross layers add cross-attn
            total += layer_attn
            active += layer_attn
            if i < len(moe_mask) and moe_mask[i] and self.moe.num_experts:
                e = self.moe
                per_expert = mlp_params(e.d_ff_expert)
                shared = e.num_shared * mlp_params(e.d_ff_shared or e.d_ff_expert)
                total += e.num_experts * per_expert + shared
                active += e.top_k * per_expert + shared
            else:
                total += mlp_params(ff)
                active += mlp_params(ff)
        return {"total": total, "active": active}

    def with_overrides(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}
_SMOKE_REGISTRY: dict = {}

ARCH_IDS = (
    "seamless-m4t-medium",
    "rwkv6-1.6b",
    "llama-3.2-vision-90b",
    "mistral-large-123b",
    "qwen1.5-4b",
    "nemotron-4-15b",
    "qwen2-1.5b",
    "jamba-1.5-large-398b",
    "deepseek-v2-lite-16b",
    "granite-moe-1b-a400m",
)

_MODULE_BY_ID = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def register(name: str, full: ArchConfig, smoke: ArchConfig) -> None:
    _REGISTRY[name] = full
    _SMOKE_REGISTRY[name] = smoke


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    if name not in _REGISTRY:
        mod = _MODULE_BY_ID.get(name)
        if mod is None:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULE_BY_ID)}")
        importlib.import_module(f"repro.configs.{mod}")
    return (_SMOKE_REGISTRY if smoke else _REGISTRY)[name]


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}


def shapes_for(cfg: ArchConfig):
    """The input shapes applicable to this architecture (skips recorded)."""
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue
        out.append(s)
    return tuple(out)


def skipped_shapes_for(cfg: ArchConfig):
    return tuple(s for s in ALL_SHAPES if s not in shapes_for(cfg))
