"""llama-3.2-vision-90b — VLM with interleaved cross-attention image layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  100L, d_model=8192,
64H (GQA kv=8), d_ff=28672, vocab=128256; a cross-attention layer every 5
layers attends to stubbed image patch embeddings. Full attention ->
long_500k skipped.
"""

from repro.configs.base import ArchConfig, register

FULL = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-90B-Vision",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=128256,
    mlp_act="silu_glu",
    rope_theta=500_000.0,
    cross_attn_period=5,
    frontend="image_patches",
    frontend_len=1600,
    frontend_dim=7680,
    recipe="tp_fsdp",
    remat="full",
    microbatches=8,
)

SMOKE = ArchConfig(
    name="llama-3.2-vision-90b-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=160,
    vocab_size=499,
    vocab_pad_multiple=16,
    cross_attn_period=2,
    frontend="image_patches",
    frontend_len=12,
    frontend_dim=48,
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("llama-3.2-vision-90b", FULL, SMOKE)
