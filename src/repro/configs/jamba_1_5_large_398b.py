"""jamba-1.5-large-398b — hybrid Mamba + attention (1:7) with MoE.

[arXiv:2403.19887; hf]  72L, d_model=8192, 64H (GQA kv=8), d_ff=24576,
vocab=65536; one attention layer per 8 (rest Mamba), MoE 16 experts top-2
every other layer. Hybrid -> runs long_500k (Mamba layers O(1) state; the
9 attention layers hold a sharded 500k KV cache, O(S) per decoded token).
bf16 optimizer states for memory (DESIGN.md §8).
"""

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig, register

FULL = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    pos_embed="none",          # jamba uses no positional embedding
    attn_period=8,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576,
                  layer_period=2, capacity_factor=1.25),
    recipe="ep_tp_fsdp",
    remat="full",
    microbatches=8,
    opt_state_dtype="bfloat16",
    fp32_master=False,            # 398B: bf16 m/v, no master (memory budget)
)

SMOKE = ArchConfig(
    name="jamba-1.5-large-398b-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=512,
    vocab_pad_multiple=16,
    pos_embed="none",
    attn_period=4,
    mamba=MambaConfig(d_state=4, d_conv=4, expand=2, chunk=16),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                  layer_period=2, capacity_factor=2.0),
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("jamba-1.5-large-398b", FULL, SMOKE)
