"""rwkv6-1.6b ("Finch") — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; unverified]  24L, d_model=2048, d_ff=7168 (channel mix),
vocab=65536, head_size=64 -> 32 wkv heads. Sub-quadratic: runs long_500k.
"""

from repro.configs.base import ArchConfig, RWKVConfig, register

FULL = ArchConfig(
    name="rwkv6-1.6b",
    family="rwkv",
    source="arXiv:2404.05892",
    n_layers=24,
    d_model=2048,
    n_heads=32,               # d_model / head_size
    n_kv_heads=32,
    d_head=64,
    d_ff=7168,
    vocab_size=65536,
    norm_type="layernorm",
    pos_embed="none",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32, chunk=32),
    recipe="tp_fsdp",
    remat="full",
    microbatches=4,
)

SMOKE = ArchConfig(
    name="rwkv6-1.6b-smoke",
    family="rwkv",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=224,
    vocab_size=500,
    vocab_pad_multiple=16,
    norm_type="layernorm",
    pos_embed="none",
    rwkv=RWKVConfig(head_size=16, decay_lora=8, mix_lora=4, chunk=16),
    param_dtype="float32",
    compute_dtype="float32",
    recipe="dp",
    remat="none",
    seq_shard=False,
)

register("rwkv6-1.6b", FULL, SMOKE)
