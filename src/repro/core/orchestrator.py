"""The S2CE orchestrator: one object that wires the paper's Fig. 2 together.

A :class:`StreamJob` declares sources, the transformation pipeline, the ML
payload (online learner and/or DL model), and an SLA. The orchestrator:

  1. costs the pipeline stages and *places* them on cloud/edge pools
     (core/placement),
  2. runs the edge stage (preprocess/sample/sketch/pre-model) and the cloud
     stage (drift-adaptive learning) over the stream,
  3. monitors rate + SLA and *re-plans* via the offload controller,
  4. reacts to drift alarms by adapting the learner (reset/LR bump),
  5. exposes metrics for the Output Interface.

The DL path (assigned architectures) reuses exactly the same train_step /
serve substrate as the dry-run cells; here it runs reduced configs on CPU.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CLOUD_POD, EDGE_NODE, Resource
from repro.core.offload import OffloadController, OffloadDecision
from repro.core.placement import Objective, standard_pipeline
from repro.core.sla import SLA, SLATracker
from repro.dist.elastic import ElasticController
from repro.ml import metrics as mmetrics
from repro.ml import online
from repro.streams import drift as drift_mod
from repro.streams import preprocess as prep
from repro.streams import sampling as samp
from repro.streams import sketches as sk
from repro.streams.events import StreamBatch


@dataclass
class StreamJob:
    name: str
    dim: int = 16
    n_classes: int = 2
    sla: SLA = field(default_factory=SLA)
    sample_rate: float = 0.5
    drift_detector: str = "ddm"          # ddm|eddm|ph|adwin
    edge_resource: Resource = EDGE_NODE
    cloud_resource: Resource = CLOUD_POD
    objective: Objective = field(default_factory=Objective)
    # elastic cloud-pool sizing (dist/elastic): starting worker count and cap
    workers: int = 1
    max_workers: int = 16


@dataclass
class JobMetrics:
    events: int = 0
    drift_alarms: int = 0
    migrations: int = 0
    rescales: int = 0
    workers: int = 1
    preq: Optional[dict] = None
    sla: Optional[dict] = None
    decisions: List[str] = field(default_factory=list)


class Orchestrator:
    """Runs a StreamJob over a stream of feature batches."""

    def __init__(self, job: StreamJob):
        self.job = job
        self.resources = {job.edge_resource.name: job.edge_resource,
                          job.cloud_resource.name: job.cloud_resource}
        self.ops = standard_pipeline(job.dim, sample_rate=job.sample_rate)
        self.controller = OffloadController(self.ops, self.resources,
                                            job.objective)
        self.sla = SLATracker(job.sla)
        self.elastic = ElasticController(workers=job.workers,
                                         max_workers=job.max_workers)

        # edge state
        self.norm = prep.norm_init(job.dim)
        self.reservoir = samp.reservoir_init(256, job.dim)
        self.moments = sk.moments_init(job.dim)
        # cloud state
        self.model = online.logreg_init(job.dim)
        self.preq = mmetrics.preq_init()
        det = {"ddm": (drift_mod.ddm_init, drift_mod.ddm_step),
               "eddm": (drift_mod.eddm_init, drift_mod.eddm_step),
               "ph": (drift_mod.ph_init, drift_mod.ph_step),
               "adwin": (drift_mod.adwin_init, drift_mod.adwin_step)}[
                   job.drift_detector]
        self.det_state = det[0]()
        self._det_step = jax.jit(det[1])
        self.metrics = JobMetrics()
        self._jit_edge = jax.jit(self._edge_stage)
        self._jit_cloud = jax.jit(self._cloud_stage)

    # -- stages (pure; placement decides WHERE they execute) ---------------
    def _edge_stage(self, norm, reservoir, moments, x, y, rng, rate):
        norm, xn = prep.norm_update_apply(norm, x)
        moments = sk.moments_update(moments, xn)
        reservoir = samp.reservoir_update(reservoir, xn, y)
        mask, rng = samp.bernoulli_thin(rng, xn, rate)
        return norm, reservoir, moments, xn, mask, rng

    def _cloud_stage(self, model, preq, det_state, x, y, mask):
        p = online.logreg_predict(model, x)
        err_stream = (jnp.where(p > 0.5, 1, 0) != y).astype(jnp.float32)
        # prequential: test THEN train (only on sampled rows, reweighted)
        preq = mmetrics.preq_update(preq, p, y)
        w = mask.astype(jnp.float32)
        xw = x * w[:, None]
        model = online.logreg_update(model, xw, y * mask, lr=0.5)
        det_state, levels = jax.lax.scan(self._det_step, det_state, err_stream)
        drifted = jnp.any(levels == drift_mod.DRIFT)
        return model, preq, det_state, drifted

    # -- main loop ----------------------------------------------------------
    def run(self, batches, rate_fn: Optional[Callable[[int], float]] = None,
            seed: int = 0) -> JobMetrics:
        rng = jax.random.PRNGKey(seed)
        dec = self.controller.initial_plan(
            rate_fn(0) if rate_fn else 1e4)
        self.metrics.decisions.append(f"0:init cut={dec.cut}")
        for step, batch in enumerate(batches):
            t0 = time.perf_counter()
            x = jnp.asarray(batch.data["x"])
            y = jnp.asarray(batch.data["y"])
            (self.norm, self.reservoir, self.moments, xn, mask, rng
             ) = self._jit_edge(self.norm, self.reservoir, self.moments,
                                x, y, rng, self.job.sample_rate)
            (self.model, self.preq, self.det_state, drifted
             ) = self._jit_cloud(self.model, self.preq, self.det_state,
                                 xn, y, mask)
            if bool(drifted):
                self.metrics.drift_alarms += 1
                self.model = online.logreg_reset_soft(self.model)
            dt = time.perf_counter() - t0
            rate = batch.n / max(dt, 1e-9)
            self.sla.observe(dt, rate)
            offered = rate_fn(step) if rate_fn else rate
            d = self.controller.observe(step, offered, self.sla)
            if d.reason != "hold":
                self.metrics.decisions.append(
                    f"{step}:{d.reason} cut={d.cut}")
            # elastic cloud-pool sizing: grow/shrink the worker count when
            # the offered rate persistently over/under-runs the pool
            plan = self.elastic.observe(step, offered, rate)
            if plan.changed:
                self.metrics.decisions.append(
                    f"{step}:elastic-{plan.action} workers={plan.workers} "
                    f"({plan.reason})")
            self.metrics.events += batch.n
        self.metrics.migrations = self.controller.migrations()
        self.metrics.rescales = self.elastic.rescales
        self.metrics.workers = self.elastic.workers
        self.metrics.preq = mmetrics.preq_metrics(self.preq)
        self.metrics.sla = self.sla.report()
        return self.metrics
