"""The S2CE orchestrator: one object that wires the paper's Fig. 2 together.

A :class:`StreamJob` declares sources, the transformation pipeline (a
linear :class:`~repro.core.pipeline.Pipeline` or a fan-out/rejoin
:class:`~repro.core.pipeline.OpGraph` — the default is the classic
normalize -> sketch -> sample -> train -> drift chain), the ML payload,
and an SLA. The orchestrator:

  1. costs the pipeline's op graph and *places* it over the job's
     :class:`~repro.core.costmodel.ClusterSpec` (any number of edge
     pools / cloud pods with codec-carrying links; core/placement) —
     the same op list the executor runs. The SLA error budget picks the
     cheapest admissible uplink codec (core/sla.pick_codec), attached
     to every edge->cloud link,
  2. executes the planned partition: the frontier (ops resident on any
     edge pool; a prefix for linear pipelines) as the edge segment, the
     rest as the cloud segment (core/pipeline), applying the chosen
     codec's wire round-trip to batches crossing the uplink,
  3. monitors rate + SLA, *re-plans* via the offload controller, and
     re-partitions the graph when the assignment migrates — including
     **codec migrations**: the controller re-runs codec admission
     against the windowed SLA report on every replan, and when the
     winning plan carries a different uplink codec the orchestrator
     swaps the wire round-trip fn and flushes the error-feedback
     residuals (a stale carry from the old codec's quantization
     geometry must not leak into the new one),
  4. reacts to drift alarms through each op's declared drift response,
  5. drives elastic grow/shrink plans through the real state-carrying
     ``elastic.rescale_cycle`` (checkpoint.save -> rebuild_mesh ->
     reshard_tree -> resume — the same path failure recovery takes),
  6. exposes metrics for the Output Interface.

Because segments are composed from shared per-op executables (see
core/pipeline), a migration changes *where* ops run without perturbing
*what* they compute: results are bitwise-identical to any fixed-cut run.
"""

from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, FrozenSet, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CLOUD_POD, EDGE_NODE, ClusterSpec, Resource
from repro.core.offload import OffloadController
from repro.core.pipeline import OpGraph, Pipeline, standard_stream_pipeline
from repro.core.placement import Objective
from repro.core.sla import SLA, SLATracker, codec_candidates, pick_codec
from repro.dist import elastic


@dataclass
class StreamJob:
    name: str
    dim: int = 16
    n_classes: int = 2
    sla: SLA = field(default_factory=SLA)
    # SLA telemetry window: every tracker statistic (violation rate,
    # p99) covers the last `sla_window` batches, so violations age out
    # and replanning reacts to current state, not lifetime history
    sla_window: int = 100
    sample_rate: float = 0.5
    drift_detector: str = "ddm"          # ddm|eddm|ph|adwin
    # full cluster topology (any number of edge pools / cloud pods with
    # explicit links); None -> the classic two-pool spec built from
    # edge_resource/cloud_resource below (kept for back-compat)
    cluster: Optional[ClusterSpec] = None
    # live topology: a core/membership.MembershipDirectory whose events
    # (pools joining/leaving/failing, probe-driven latency rewrites) the
    # orchestrator drains every step. Mutually exclusive with `cluster`;
    # a directory that emits no events runs bitwise identically to the
    # equivalent static spec
    membership: Optional[object] = None
    edge_resource: Resource = EDGE_NODE
    cloud_resource: Resource = CLOUD_POD
    objective: Objective = field(default_factory=Objective)
    # user-supplied operator graph (linear Pipeline or fan-out OpGraph);
    # None -> the standard S2CE chain
    pipeline: Optional[OpGraph] = None
    # measure per-op costs from a dry-run compile of the first batch
    # (selftune.measure_operator_costs) and optimize placement against
    # the measurement instead of the declared OperatorCost guesses
    measured_costs: bool = False
    # elastic cloud-pool sizing (dist/elastic): starting worker count and cap
    workers: int = 1
    max_workers: int = 16
    # where elastic rescale cycles publish checkpoints; None -> a tempdir
    ckpt_dir: Optional[str] = None
    # explicit codec ladder for SLA admission and rate-adaptive replans
    # (names resolvable by core/codecs.get_codec). None -> the default
    # gradient ladder (DEFAULT_CODECS). A serving job passes the KV
    # ladder (identity / kv_int8 / kv_latent) here so the controller's
    # escalate/de-escalate loop governs KV-cache compression
    uplink_codecs: Optional[List[str]] = None


@dataclass
class JobMetrics:
    events: int = 0
    drift_alarms: int = 0
    migrations: int = 0
    rescales: int = 0
    workers: int = 1
    preq: Optional[dict] = None
    sla: Optional[dict] = None
    decisions: List[str] = field(default_factory=list)
    cuts: List[int] = field(default_factory=list)        # |frontier| per batch
    # assignment record per batch: the frozenset of edge-resident op names
    # (the frontier VIEW — kept for back-compat; migrations count on the
    # full plan identity below)
    assignments: List[FrozenSet[str]] = field(default_factory=list)
    # full executed plan identity per batch: (sorted (op, pool) pairs,
    # uplink codec) — the identity contract of core/offload, so a
    # multi-pool rebalance that keeps the frontier but moves ops between
    # pods, or a codec-only migration, is still counted
    plan_identities: List[tuple] = field(default_factory=list)
    codecs: List[str] = field(default_factory=list)      # codec per batch
    outputs: List[dict] = field(default_factory=list)    # when recording
    # the initially admitted uplink codec (pick_codec at job start); the
    # per-batch trajectory under rate-adaptive control is `codecs`
    codec: str = "identity"


class Orchestrator:
    """Runs a StreamJob over a stream of feature batches."""

    def __init__(self, job: StreamJob):
        self.job = job
        # the cluster topology placement runs over: the job's ClusterSpec,
        # or the classic two-pool spec from edge/cloud resources. The SLA
        # error budget picks the cheapest admissible uplink codec, which
        # fills every uplink that doesn't declare its own (pricing) AND
        # is applied to batches crossing segments at runtime (execution).
        # A user-declared per-link codec wins over the blanket pick but
        # must itself fit the budget — a lossy topology under a lossless
        # SLA is a configuration conflict, not something to paper over.
        self.membership = job.membership
        self._topo_sub = None
        if self.membership is not None:
            if job.cluster is not None:
                raise ValueError(
                    "StreamJob takes either cluster= (static topology) "
                    "or membership= (live directory), not both")
            spec = self.membership.spec
            self._topo_sub = self.membership.subscribe()
        else:
            spec = (ClusterSpec.of(job.cluster) if job.cluster is not None
                    else ClusterSpec.edge_cloud(job.edge_resource,
                                                job.cloud_resource))
        # the user-declared topology, BEFORE the blanket codec attach:
        # rate-adaptive replans re-derive per-candidate specs from it
        # (user-declared per-link codecs always win over the blanket)
        self._base_cluster = spec
        from repro.core.codecs import get_codec
        self._codec_ladder = (
            [get_codec(n) for n in job.uplink_codecs]
            if job.uplink_codecs is not None else None)
        self.codec = pick_codec(job.sla, candidates=self._codec_ladder)
        self.cluster = spec.with_uplink_codec(self.codec.name)
        for e in self.cluster.edge_pools:
            for c in self.cluster.cloud_pools:
                ln = self.cluster.link(e.name, c.name)
                bound = get_codec(ln.codec).error_bound
                if bound > job.sla.error_budget + 1e-12:
                    raise ValueError(
                        f"link {ln.src}->{ln.dst} declares codec "
                        f"{ln.codec!r} (error bound {bound:.4g}) but the "
                        f"SLA error budget is {job.sla.error_budget:.4g}; "
                        f"raise the budget or drop the link codec")
        self.resources = dict(self.cluster.pools)
        self.pipeline = job.pipeline or standard_stream_pipeline(
            job.dim, sample_rate=job.sample_rate,
            drift_detector=job.drift_detector)
        # a Pipeline partitions at prefix cuts (plans identical to the
        # linear IR); any other OpGraph partitions at frontier cuts
        self.is_graph = not isinstance(self.pipeline, Pipeline)
        # the cost model prices the SAME op list the executor runs
        self.ops = self.pipeline.costs()
        # every budget-admissible codec is a replan-time candidate: the
        # controller re-runs admission against windowed SLA telemetry on
        # each replan event and may migrate the codec (a zero budget
        # leaves exactly [identity] — the codec is then pinned)
        self.codec_candidates = [
            c.name for c in codec_candidates(
                job.sla, candidates=self._codec_ladder)]
        self.controller = OffloadController(
            self.ops, self._base_cluster, job.objective,
            graph=self.pipeline if self.is_graph else None,
            codec=self.codec.name, sla_spec=job.sla,
            codec_candidates=self.codec_candidates)
        self.sla = SLATracker(job.sla, window=job.sla_window)
        # error-feedback residuals for the lossy uplink codec, keyed by
        # (batch channel, pytree leaf index) — carried across steps so
        # accumulated error stays within the codec's admitted bound
        self._uplink_residuals: Dict[tuple, object] = {}
        self.elastic = elastic.ElasticController(workers=job.workers,
                                                 max_workers=job.max_workers)
        self.states = self.pipeline.init_states()
        self.cut = 0
        self.frontier: FrozenSet[str] = frozenset()
        self.metrics = JobMetrics()
        self._ckpt_dir = job.ckpt_dir

    # -- uplink codec: the wire transform between segments ------------------
    def _uplink_fn(self):
        """The batch transform applied where data crosses the edge->cloud
        uplink (or the cloud->edge downlink of a ``downlink_ok`` split),
        or None for a lossless (identity) codec. Channels are arbitrary
        pytrees — a flat feature array or a whole KV-cache tree — and
        every float leaf round-trips the codec with its own error-
        feedback residual (keyed by ``(channel, leaf index)``); integer/
        bool/PRNG leaves cross uncompressed."""
        if self.codec.lossless:
            return None

        def uplink(env):
            out = dict(env)
            for k, v in env.items():
                if k == "rng":
                    continue
                leaves, treedef = jax.tree_util.tree_flatten(v)
                changed = False
                for i, leaf in enumerate(leaves):
                    if not jnp.issubdtype(jnp.result_type(leaf),
                                          jnp.floating):
                        continue
                    r = self._uplink_residuals.get((k, i))
                    if r is None or np.shape(r) != jnp.shape(leaf):
                        r = self.codec.init_residual(leaf)
                    # residuals live on host (numpy): elastic rescales
                    # can move op state to a different mesh between
                    # steps, and an uncommitted carry follows the
                    # batch's devices
                    dec, r = self.codec.roundtrip(
                        jnp.asarray(np.asarray(r)), leaf)
                    self._uplink_residuals[(k, i)] = np.asarray(r)
                    leaves[i] = dec
                    changed = True
                if changed:
                    out[k] = jax.tree_util.tree_unflatten(treedef, leaves)
            return out

        return uplink

    # -- codec migration: swap the wire round-trip at a replan boundary -----
    def _swap_codec(self, name: str, step: int) -> None:
        """Runtime codec migration: swap the wire round-trip fn and FLUSH
        the error-feedback residuals — a stale carry is expressed in the
        old codec's quantization geometry and would corrupt (leak stale
        mass into) the first round-trips of the new codec. The next lossy
        crossing reseeds zero residuals via ``init_residual``."""
        from repro.core.codecs import get_codec
        old = self.codec.name
        self.codec = get_codec(name)
        self._uplink_residuals.clear()
        self.cluster = self._base_cluster.with_uplink_codec(name)
        self._uplink = self._uplink_fn()
        self.metrics.decisions.append(f"{step}:codec {old}->{name}")

    # -- drift response: each op declares its own -------------------------
    def _apply_drift_response(self):
        for op in self.pipeline.ops:
            if op.on_drift is not None:
                self.states[op.name] = op.on_drift(self.states[op.name])

    def _collect_op_metrics(self) -> Optional[dict]:
        out: Dict[str, float] = {}
        for op in self.pipeline.ops:
            if op.metrics is not None:
                out.update(op.metrics(self.states[op.name]))
        return out or None

    # -- elastic rescale: the ROADMAP save->rebuild->reshard->resume cycle --
    def _apply_rescale(self, step: int, plan) -> None:
        """Drive an elastic grow/shrink through ``elastic.rescale_cycle``:
        the op states round-trip a published checkpoint and come back
        resident (replicated) on the rebuilt mesh — the same machinery a
        failure recovery takes, so values are preserved bitwise."""
        if self._ckpt_dir is None:
            self._ckpt_dir = tempfile.mkdtemp(
                prefix=f"s2ce-{self.job.name}-elastic-")
        axes = elastic.replicated_axes(self.states)
        self.states, mesh = elastic.rescale_cycle(
            self._ckpt_dir, step, self.states, axes, {}, plan.workers,
            meta={"reason": plan.reason, "job": self.job.name}, keep=2)
        self.metrics.decisions.append(
            f"{step}:elastic-{plan.action} workers={plan.workers} "
            f"mesh={tuple(mesh.devices.shape)} ({plan.reason})")

    # -- dynamic topology: membership events drive the run ------------------
    def set_cluster(self, spec) -> None:
        """Swap the topology mid-run (membership churn). The controller's
        candidate set updates IMMEDIATELY — a lost pool is excluded
        before the next placement search runs — and the blanket SLA
        codec re-attaches to the new uplink set."""
        self._base_cluster = ClusterSpec.of(spec)
        self.cluster = self._base_cluster.with_uplink_codec(self.codec.name)
        self.resources = dict(self.cluster.pools)
        self.controller.set_resources(self._base_cluster)

    def topology_step(self, step: int, offered: float) -> list:
        """Drain membership events and react: a lost pool the executing
        plan touches rides the involuntary checkpoint-rescale path and
        forces a replan with the dead pool already excluded; a join
        replans so the plan can spread onto the new capacity; a probe-
        driven link update re-prices silently at the next replan. With
        no directory (or no events) this is a strict no-op — the
        zero-event trajectory stays bitwise identical to a static spec.
        Returns the events handled."""
        if self._topo_sub is None:
            return []
        self.membership.tick(step)
        events = self._topo_sub.poll()
        for ev in events:
            self._apply_topology_event(step, ev, offered)
        return events

    def _apply_topology_event(self, step: int, ev, offered: float) -> None:
        from repro.core import membership as ms
        spec_now = self.membership.spec
        if ev.kind in (ms.POOL_FAILED, ms.POOL_LEFT):
            lost = ev.subject
            touched = lost in set(self._exec_assignment.values())
            self.metrics.decisions.append(
                f"{step}:topology {ev.kind} {lost} v{ev.version}"
                + (" [in plan]" if touched else ""))
            self.set_cluster(spec_now)
            if not touched:
                # dead pool carried none of this job's ops: the
                # candidate set shrank, the plan stands as-is
                return
            # involuntary shrink: checkpoint -> rebuild mesh -> reshard
            # (state held on the lost pool survives via the published
            # checkpoint, the same path failure recovery takes) ...
            plan = self.elastic.involuntary(
                step, reason=f"pool {lost} {ev.kind}")
            self._apply_rescale(step, plan)
            # ... then a forced replan over the survivor-only spec: the
            # DP never sees the dead pool as a candidate
            d = self.controller.replan(step, offered, self.sla,
                                       reason="pool_lost")
            self.apply_decision(step, d)
        elif ev.kind == ms.POOL_JOINED:
            self.metrics.decisions.append(
                f"{step}:topology pool_joined {ev.subject} v{ev.version}")
            self.set_cluster(spec_now)
            d = self.controller.replan(step, offered, self.sla,
                                       reason="pool_joined")
            self.apply_decision(step, d)
        elif ev.kind == ms.LINK_UPDATE:
            # refreshed latencies re-price the next (voluntary) replan;
            # a probe alone never forces a migration
            self.set_cluster(spec_now)

    def _measure_costs(self, batches):
        """Close the self-tuning loop (ROADMAP item 5): peek the first
        batch, dry-run-measure every op's cost at its true input
        signature (:func:`repro.core.selftune.measure_operator_costs`),
        and install the measurements on the pipeline and controller so
        the INITIAL plan — and every replan after it — optimizes against
        what the compiler actually emits, not the hand-written guesses.
        Returns the stream with the peeked batch put back in front."""
        import itertools

        from repro.core import selftune
        it = iter(batches)
        try:
            first = next(it)
        except StopIteration:
            return iter(())
        bd = {k: jnp.asarray(v) for k, v in first.data.items()}
        # the dry-run sees the same batch signature run() feeds,
        # including the per-step rng key (any key: it prices, not learns)
        bd.setdefault("rng", jax.random.PRNGKey(0))
        measured, notes = selftune.measure_operator_costs(self.pipeline, bd)
        if measured:
            self.pipeline.set_measured_costs(measured)
            self.ops = self.pipeline.costs()
            self.controller.ops = self.ops
        self.metrics.decisions.append(
            f"0:measured-costs {len(measured)}/{len(self.pipeline.ops)} ops"
            + (f" ({len(notes)} kept declared)" if notes else ""))
        return itertools.chain([first], it)

    # -- step primitives ----------------------------------------------------
    # run() composes these; the fleet orchestrator (core/fleet) drives
    # them directly so N tenant jobs can interleave batch execution with
    # fleet-arbitrated (instead of per-job immediate) replanning.

    def begin(self, rate0: float, seed: int = 0,
              fixed_cut: Optional[int] = None,
              fixed_frontier: Optional[Iterable[str]] = None,
              decision=None):
        """Take (or adopt) the initial plan and arm the run state.
        ``decision`` lets a fleet admission pass hand over the
        OffloadDecision it already took through this job's controller —
        ``begin`` then must not call ``initial_plan`` a second time."""
        self._root_rng = jax.random.PRNGKey(seed)
        dec = decision if decision is not None else \
            self.controller.initial_plan(rate0)
        if fixed_frontier is not None:
            self.frontier = self.pipeline.check_frontier(fixed_frontier)
        elif fixed_cut is not None:
            self.frontier = frozenset(self.pipeline.names[:fixed_cut])
        else:
            self.frontier = dec.frontier
        self._pinned = fixed_cut is not None or fixed_frontier is not None
        self.cut = len(self.frontier)
        # the executed plan identity (assignment + codec) in force; a
        # pinned reference run keeps it constant -> 0 executed migrations
        if self._pinned:
            e = self.cluster.edge_pools[0].name
            c = self.cluster.cloud_pools[0].name
            self._exec_assignment = {
                n: (e if n in self.frontier else c)
                for n in self.pipeline.names}
        else:
            self._exec_assignment = dict(dec.assignment)
        self.metrics.codec = self.codec.name
        self.metrics.decisions.append(
            f"0:init cut={self.cut} codec={self.codec.name}")
        self._uplink = self._uplink_fn()
        return dec

    def execute_batch(self, step: int, batch,
                      record_outputs: bool = False) -> float:
        """Execute one batch under the plan in force; record metrics and
        feed the SLA tracker. Returns the measured event rate."""
        t0 = time.perf_counter()
        bd = {k: jnp.asarray(v) for k, v in batch.data.items()}
        # a fresh per-step key: pipelines with no rng-threading op used
        # to see the SAME key every batch (stale-RNG bug); splitting
        # here makes randomness advance regardless of the op set
        bd["rng"] = jax.random.fold_in(self._root_rng, step)
        if self.is_graph:
            self.states, out = self.pipeline.run(self.states, bd,
                                                 self.frontier,
                                                 uplink=self._uplink)
        else:
            self.states, out = self.pipeline.run(self.states, bd,
                                                 self.cut,
                                                 uplink=self._uplink)
        self.metrics.cuts.append(self.cut)
        self.metrics.assignments.append(self.frontier)
        self.metrics.codecs.append(self.codec.name)
        self.metrics.plan_identities.append(
            (tuple(sorted(self._exec_assignment.items())),
             self.codec.name))
        if record_outputs:
            self.metrics.outputs.append(
                {k: np.asarray(v) for k, v in out.items() if k != "rng"})
        if "drifted" in out and bool(out["drifted"]):
            self.metrics.drift_alarms += 1
            self._apply_drift_response()
        dt = time.perf_counter() - t0
        rate = batch.n / max(dt, 1e-9)
        self.sla.observe(dt, rate)
        self.metrics.events += batch.n
        return rate

    def apply_decision(self, step: int, d) -> None:
        """Apply an OffloadDecision to the executing partition: codec
        migration and/or re-partition. Hold decisions are no-ops beyond
        the decision log."""
        if d.reason != "hold":
            self.metrics.decisions.append(
                f"{step}:{d.reason} cut={d.cut}")
        if self._pinned:
            return
        if d.codec != self.codec.name:
            # codec migration: new wire round-trip, flushed EF
            # residuals (frontier may or may not move with it)
            self._swap_codec(d.codec, step)
        if d.frontier != self.frontier:
            # migration: re-partition — the next pipeline.run
            # re-fuses segments for the new cut (compile cache
            # makes revisits free)
            self.metrics.decisions.append(
                f"{step}:repartition {self.cut}->{d.cut} "
                f"edge={sorted(d.frontier)}")
            self.frontier = d.frontier
            self.cut = len(d.frontier)
        self._exec_assignment = dict(d.assignment)

    def elastic_step(self, step: int, offered: float, rate: float) -> None:
        """Elastic cloud-pool sizing: grow/shrink the worker count when
        the offered rate persistently over/under-runs the pool; a
        changed plan is DRIVEN through the checkpoint rescale cycle."""
        plan = self.elastic.observe(step, offered, rate)
        if plan.changed:
            self._apply_rescale(step, plan)

    def finish(self) -> JobMetrics:
        """Derive the executed-migration count and final telemetry."""
        # migrations = plan-identity changes that actually EXECUTED (the
        # full (assignment, codec) identity per core/offload's contract:
        # a pod rebalance that keeps the frontier, or a codec-only swap,
        # still counts; a pinned reference run reports 0 even when the
        # controller's virtual plan moved)
        self.metrics.migrations = sum(
            1 for a, b in zip(self.metrics.plan_identities,
                              self.metrics.plan_identities[1:])
            if a != b)
        self.metrics.rescales = self.elastic.rescales
        self.metrics.workers = self.elastic.workers
        self.metrics.preq = self._collect_op_metrics()
        self.metrics.sla = self.sla.report()
        return self.metrics

    # -- main loop ----------------------------------------------------------
    def run(self, batches, rate_fn: Optional[Callable[[int], float]] = None,
            seed: int = 0, fixed_cut: Optional[int] = None,
            fixed_frontier: Optional[Iterable[str]] = None,
            record_outputs: bool = False) -> JobMetrics:
        """Run the job. ``fixed_cut`` (linear) or ``fixed_frontier`` (DAG)
        pins the partition (reference runs / ablations); otherwise the
        offload controller's plan drives which segment each op executes
        in, re-partitioning on migration."""
        if self.job.measured_costs:
            batches = self._measure_costs(batches)
        self.begin(rate_fn(0) if rate_fn else 1e4, seed=seed,
                   fixed_cut=fixed_cut, fixed_frontier=fixed_frontier)
        for step, batch in enumerate(batches):
            rate = self.execute_batch(step, batch, record_outputs)
            offered = rate_fn(step) if rate_fn else rate
            # membership churn first: a dead pool must leave the
            # candidate set (and the executing plan) before the regular
            # control pass could decide to hold a stale plan
            self.topology_step(step, offered)
            d = self.controller.observe(step, offered, self.sla)
            self.apply_decision(step, d)
            self.elastic_step(step, offered, rate)
        return self.finish()
