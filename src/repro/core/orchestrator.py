"""The S2CE orchestrator: one object that wires the paper's Fig. 2 together.

A :class:`StreamJob` declares sources, the transformation pipeline (any
:class:`~repro.core.pipeline.Pipeline` — the default is the classic
normalize -> sketch -> sample -> train -> drift chain), the ML payload,
and an SLA. The orchestrator:

  1. costs the pipeline's op list and *places* it on cloud/edge pools
     (core/placement) — the same op list the executor runs,
  2. executes the planned partition: ops[:cut] as the edge segment,
     ops[cut:] as the cloud segment (core/pipeline),
  3. monitors rate + SLA, *re-plans* via the offload controller, and
     re-partitions the pipeline when the cut migrates,
  4. reacts to drift alarms through each op's declared drift response,
  5. exposes metrics for the Output Interface.

Because segments are composed from shared per-op executables (see
core/pipeline), a migration changes *where* ops run without perturbing
*what* they compute: results are bitwise-identical to any fixed-cut run.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel import CLOUD_POD, EDGE_NODE, Resource
from repro.core.offload import OffloadController
from repro.core.pipeline import Pipeline, standard_stream_pipeline
from repro.core.placement import Objective
from repro.core.sla import SLA, SLATracker
from repro.dist.elastic import ElasticController


@dataclass
class StreamJob:
    name: str
    dim: int = 16
    n_classes: int = 2
    sla: SLA = field(default_factory=SLA)
    sample_rate: float = 0.5
    drift_detector: str = "ddm"          # ddm|eddm|ph|adwin
    edge_resource: Resource = EDGE_NODE
    cloud_resource: Resource = CLOUD_POD
    objective: Objective = field(default_factory=Objective)
    # user-supplied operator graph; None -> the standard S2CE chain
    pipeline: Optional[Pipeline] = None
    # elastic cloud-pool sizing (dist/elastic): starting worker count and cap
    workers: int = 1
    max_workers: int = 16


@dataclass
class JobMetrics:
    events: int = 0
    drift_alarms: int = 0
    migrations: int = 0
    rescales: int = 0
    workers: int = 1
    preq: Optional[dict] = None
    sla: Optional[dict] = None
    decisions: List[str] = field(default_factory=list)
    cuts: List[int] = field(default_factory=list)        # cut per batch
    outputs: List[dict] = field(default_factory=list)    # when recording


class Orchestrator:
    """Runs a StreamJob over a stream of feature batches."""

    def __init__(self, job: StreamJob):
        self.job = job
        self.resources = {job.edge_resource.name: job.edge_resource,
                          job.cloud_resource.name: job.cloud_resource}
        self.pipeline = job.pipeline or standard_stream_pipeline(
            job.dim, sample_rate=job.sample_rate,
            drift_detector=job.drift_detector)
        # the cost model prices the SAME op list the executor runs
        self.ops = self.pipeline.costs()
        self.controller = OffloadController(self.ops, self.resources,
                                            job.objective)
        self.sla = SLATracker(job.sla)
        self.elastic = ElasticController(workers=job.workers,
                                         max_workers=job.max_workers)
        self.states = self.pipeline.init_states()
        self.cut = 0
        self.metrics = JobMetrics()

    # -- drift response: each op declares its own -------------------------
    def _apply_drift_response(self):
        for op in self.pipeline.ops:
            if op.on_drift is not None:
                self.states[op.name] = op.on_drift(self.states[op.name])

    def _collect_op_metrics(self) -> Optional[dict]:
        out: Dict[str, float] = {}
        for op in self.pipeline.ops:
            if op.metrics is not None:
                out.update(op.metrics(self.states[op.name]))
        return out or None

    # -- main loop ----------------------------------------------------------
    def run(self, batches, rate_fn: Optional[Callable[[int], float]] = None,
            seed: int = 0, fixed_cut: Optional[int] = None,
            record_outputs: bool = False) -> JobMetrics:
        """Run the job. ``fixed_cut`` pins the partition (reference runs /
        ablations); otherwise the offload controller's plan drives which
        segment each op executes in, re-partitioning on migration."""
        rng = jax.random.PRNGKey(seed)
        dec = self.controller.initial_plan(rate_fn(0) if rate_fn else 1e4)
        self.cut = fixed_cut if fixed_cut is not None else dec.cut
        self.metrics.decisions.append(f"0:init cut={self.cut}")
        for step, batch in enumerate(batches):
            t0 = time.perf_counter()
            bd = {k: jnp.asarray(v) for k, v in batch.data.items()}
            bd["rng"] = rng
            self.states, out = self.pipeline.run(self.states, bd, self.cut)
            rng = out.get("rng", rng)
            self.metrics.cuts.append(self.cut)
            if record_outputs:
                self.metrics.outputs.append(
                    {k: np.asarray(v) for k, v in out.items() if k != "rng"})
            if "drifted" in out and bool(out["drifted"]):
                self.metrics.drift_alarms += 1
                self._apply_drift_response()
            dt = time.perf_counter() - t0
            rate = batch.n / max(dt, 1e-9)
            self.sla.observe(dt, rate)
            offered = rate_fn(step) if rate_fn else rate
            d = self.controller.observe(step, offered, self.sla)
            if d.reason != "hold":
                self.metrics.decisions.append(
                    f"{step}:{d.reason} cut={d.cut}")
            if fixed_cut is None and d.cut != self.cut:
                # migration: re-partition — the next pipeline.run re-fuses
                # segments for the new cut (compile cache makes revisits free)
                self.metrics.decisions.append(
                    f"{step}:repartition {self.cut}->{d.cut}")
                self.cut = d.cut
            # elastic cloud-pool sizing: grow/shrink the worker count when
            # the offered rate persistently over/under-runs the pool
            plan = self.elastic.observe(step, offered, rate)
            if plan.changed:
                self.metrics.decisions.append(
                    f"{step}:elastic-{plan.action} workers={plan.workers} "
                    f"({plan.reason})")
            self.metrics.events += batch.n
        # migrations = partition changes that actually EXECUTED (a
        # fixed_cut reference run reports 0 even when the controller's
        # virtual plan moved)
        self.metrics.migrations = sum(
            1 for a, b in zip(self.metrics.cuts, self.metrics.cuts[1:])
            if a != b)
        self.metrics.rescales = self.elastic.rescales
        self.metrics.workers = self.elastic.workers
        self.metrics.preq = self._collect_op_metrics()
        self.metrics.sla = self.sla.report()
        return self.metrics
