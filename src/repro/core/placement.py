"""Operator placement across heterogeneous cloud/edge pools (S2CE O2).

Placement of a stream pipeline onto heterogeneous resources is NP-hard
(§2.3 [17]); the tractable structure is the *downward-closed cut*: in
any feasible assignment the edge-resident op set contains all of its own
ancestors, because a cloud op feeding an edge op would route a high-rate
stream back over the constrained link (backhaul — infeasible by the cost
model). For a linear chain the downward-closed sets are the prefixes, so
:func:`place` searches all prefix cuts exactly (unchanged from the
linear IR); for an operator DAG over a :class:`ClusterSpec`,
:func:`place_frontier` enumerates every downward-closed *frontier* of
the graph and, when the spec declares several pools of a kind, every
within-kind pool assignment (frontier ops across edge pools, the
complement across cloud pods) — which covers exactly the backhaul-free
assignments, so the search provably matches the exhaustive all-
assignments oracle (:func:`place_graph_exhaustive`; hypothesis-tested on
random small DAGs with multi-pool specs).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import (Dict, FrozenSet, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.costmodel import (ClusterSpec, OperatorCost, PipelinePlan,
                                  Resource, ResourcesLike,
                                  evaluate_graph_plan, evaluate_plan)


@dataclass
class Objective:
    latency_weight: float = 1.0
    energy_weight: float = 0.0
    uplink_weight: float = 0.2

    def score(self, plan: PipelinePlan) -> float:
        if not plan.feasible:
            return float("inf")
        return (self.latency_weight * plan.latency_s
                + self.energy_weight * plan.energy_w * 1e-3
                + self.uplink_weight * plan.uplink_utilization)


def edge_cloud_pools(resources: ResourcesLike
                     ) -> Tuple[Resource, Resource]:
    """The (edge, cloud) pool pair two-pool placement runs over.

    .. deprecated::
        This is the thin back-compat shim for the flat two-pool world:
        it collapses a :class:`ClusterSpec` (or legacy resource dict) to
        the *first* pool of each kind, ignoring any further pools and
        their links. New code should pass a ``ClusterSpec`` to
        :func:`place_frontier`, which places across every pool. The shim
        keeps prefix-cut call sites and the PR 2/3 parity tests working
        unchanged.

    Raises a clear ``ValueError`` when either kind is missing — instead
    of the bare ``StopIteration`` a ``next()`` over an ill-formed
    resource dict used to surface.
    """
    spec = ClusterSpec.of(resources)
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "prefix-cut placement needs at least one 'edge' and one "
            f"'cloud' pool; resource dict has kinds {kinds or '(empty)'}")
    return edges[0], clouds[0]


def prefix_cut_plans(ops: List[OperatorCost], resources: ResourcesLike,
                     rate: float):
    """All plans of the form: stages[:k] on edge, stages[k:] on cloud.
    Two-pool only (first pool of each kind via the deprecated
    :func:`edge_cloud_pools` shim)."""
    edge, cloud = edge_cloud_pools(resources)
    for k in range(len(ops) + 1):
        assign = {op.name: (edge.name if i < k else cloud.name)
                  for i, op in enumerate(ops)}
        yield k, evaluate_plan(ops, assign, resources, rate)


def place(ops: List[OperatorCost], resources: ResourcesLike,
          rate: float, objective: Optional[Objective] = None
          ) -> Tuple[PipelinePlan, int]:
    """Best prefix-cut placement. Returns (plan, cut_index)."""
    objective = objective or Objective()
    best, best_k, best_score = None, 0, float("inf")
    for k, plan in prefix_cut_plans(ops, resources, rate):
        s = objective.score(plan)
        if s < best_score:
            best, best_k, best_score = plan, k, s
    if best is None or not best.feasible:
        # all-cloud fallback (always structurally valid; may still be
        # infeasible under extreme rates — caller must check .feasible)
        _, cloud = edge_cloud_pools(resources)
        assign = {op.name: cloud.name for op in ops}
        best = evaluate_plan(ops, assign, resources, rate)
        best_k = 0
    return best, best_k


def place_exhaustive(ops: List[OperatorCost], resources: ResourcesLike,
                     rate: float, objective: Optional[Objective] = None
                     ) -> PipelinePlan:
    """Oracle: try every assignment (exponential; tests only)."""
    objective = objective or Objective()
    names = list(ClusterSpec.of(resources))
    best, best_score = None, float("inf")
    for combo in itertools.product(names, repeat=len(ops)):
        assign = {op.name: r for op, r in zip(ops, combo)}
        plan = evaluate_plan(ops, assign, resources, rate)
        s = objective.score(plan)
        if s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# DAG placement: frontier (downward-closed) cuts over an OpGraph, with
# multi-pool assignment within each side of the cut
# ---------------------------------------------------------------------------

def _graph_plan(graph, assign: Dict[str, str],
                resources: ResourcesLike, rate: float) -> PipelinePlan:
    return evaluate_graph_plan(
        graph.costs(), graph.flow_edges, assign, resources, rate,
        source_consumers=graph.source_consumers,
        source_bytes=graph.source_bytes_per_event)


def _frontier_assignments(names: List[str], frontier: FrozenSet[str],
                          edge_names: List[str], cloud_names: List[str]
                          ) -> Iterator[Dict[str, str]]:
    """Every assignment that realizes ``frontier``: each frontier op on
    one of the edge pools, each complement op on one of the cloud pods.
    With one pool of each kind this yields exactly one assignment (the
    classic two-pool cut)."""
    f_ops = [n for n in names if n in frontier]
    c_ops = [n for n in names if n not in frontier]
    for e_combo in itertools.product(edge_names, repeat=len(f_ops)):
        base = dict(zip(f_ops, e_combo))
        for c_combo in itertools.product(cloud_names, repeat=len(c_ops)):
            assign = dict(base)
            assign.update(zip(c_ops, c_combo))
            yield assign


def _codec_specs(spec: ClusterSpec, codecs: Optional[Sequence[str]]
                 ) -> List[Tuple[Optional[str], ClusterSpec]]:
    """The (codec name, spec-with-that-uplink-codec) pairs a codec-aware
    search prices. ``codecs=None`` -> the spec as declared (one entry,
    codec ``None``). A user-declared per-link lossy codec is preserved
    (``with_uplink_codec`` default), so the blanket candidate fills only
    undeclared uplinks."""
    if codecs is None:
        return [(None, spec)]
    return [(c, spec.with_uplink_codec(c)) for c in codecs]


def frontier_plans(graph, resources: ResourcesLike, rate: float,
                   objective: Optional[Objective] = None,
                   codecs: Optional[Sequence[str]] = None
                   ) -> Iterator[Tuple[FrozenSet[str], PipelinePlan]]:
    """For every downward-closed frontier of ``graph``: the best plan
    (under ``objective``) over all within-kind pool assignments — the
    frontier across the spec's edge pools, its complement across the
    cloud pods. For a one-edge/one-cloud spec each frontier has exactly
    one assignment, so this degenerates to the classic two-pool frontier
    enumeration (and, for a linear :class:`~repro.core.pipeline.Pipeline`,
    to :func:`prefix_cut_plans`).

    ``codecs`` makes the uplink codec a searched plan dimension: each
    candidate name is attached to the spec's uplinks
    (:meth:`~repro.core.costmodel.ClusterSpec.with_uplink_codec`) and
    the winning plan per frontier is the best (pool-assignment, codec)
    pair, with ``plan.uplink_codec`` recording the codec it was priced
    under. Pass candidates most-faithful-first so score ties (e.g. a
    frontier with no uplink crossing) resolve toward lossless.
    """
    spec = ClusterSpec.of(resources)
    objective = objective or Objective()
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "frontier placement needs at least one 'edge' and one 'cloud' "
            f"pool; ClusterSpec has kinds {kinds or '(empty)'}")
    e_names = [r.name for r in edges]
    c_names = [r.name for r in clouds]
    names = graph.names
    specs = _codec_specs(spec, codecs)
    for frontier in graph.frontiers():
        best, best_score = None, float("inf")
        for assign in _frontier_assignments(names, frontier,
                                            e_names, c_names):
            for cname, cspec in specs:
                plan = _graph_plan(graph, assign, cspec, rate)
                plan.uplink_codec = cname
                s = objective.score(plan)
                if best is None or s < best_score:
                    best, best_score = plan, s
        yield frontier, best


def place_frontier(graph, resources: ResourcesLike, rate: float,
                   objective: Optional[Objective] = None,
                   codecs: Optional[Sequence[str]] = None
                   ) -> Tuple[PipelinePlan, FrozenSet[str]]:
    """Best frontier-cut placement of an operator DAG over a
    :class:`ClusterSpec` — multi-pool: each frontier side may split
    across the pools of its kind, priced per crossing link with
    codec-compressed bytes. With ``codecs`` the winning plan is the best
    (frontier, pool-assignment, codec) triple and ``plan.uplink_codec``
    names the codec it was priced under. Returns ``(plan, frontier)``
    where ``frontier`` is the edge-resident op set (``plan.assignment``
    holds the per-op pool detail)."""
    objective = objective or Objective()
    best, best_f, best_score = None, frozenset(), float("inf")
    for frontier, plan in frontier_plans(graph, resources, rate, objective,
                                         codecs=codecs):
        s = objective.score(plan)
        if s < best_score or (s == best_score and best is not None
                              and len(frontier) < len(best_f)):
            best, best_f, best_score = plan, frontier, s
    if best is None or not best.feasible:
        # all-cloud fallback (the empty frontier on the first pod is
        # always structurally valid; may still be infeasible under
        # extreme rates — caller must check .feasible)
        spec = ClusterSpec.of(resources)
        cloud = spec.cloud_pools[0]
        assign = {name: cloud.name for name in graph.names}
        fb, fb_score = None, float("inf")
        for cname, cspec in _codec_specs(spec, codecs):
            plan = _graph_plan(graph, assign, cspec, rate)
            plan.uplink_codec = cname
            s = objective.score(plan)
            if fb is None or s < fb_score:
                fb, fb_score = plan, s
        best, best_f = fb, frozenset()
    return best, best_f


def place_graph_exhaustive(graph, resources: ResourcesLike,
                           rate: float,
                           objective: Optional[Objective] = None
                           ) -> PipelinePlan:
    """Oracle for DAG placement: every assignment of every op to every
    pool of the spec — including non-downward-closed and cross-kind-
    scrambled ones (exponential; tests and the benchmark harness only).
    With a multi-pool ClusterSpec this is the multi-pool oracle
    :func:`place_frontier` is checked against."""
    objective = objective or Objective()
    spec = ClusterSpec.of(resources)
    rnames = list(spec)
    best, best_score = None, float("inf")
    for combo in itertools.product(rnames, repeat=len(graph.names)):
        assign = dict(zip(graph.names, combo))
        plan = _graph_plan(graph, assign, spec, rate)
        s = objective.score(plan)
        if best is None or s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# Standard S2CE pipeline stage costs
# ---------------------------------------------------------------------------

def standard_pipeline(dim: int = 32, model_flops_per_event: float = 2e6,
                      sample_rate: float = 0.25) -> List[OperatorCost]:
    """ingest -> preprocess -> sample/sketch -> pre-model -> full train.

    A synthetic DL-payload cost-list *exemplar* (placement oracle tests,
    S3 benchmark, edge_cloud example). Executable jobs should not use
    this: build a :class:`repro.core.pipeline.Pipeline` and price it via
    ``Pipeline.costs()`` so the optimizer and the executor consume the
    same op list.
    """
    ev = 4.0 * dim
    return [
        OperatorCost("ingest", flops_per_event=10 * dim,
                     bytes_per_event=2 * ev, out_bytes_per_event=ev),
        OperatorCost("preprocess", flops_per_event=50 * dim,
                     bytes_per_event=4 * ev, out_bytes_per_event=ev),
        OperatorCost("sample", flops_per_event=20,
                     bytes_per_event=2 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("pre_model", flops_per_event=4 * dim * dim,
                     bytes_per_event=6 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("dl_train", flops_per_event=model_flops_per_event,
                     bytes_per_event=20 * ev,
                     out_bytes_per_event=64, edge_capable=False),
    ]
