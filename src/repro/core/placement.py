"""Operator placement across heterogeneous cloud/edge pools (S2CE O2).

Placement of a stream pipeline onto heterogeneous resources is NP-hard
(§2.3 [17]); the tractable structure is the *downward-closed cut*: the
optimal assignment puts an ancestor-closed set of operators on the edge
and the rest on the cloud, because moving an op whose input already
crossed the uplink back to the (slower) edge only adds transfers and
compute latency. For a linear chain the downward-closed sets are the
prefixes, so :func:`place` searches all prefix cuts exactly (unchanged
from the linear IR); for an operator DAG, :func:`place_frontier`
enumerates every downward-closed *frontier* of the graph — the antichain
cuts — and prices each crossing edge individually. Both fall back to
exhaustive assignment search on small graphs as the oracle the tests
check against (:func:`place_exhaustive` / :func:`place_graph_exhaustive`).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

from repro.core.costmodel import (OperatorCost, PipelinePlan, Resource,
                                  evaluate_graph_plan, evaluate_plan)


@dataclass
class Objective:
    latency_weight: float = 1.0
    energy_weight: float = 0.0
    uplink_weight: float = 0.2

    def score(self, plan: PipelinePlan) -> float:
        if not plan.feasible:
            return float("inf")
        return (self.latency_weight * plan.latency_s
                + self.energy_weight * plan.energy_w * 1e-3
                + self.uplink_weight * plan.uplink_utilization)


def edge_cloud_pools(resources: Dict[str, Resource]
                     ) -> Tuple[Resource, Resource]:
    """The (edge, cloud) pool pair prefix-cut placement runs over.

    Explicitly takes the *first* pool of each kind (insertion order) when
    several are present, and raises a clear ``ValueError`` when either
    kind is missing — instead of the bare ``StopIteration`` a ``next()``
    over an ill-formed resource dict used to surface.
    """
    edges = [r for r in resources.values() if r.kind == "edge"]
    clouds = [r for r in resources.values() if r.kind == "cloud"]
    if not edges or not clouds:
        kinds = sorted({r.kind for r in resources.values()})
        raise ValueError(
            "prefix-cut placement needs at least one 'edge' and one "
            f"'cloud' pool; resource dict has kinds {kinds or '(empty)'}")
    return edges[0], clouds[0]


def prefix_cut_plans(ops: List[OperatorCost], resources: Dict[str, Resource],
                     rate: float):
    """All plans of the form: stages[:k] on edge, stages[k:] on cloud."""
    edge, cloud = edge_cloud_pools(resources)
    for k in range(len(ops) + 1):
        assign = {op.name: (edge.name if i < k else cloud.name)
                  for i, op in enumerate(ops)}
        yield k, evaluate_plan(ops, assign, resources, rate)


def place(ops: List[OperatorCost], resources: Dict[str, Resource],
          rate: float, objective: Optional[Objective] = None
          ) -> Tuple[PipelinePlan, int]:
    """Best prefix-cut placement. Returns (plan, cut_index)."""
    objective = objective or Objective()
    best, best_k, best_score = None, 0, float("inf")
    for k, plan in prefix_cut_plans(ops, resources, rate):
        s = objective.score(plan)
        if s < best_score:
            best, best_k, best_score = plan, k, s
    if best is None or not best.feasible:
        # all-cloud fallback (always structurally valid; may still be
        # infeasible under extreme rates — caller must check .feasible)
        _, cloud = edge_cloud_pools(resources)
        assign = {op.name: cloud.name for op in ops}
        best = evaluate_plan(ops, assign, resources, rate)
        best_k = 0
    return best, best_k


def place_exhaustive(ops: List[OperatorCost], resources: Dict[str, Resource],
                     rate: float, objective: Optional[Objective] = None
                     ) -> PipelinePlan:
    """Oracle: try every assignment (exponential; tests only)."""
    objective = objective or Objective()
    names = list(resources)
    best, best_score = None, float("inf")
    for combo in itertools.product(names, repeat=len(ops)):
        assign = {op.name: r for op, r in zip(ops, combo)}
        plan = evaluate_plan(ops, assign, resources, rate)
        s = objective.score(plan)
        if s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# DAG placement: frontier (downward-closed) cuts over an OpGraph
# ---------------------------------------------------------------------------

def _graph_plan(graph, assign: Dict[str, str],
                resources: Dict[str, Resource], rate: float) -> PipelinePlan:
    return evaluate_graph_plan(
        graph.costs(), graph.flow_edges, assign, resources, rate,
        source_consumers=graph.source_consumers,
        source_bytes=graph.source_bytes_per_event)


def frontier_plans(graph, resources: Dict[str, Resource], rate: float
                   ) -> Iterator[Tuple[FrozenSet[str], PipelinePlan]]:
    """All plans of the form: a downward-closed frontier of ``graph`` on
    the edge pool, its complement on the cloud pool. For a linear
    :class:`~repro.core.pipeline.Pipeline` the frontiers are exactly the
    prefixes, so this degenerates to :func:`prefix_cut_plans`."""
    edge, cloud = edge_cloud_pools(resources)
    for frontier in graph.frontiers():
        assign = {name: (edge.name if name in frontier else cloud.name)
                  for name in graph.names}
        yield frontier, _graph_plan(graph, assign, resources, rate)


def place_frontier(graph, resources: Dict[str, Resource], rate: float,
                   objective: Optional[Objective] = None
                   ) -> Tuple[PipelinePlan, FrozenSet[str]]:
    """Best frontier-cut placement of an operator DAG. Returns
    ``(plan, frontier)`` where ``frontier`` is the edge-resident op set."""
    objective = objective or Objective()
    best, best_f, best_score = None, frozenset(), float("inf")
    for frontier, plan in frontier_plans(graph, resources, rate):
        s = objective.score(plan)
        if s < best_score or (s == best_score and best is not None
                              and len(frontier) < len(best_f)):
            best, best_f, best_score = plan, frontier, s
    if best is None or not best.feasible:
        # all-cloud fallback (the empty frontier is always structurally
        # valid; may still be infeasible under extreme rates — caller
        # must check .feasible)
        _, cloud = edge_cloud_pools(resources)
        assign = {name: cloud.name for name in graph.names}
        best = _graph_plan(graph, assign, resources, rate)
        best_f = frozenset()
    return best, best_f


def place_graph_exhaustive(graph, resources: Dict[str, Resource],
                           rate: float,
                           objective: Optional[Objective] = None
                           ) -> PipelinePlan:
    """Oracle for DAG placement: every assignment of every op to every
    resource, including non-downward-closed ones (exponential; tests and
    the benchmark harness only)."""
    objective = objective or Objective()
    rnames = list(resources)
    best, best_score = None, float("inf")
    for combo in itertools.product(rnames, repeat=len(graph.names)):
        assign = dict(zip(graph.names, combo))
        plan = _graph_plan(graph, assign, resources, rate)
        s = objective.score(plan)
        if best is None or s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# Standard S2CE pipeline stage costs
# ---------------------------------------------------------------------------

def standard_pipeline(dim: int = 32, model_flops_per_event: float = 2e6,
                      sample_rate: float = 0.25) -> List[OperatorCost]:
    """ingest -> preprocess -> sample/sketch -> pre-model -> full train.

    A synthetic DL-payload cost-list *exemplar* (placement oracle tests,
    S3 benchmark, edge_cloud example). Executable jobs should not use
    this: build a :class:`repro.core.pipeline.Pipeline` and price it via
    ``Pipeline.costs()`` so the optimizer and the executor consume the
    same op list.
    """
    ev = 4.0 * dim
    return [
        OperatorCost("ingest", flops_per_event=10 * dim,
                     bytes_per_event=2 * ev, out_bytes_per_event=ev),
        OperatorCost("preprocess", flops_per_event=50 * dim,
                     bytes_per_event=4 * ev, out_bytes_per_event=ev),
        OperatorCost("sample", flops_per_event=20,
                     bytes_per_event=2 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("pre_model", flops_per_event=4 * dim * dim,
                     bytes_per_event=6 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("dl_train", flops_per_event=model_flops_per_event,
                     bytes_per_event=20 * ev,
                     out_bytes_per_event=64, edge_capable=False),
    ]
