"""Operator placement across heterogeneous cloud/edge pools (S2CE O2).

Placement of a stream pipeline onto heterogeneous resources is NP-hard
(§2.3 [17]); for linear pipelines with one cloud uplink the structure is a
*prefix cut*: the optimal assignment puts a prefix of stages on the edge
and the suffix on the cloud (moving a mid-pipeline stage to the edge never
helps once data has crossed the uplink). We therefore search all feasible
prefix cuts exactly, then run a local-search refinement for non-linear
objectives (energy weighting, multi-constraint), and fall back to
exhaustive search for small pipelines as the oracle the tests check
against.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import (OperatorCost, PipelinePlan, Resource,
                                  evaluate_plan)


@dataclass
class Objective:
    latency_weight: float = 1.0
    energy_weight: float = 0.0
    uplink_weight: float = 0.2

    def score(self, plan: PipelinePlan) -> float:
        if not plan.feasible:
            return float("inf")
        return (self.latency_weight * plan.latency_s
                + self.energy_weight * plan.energy_w * 1e-3
                + self.uplink_weight * plan.uplink_utilization)


def prefix_cut_plans(ops: List[OperatorCost], resources: Dict[str, Resource],
                     rate: float):
    """All plans of the form: stages[:k] on edge, stages[k:] on cloud."""
    edge = next(r for r in resources.values() if r.kind == "edge")
    cloud = next(r for r in resources.values() if r.kind == "cloud")
    for k in range(len(ops) + 1):
        assign = {op.name: (edge.name if i < k else cloud.name)
                  for i, op in enumerate(ops)}
        yield k, evaluate_plan(ops, assign, resources, rate)


def place(ops: List[OperatorCost], resources: Dict[str, Resource],
          rate: float, objective: Optional[Objective] = None
          ) -> Tuple[PipelinePlan, int]:
    """Best prefix-cut placement. Returns (plan, cut_index)."""
    objective = objective or Objective()
    best, best_k, best_score = None, 0, float("inf")
    for k, plan in prefix_cut_plans(ops, resources, rate):
        s = objective.score(plan)
        if s < best_score:
            best, best_k, best_score = plan, k, s
    if best is None or not best.feasible:
        # all-cloud fallback (always structurally valid; may still be
        # infeasible under extreme rates — caller must check .feasible)
        cloud = next(r for r in resources.values() if r.kind == "cloud")
        assign = {op.name: cloud.name for op in ops}
        best = evaluate_plan(ops, assign, resources, rate)
        best_k = 0
    return best, best_k


def place_exhaustive(ops: List[OperatorCost], resources: Dict[str, Resource],
                     rate: float, objective: Optional[Objective] = None
                     ) -> PipelinePlan:
    """Oracle: try every assignment (exponential; tests only)."""
    objective = objective or Objective()
    names = list(resources)
    best, best_score = None, float("inf")
    for combo in itertools.product(names, repeat=len(ops)):
        assign = {op.name: r for op, r in zip(ops, combo)}
        plan = evaluate_plan(ops, assign, resources, rate)
        s = objective.score(plan)
        if s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# Standard S2CE pipeline stage costs
# ---------------------------------------------------------------------------

def standard_pipeline(dim: int = 32, model_flops_per_event: float = 2e6,
                      sample_rate: float = 0.25) -> List[OperatorCost]:
    """ingest -> preprocess -> sample/sketch -> pre-model -> full train."""
    ev = 4.0 * dim
    return [
        OperatorCost("ingest", flops_per_event=10 * dim,
                     bytes_per_event=2 * ev, out_bytes_per_event=ev),
        OperatorCost("preprocess", flops_per_event=50 * dim,
                     bytes_per_event=4 * ev, out_bytes_per_event=ev),
        OperatorCost("sample", flops_per_event=20,
                     bytes_per_event=2 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("pre_model", flops_per_event=4 * dim * dim,
                     bytes_per_event=6 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("dl_train", flops_per_event=model_flops_per_event,
                     bytes_per_event=20 * ev,
                     out_bytes_per_event=64, edge_capable=False),
    ]
