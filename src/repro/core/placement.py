"""Operator placement across heterogeneous cloud/edge pools (S2CE O2).

Placement of a stream pipeline onto heterogeneous resources is NP-hard
(§2.3 [17]); the tractable structure is the *downward-closed cut*: in
any feasible assignment the edge-resident op set contains all of its own
ancestors, because a cloud op feeding an edge op would route a high-rate
stream back over the constrained link (backhaul — infeasible by the cost
model). Ops declaring ``OperatorCost.downlink_ok`` relax this per
consumer: their cloud->edge crossing is a priced *downlink* (cloud-
prefill/edge-decode serving), so closure is taken under
``OpGraph.closure_parent_indices`` — identical to the full hazard
relation everywhere else. For a linear chain the downward-closed sets
are the prefixes, so
:func:`place` searches all prefix cuts exactly (unchanged from the
linear IR); for an operator DAG over a :class:`ClusterSpec` there are
two engines behind :func:`place_frontier`:

  * **enumeration** (:func:`frontier_plans`): every downward-closed
    *frontier* of the graph x every within-kind pool assignment
    (frontier ops across edge pools, the complement across cloud pods)
    x every codec candidate — which covers exactly the backhaul-free
    assignments, so the search provably matches the exhaustive all-
    assignments oracle (:func:`place_graph_exhaustive`). Exponential in
    op count: the differential-test twin, not the production path.
  * **dynamic program** (:func:`place_frontier_dp`): a label-correcting
    DP over topological prefixes of the frontier lattice. Ops are placed
    one at a time in graph order; a label carries exactly the state the
    cost model's forward sweep needs (per-pool utilization, per-link
    bytes, finish times of ops that still feed unplaced consumers,
    per-producer shipped-pool sets for multicast dedup, energy) and
    labels that agree on the *discrete* part of that state (the live
    frontier signature) are pruned by Pareto dominance over the
    continuous part — sound because every aggregate enters the score and
    the feasibility checks monotonically. An admissible lower bound
    against a greedy incumbent prunes further. The DP returns a
    cost-identical plan to the enumeration on every DAG (property-tested
    against the oracle) at polynomial label counts on the chain-like
    graphs real jobs are, which lifts the search ceiling from ~7 ops to
    100+ ops x dozens of pools (the ``dag_place_dp_*`` benchmark rows).

Both engines share one canonical tie-break — (score, |frontier|, codec
faithfulness, pool-index tuple) — so equal-cost optima resolve
identically and a controller switching engines does not phantom-migrate.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import (Dict, FrozenSet, Iterator, List, Optional, Sequence,
                    Tuple)

from repro.core.costmodel import (ClusterSpec, OperatorCost, PipelinePlan,
                                  Resource, ResourcesLike,
                                  evaluate_graph_plan, evaluate_plan,
                                  op_placement_terms)


@dataclass
class Objective:
    latency_weight: float = 1.0
    energy_weight: float = 0.0
    uplink_weight: float = 0.2

    def score(self, plan: PipelinePlan) -> float:
        if not plan.feasible:
            return float("inf")
        return (self.latency_weight * plan.latency_s
                + self.energy_weight * plan.energy_w * 1e-3
                + self.uplink_weight * plan.uplink_utilization)


def edge_cloud_pools(resources: ResourcesLike
                     ) -> Tuple[Resource, Resource]:
    """The (edge, cloud) pool pair two-pool placement runs over.

    .. deprecated::
        This is the thin back-compat shim for the flat two-pool world:
        it collapses a :class:`ClusterSpec` (or legacy resource dict) to
        the *first* pool of each kind, ignoring any further pools and
        their links — calling it emits a ``DeprecationWarning``. New
        code should pass a ``ClusterSpec`` to :func:`place_frontier`,
        which places across every pool. The prefix-cut engine
        (:func:`place`/:func:`prefix_cut_plans`) still collapses through
        the same rule internally (it IS the two-pool engine) without
        warning on every replan.

    Raises a clear ``ValueError`` when either kind is missing — instead
    of the bare ``StopIteration`` a ``next()`` over an ill-formed
    resource dict used to surface.
    """
    import warnings
    warnings.warn(
        "edge_cloud_pools is the deprecated two-pool shim: it collapses "
        "the topology to the FIRST pool of each kind, ignoring further "
        "pools and their links; pass the ClusterSpec to place_frontier "
        "instead", DeprecationWarning, stacklevel=2)
    return _first_edge_cloud(resources)


def _first_edge_cloud(resources: ResourcesLike
                      ) -> Tuple[Resource, Resource]:
    """The collapse rule behind :func:`edge_cloud_pools`, warning-free
    for the prefix-cut engine's own use."""
    spec = ClusterSpec.of(resources)
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "prefix-cut placement needs at least one 'edge' and one "
            f"'cloud' pool; resource dict has kinds {kinds or '(empty)'}")
    return edges[0], clouds[0]


def stale_pools(assignment: Dict[str, str], resources: ResourcesLike
                ) -> List[str]:
    """The pools ``assignment`` references that no longer exist in
    ``resources`` (sorted). Non-empty means membership churn removed a
    pool out from under the plan — the controller must replan (and may
    never silently hold) before the next batch executes."""
    spec = ClusterSpec.of(resources)
    return sorted({p for p in assignment.values() if p not in spec.pools})


def prefix_cut_plans(ops: List[OperatorCost], resources: ResourcesLike,
                     rate: float):
    """All plans of the form: stages[:k] on edge, stages[k:] on cloud.
    Two-pool only (first pool of each kind, the deprecated
    :func:`edge_cloud_pools` collapse rule)."""
    edge, cloud = _first_edge_cloud(resources)
    for k in range(len(ops) + 1):
        assign = {op.name: (edge.name if i < k else cloud.name)
                  for i, op in enumerate(ops)}
        yield k, evaluate_plan(ops, assign, resources, rate)


def place(ops: List[OperatorCost], resources: ResourcesLike,
          rate: float, objective: Optional[Objective] = None
          ) -> Tuple[PipelinePlan, int]:
    """Best prefix-cut placement. Returns (plan, cut_index)."""
    objective = objective or Objective()
    best, best_k, best_score = None, 0, float("inf")
    for k, plan in prefix_cut_plans(ops, resources, rate):
        s = objective.score(plan)
        if s < best_score:
            best, best_k, best_score = plan, k, s
    if best is None or not best.feasible:
        # all-cloud fallback (always structurally valid; may still be
        # infeasible under extreme rates — caller must check .feasible)
        _, cloud = _first_edge_cloud(resources)
        assign = {op.name: cloud.name for op in ops}
        best = evaluate_plan(ops, assign, resources, rate)
        best_k = 0
    return best, best_k


def _check_state_count(what: str, n_pools: int, n_ops: int,
                       max_states: int) -> None:
    """Guard an exhaustive oracle against silently hanging: the state
    count is pools**ops, compared in log space so the estimate itself
    cannot overflow."""
    if n_pools <= 1 or n_ops == 0:
        return
    if n_ops * math.log(n_pools) > math.log(max_states):
        est10 = n_ops * math.log10(n_pools)
        raise ValueError(
            f"{what} would enumerate {n_pools}^{n_ops} (~1e{est10:.0f}) "
            f"assignments, over the max_states={max_states} cap; it is "
            "an exhaustive test oracle — use place/place_frontier for "
            "real problem sizes, or raise max_states explicitly")


def place_exhaustive(ops: List[OperatorCost], resources: ResourcesLike,
                     rate: float, objective: Optional[Objective] = None,
                     *, max_states: int = 1_000_000) -> PipelinePlan:
    """Oracle: try every assignment (exponential; tests only). Refuses
    inputs whose ``pools**ops`` state count exceeds ``max_states``."""
    objective = objective or Objective()
    names = list(ClusterSpec.of(resources))
    _check_state_count("place_exhaustive", len(names), len(ops), max_states)
    best, best_score = None, float("inf")
    for combo in itertools.product(names, repeat=len(ops)):
        assign = {op.name: r for op, r in zip(ops, combo)}
        plan = evaluate_plan(ops, assign, resources, rate)
        s = objective.score(plan)
        if s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# DAG placement: frontier (downward-closed) cuts over an OpGraph, with
# multi-pool assignment within each side of the cut
# ---------------------------------------------------------------------------

def _graph_plan(graph, assign: Dict[str, str],
                resources: ResourcesLike, rate: float) -> PipelinePlan:
    return evaluate_graph_plan(
        graph.costs(), graph.flow_edges, assign, resources, rate,
        source_consumers=graph.source_consumers,
        source_bytes=graph.source_bytes_per_event)


def _frontier_assignments(names: List[str], frontier: FrozenSet[str],
                          edge_names: List[str], cloud_names: List[str]
                          ) -> Iterator[Dict[str, str]]:
    """Every assignment that realizes ``frontier``: each frontier op on
    one of the edge pools, each complement op on one of the cloud pods.
    With one pool of each kind this yields exactly one assignment (the
    classic two-pool cut)."""
    f_ops = [n for n in names if n in frontier]
    c_ops = [n for n in names if n not in frontier]
    for e_combo in itertools.product(edge_names, repeat=len(f_ops)):
        base = dict(zip(f_ops, e_combo))
        for c_combo in itertools.product(cloud_names, repeat=len(c_ops)):
            assign = dict(base)
            assign.update(zip(c_ops, c_combo))
            yield assign


def _codec_specs(spec: ClusterSpec, codecs: Optional[Sequence[str]]
                 ) -> List[Tuple[Optional[str], ClusterSpec]]:
    """The (codec name, spec-with-that-uplink-codec) pairs a codec-aware
    search prices. ``codecs=None`` -> the spec as declared (one entry,
    codec ``None``). A user-declared per-link lossy codec is preserved
    (``with_uplink_codec`` default), so the blanket candidate fills only
    undeclared uplinks.

    Candidates are ordered most-faithful-first (by (error_bound, ratio))
    and deduplicated on their *effective* per-uplink codec signature:
    when user-declared link codecs make several blanket candidates
    produce the identical priced topology, only the most faithful name
    survives — the search prices each distinct plan once instead of once
    per admitted candidate, and score ties resolve toward lossless no
    matter what order the caller passed."""
    if codecs is None:
        return [(None, spec)]
    from repro.core.codecs import get_codec
    pairs = [(c, spec.with_uplink_codec(c)) for c in codecs]

    def faithfulness(pair):
        try:
            codec = get_codec(pair[0])
        except KeyError as e:
            raise ValueError(str(e.args[0])) from None
        return (codec.error_bound, codec.ratio, codec.name)

    pairs.sort(key=faithfulness)
    uplinks = [(e.name, c.name) for e in spec.edge_pools
               for c in spec.cloud_pools]
    out: List[Tuple[Optional[str], ClusterSpec]] = []
    seen = set()
    for cname, cspec in pairs:
        sig = tuple(cspec.link(e, c).codec for e, c in uplinks)
        if sig not in seen:
            seen.add(sig)
            out.append((cname, cspec))
    return out


def frontier_plans(graph, resources: ResourcesLike, rate: float,
                   objective: Optional[Objective] = None,
                   codecs: Optional[Sequence[str]] = None
                   ) -> Iterator[Tuple[FrozenSet[str], PipelinePlan]]:
    """For every downward-closed frontier of ``graph``: the best plan
    (under ``objective``) over all within-kind pool assignments — the
    frontier across the spec's edge pools, its complement across the
    cloud pods. For a one-edge/one-cloud spec each frontier has exactly
    one assignment, so this degenerates to the classic two-pool frontier
    enumeration (and, for a linear :class:`~repro.core.pipeline.Pipeline`,
    to :func:`prefix_cut_plans`).

    ``codecs`` makes the uplink codec a searched plan dimension: each
    candidate name is attached to the spec's uplinks
    (:meth:`~repro.core.costmodel.ClusterSpec.with_uplink_codec`) and
    the winning plan per frontier is the best (pool-assignment, codec)
    pair, with ``plan.uplink_codec`` recording the codec it was priced
    under. Candidates are searched most-faithful-first regardless of the
    order passed, and duplicates that price to the identical plan are
    collapsed (see :func:`_codec_specs`), so score ties (e.g. a frontier
    with no uplink crossing) always resolve toward lossless. Ties within
    a frontier break canonically on the pool-index tuple — the same
    order :func:`place_frontier_dp` uses.
    """
    spec = ClusterSpec.of(resources)
    objective = objective or Objective()
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "frontier placement needs at least one 'edge' and one 'cloud' "
            f"pool; ClusterSpec has kinds {kinds or '(empty)'}")
    e_names = [r.name for r in edges]
    c_names = [r.name for r in clouds]
    names = graph.names
    specs = _codec_specs(spec, codecs)
    pidx = {name: i for i, name in enumerate(spec)}
    for frontier in graph.frontiers():
        best, best_key = None, None
        for assign in _frontier_assignments(names, frontier,
                                            e_names, c_names):
            ptup = tuple(pidx[assign[n]] for n in names)
            for rank, (cname, cspec) in enumerate(specs):
                plan = _graph_plan(graph, assign, cspec, rate)
                plan.uplink_codec = cname
                key = (objective.score(plan), rank, ptup)
                if best is None or key < best_key:
                    best, best_key = plan, key
        yield frontier, best


def _enumeration_plans(graph, n_edge: int, n_cloud: int,
                       limit: float) -> Optional[float]:
    """Number of (frontier x within-kind pool assignment) plans the
    enumeration engine would price, or None as soon as the running total
    passes ``limit`` (both the frontier walk and the arithmetic stop
    early, so the estimate is cheap even on graphs with exponentially
    many frontiers)."""
    n = len(graph.names)
    total = 0.0
    for f in graph.frontiers():
        k = len(f)
        total += float(n_edge) ** k * float(n_cloud) ** (n - k)
        if total > limit:
            return None
    return total


def _all_cloud_fallback(graph, spec: ClusterSpec, rate: float,
                        objective: Objective,
                        codecs: Optional[Sequence[str]]
                        ) -> Tuple[PipelinePlan, FrozenSet[str]]:
    """The empty frontier on the first pod — always structurally valid;
    may still be infeasible under extreme rates (caller must check
    ``.feasible``). Shared by both search engines so an infeasible
    instance degrades identically whichever engine ran."""
    cloud = spec.cloud_pools[0]
    assign = {name: cloud.name for name in graph.names}
    fb, fb_key = None, None
    for rank, (cname, cspec) in enumerate(_codec_specs(spec, codecs)):
        plan = _graph_plan(graph, assign, cspec, rate)
        plan.uplink_codec = cname
        key = (objective.score(plan), rank)
        if fb is None or key < fb_key:
            fb, fb_key = plan, key
    return fb, frozenset()


def place_frontier(graph, resources: ResourcesLike, rate: float,
                   objective: Optional[Objective] = None,
                   codecs: Optional[Sequence[str]] = None,
                   *, method: str = "auto",
                   enumerate_limit: int = 20_000,
                   max_labels: int = 4096
                   ) -> Tuple[PipelinePlan, FrozenSet[str]]:
    """Best frontier-cut placement of an operator DAG over a
    :class:`ClusterSpec` — multi-pool: each frontier side may split
    across the pools of its kind, priced per crossing link with
    codec-compressed bytes. With ``codecs`` the winning plan is the best
    (frontier, pool-assignment, codec) triple and ``plan.uplink_codec``
    names the codec it was priced under. Returns ``(plan, frontier)``
    where ``frontier`` is the edge-resident op set (``plan.assignment``
    holds the per-op pool detail).

    ``method`` selects the engine: ``"enumerate"`` (the exhaustive
    frontier x pool-product x codec walk), ``"dp"``
    (:func:`place_frontier_dp` — cost-identical, polynomial on real
    graphs), or ``"auto"`` (default): enumerate while the priced-plan
    estimate stays within ``enumerate_limit``, DP above it — small
    graphs keep the historical code path exactly, big graphs stop being
    exponential."""
    objective = objective or Objective()
    spec = ClusterSpec.of(resources)
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "frontier placement needs at least one 'edge' and one 'cloud' "
            f"pool; ClusterSpec has kinds {kinds or '(empty)'}")
    if method not in ("auto", "enumerate", "dp"):
        raise ValueError(f"method {method!r} not in ('auto', 'enumerate', "
                         "'dp')")
    if method == "auto":
        n_codecs = max(len(codecs), 1) if codecs else 1
        n_plans = _enumeration_plans(graph, len(edges), len(clouds),
                                     limit=enumerate_limit / n_codecs)
        method = "enumerate" if n_plans is not None else "dp"
    if method == "dp":
        return place_frontier_dp(graph, spec, rate, objective, codecs,
                                 max_labels=max_labels)
    specs = _codec_specs(spec, codecs)
    rank_of = {cname: r for r, (cname, _) in enumerate(specs)}
    pidx = {name: i for i, name in enumerate(spec)}
    best, best_f, best_key = None, frozenset(), None
    for frontier, plan in frontier_plans(graph, spec, rate, objective,
                                         codecs=codecs):
        key = (objective.score(plan), len(frontier),
               rank_of.get(plan.uplink_codec, 0),
               tuple(pidx[plan.assignment[n]] for n in graph.names))
        if best is None or key < best_key:
            best, best_f, best_key = plan, frontier, key
    if best is None or not best.feasible:
        best, best_f = _all_cloud_fallback(graph, spec, rate, objective,
                                           codecs)
    return best, best_f


# ---------------------------------------------------------------------------
# the DP engine: label-correcting search over topological prefixes of
# the frontier lattice with exact dominance + admissible-bound pruning
# ---------------------------------------------------------------------------

_EMPTY_FS: FrozenSet[int] = frozenset()

# Per-bucket Pareto-front width cap inside the DP sweep. Fronts past
# this size are near-tie clouds (e.g. many near-identical pods), where
# the best-bound prefix is what matters; the cap bounds the dominance
# sweep at O(labels x cap) and trips the `truncated` flag when hit.
_BUCKET_CAP = 64


def _dp_tables(graph, spec: ClusterSpec, rate: float):
    """Per-(op, pool) and per-(pool, pool) constants the DP transitions
    read: cost terms via the SAME :func:`op_placement_terms` arithmetic
    the evaluator uses, link latency/bandwidth/codec-ratio matrices, and
    the dependency structure (hazard parents for closure, flow edges for
    bytes and the critical path, retirement indices for the live-set
    signature)."""
    from repro.core.codecs import get_codec
    costs = graph.costs()
    n = len(costs)
    pools = list(spec.values())
    P = len(pools)
    kinds = [r.kind for r in pools]
    pool_names = [r.name for r in pools]
    util = [[0.0] * P for _ in range(n)]
    lat = [[0.0] * P for _ in range(n)]
    eng = [[0.0] * P for _ in range(n)]
    ok = [[True] * P for _ in range(n)]
    for j, op in enumerate(costs):
        for p, res in enumerate(pools):
            u, l, e = op_placement_terms(op, res, rate)
            util[j][p], lat[j][p], eng[j][p] = u, l, e
            if ((not op.edge_capable and res.kind == "edge")
                    or op.state_bytes > res.mem_cap * res.chips
                    or u > 1.0):
                ok[j][p] = False
    latm = [[0.0] * P for _ in range(P)]
    bwm = [[1.0] * P for _ in range(P)]
    ratm = [[1.0] * P for _ in range(P)]
    epbm = [[0.0] * P for _ in range(P)]
    for a in range(P):
        for b in range(P):
            if a == b:
                continue
            ln = spec.link(pool_names[a], pool_names[b])
            latm[a][b] = ln.latency
            bwm[a][b] = ln.bw
            ratm[a][b] = get_codec(ln.codec).ratio
            epbm[a][b] = ln.energy_per_byte
    # the closure relation: full hazard parents, minus flow parents of
    # downlink-ok ops (their inputs may ride the cloud->edge downlink —
    # priced as a ship below, not forbidden by the edge gate). Graphs
    # without downlink ops have closure == hazard parents.
    haz = getattr(graph, "closure_parent_indices",
                  graph.hazard_parent_indices)
    flow_parents: List[List[int]] = [[] for _ in range(n)]
    flow_children: List[List[int]] = [[] for _ in range(n)]
    for i, j in graph.flow_pairs:
        flow_parents[j].append(i)
        flow_children[i].append(j)
    # last_flow[i]: once the DP passes this index, op i's finish time and
    # shipped-pool set can retire from the label (no more ships / path
    # extensions from i). last_need[i]: once passed, op i's POOL also
    # stops mattering (no future hazard child constrains on it) and i
    # drops from the live signature entirely.
    last_flow = [max(cs) if cs else i for i, cs in enumerate(flow_children)]
    last_need = list(last_flow)
    for j in range(n):
        for i in haz[j]:
            if j > last_need[i]:
                last_need[i] = j
    name_idx = {nm: i for i, nm in enumerate(graph.names)}
    src_set = {name_idx[c] for c in graph.source_consumers}
    sidx = pool_names.index(spec.default_source())
    return {
        "n": n, "P": P, "kinds": kinds, "pool_names": pool_names,
        "util": util, "lat": lat, "eng": eng, "ok": ok,
        "latm": latm, "bwm": bwm, "ratm": ratm, "epbm": epbm,
        "haz": haz, "flow_parents": flow_parents,
        "flow_children": flow_children,
        "last_flow": last_flow, "last_need": last_need,
        "src_set": src_set, "last_src": max(src_set, default=-1),
        "sidx": sidx, "sb": graph.source_bytes_per_event,
        "out_bytes": [c.out_bytes_per_event for c in costs],
    }


def _dp_pass(t: dict, rate: float, objective: Objective, incumbent: float,
             beam: Optional[int], max_labels: int, agg: dict):
    """One label-correcting sweep over the op order. A label is::

        (assign_t, energy, lat_dead, max_link_util,
         pool_util, link_bytes, finish, shipped, src_shipped, bound)

    with dict aggregates keyed by pool/link/op index. ``beam=1`` is the
    greedy warm-start (cheapest-bound label per step), ``beam=None`` the
    exact sweep. Returns the surviving final labels (possibly empty)."""
    n, P = t["n"], t["P"]
    kinds, util, lat, eng, ok = (t["kinds"], t["util"], t["lat"], t["eng"],
                                 t["ok"])
    latm, bwm, ratm, epbm = t["latm"], t["bwm"], t["ratm"], t["epbm"]
    haz, flow_parents, flow_children = (t["haz"], t["flow_parents"],
                                        t["flow_children"])
    last_flow, last_need = t["last_flow"], t["last_need"]
    src_set, last_src, sidx, sb = (t["src_set"], t["last_src"], t["sidx"],
                                   t["sb"])
    out_bytes = t["out_bytes"]
    lw, ew, uw = (objective.latency_weight, objective.energy_weight,
                  objective.uplink_weight)
    # incumbent slack: DP link-byte accumulation order differs from the
    # evaluator's (ulp-level drift), so a hard cutoff at the incumbent
    # could shave an exactly-optimal label
    inc_eff = incumbent * (1.0 + 1e-9) + 1e-12

    # suffix minimum energy (admissible energy completion) and deepest
    # min-pool downstream compute path per op (admissible latency tail)
    rem_e = [0.0] * (n + 1)
    down = [0.0] * n
    for j in range(n - 1, -1, -1):
        cheapest = min(eng[j][p] for p in range(P) if ok[j][p])
        rem_e[j] = rem_e[j + 1] + cheapest
        down[j] = (min(lat[j][p] for p in range(P) if ok[j][p])
                   + max((down[c] for c in flow_children[j]), default=0.0))

    labels = [((), 0.0, 0.0, 0.0, {}, {}, {}, {},
               _EMPTY_FS if src_set else None, 0.0)]
    for j in range(n):
        okj, utj, latj, engj = ok[j], util[j], lat[j], eng[j]
        f_par = flow_parents[j]
        hazj = haz[j]
        has_children = bool(flow_children[j])
        is_src = j in src_set
        live = [i for i in range(j + 1) if last_need[i] > j]
        live_flow = [i for i in live if last_flow[i] > j]
        tails = {i: max((down[c] for c in flow_children[i] if c > j),
                        default=0.0) for i in live_flow}
        cands: list = []
        n_expanded = 0
        for lab in labels:
            (assign_t, energy, lat_dead, maxlu, utild, linkd, fin,
             shipped, srcsh, _) = lab
            for p in range(P):
                if not okj[p]:
                    continue
                if kinds[p] == "edge":
                    # closure-downward gate: an edge-resident op needs
                    # every closure parent edge-resident (which rules out
                    # cloud->edge backhaul on flow edges — except into
                    # downlink-ok consumers, whose flow parents are not
                    # closure parents and whose downlink crossing is
                    # priced as a normal ship below)
                    if any(kinds[assign_t[i]] != "edge" for i in hazj):
                        continue
                nu = utild.get(p, 0.0) + utj[p]
                if nu > 1.0:
                    continue
                # --- scalar phase: price the transition without copying
                # any aggregate dict; most candidates die here -----------
                nmaxlu = maxlu
                ships = {}        # link key -> new total bytes
                ship_e = 0.0      # link transmit energy of the new ships
                start = 0.0
                src_ships = is_src and p != sidx and p not in srcsh
                if src_ships:
                    nb = linkd.get((sidx, p), 0.0) + ratm[sidx][p] * sb
                    lu = nb * rate / bwm[sidx][p]
                    if lu > 1.0:
                        continue
                    if lu > nmaxlu:
                        nmaxlu = lu
                    ships[(sidx, p)] = nb
                    ship_e += ratm[sidx][p] * sb * rate * epbm[sidx][p]
                if is_src and p != sidx:
                    start = latm[sidx][p]
                overrun = False
                crossed = []
                for i in f_par:
                    q = assign_t[i]
                    if q != p and p not in shipped[i]:
                        lk = (q, p)
                        nb = (ships.get(lk, linkd.get(lk, 0.0))
                              + ratm[q][p] * out_bytes[i])
                        lu = nb * rate / bwm[q][p]
                        if lu > 1.0:
                            overrun = True
                            break
                        if lu > nmaxlu:
                            nmaxlu = lu
                        ships[lk] = nb
                        ship_e += (ratm[q][p] * out_bytes[i] * rate
                                   * epbm[q][p])
                        crossed.append(i)
                if overrun:
                    continue
                for i in f_par:
                    ti = fin[i]
                    q = assign_t[i]
                    if q != p:
                        ti += latm[q][p]
                    if ti > start:
                        start = ti
                fj = start + latj[p]
                nen = energy + engj[p] + ship_e
                nlat_dead = lat_dead
                for i in f_par:
                    if last_flow[i] == j:
                        ti = fin[i]
                        if ti > nlat_dead:
                            nlat_dead = ti
                if not has_children and fj > nlat_dead:
                    nlat_dead = fj
                # admissible bound: finished critical path so far + the
                # cheapest-pool downstream tails, suffix-min energy, and
                # the (monotone) bottleneck link seen so far
                b_lat = nlat_dead
                for i in live_flow:
                    ti = (fj if i == j else fin[i]) + tails[i]
                    if ti > b_lat:
                        b_lat = ti
                bound = (lw * b_lat + ew * (nen + rem_e[j + 1]) * 1e-3
                         + uw * nmaxlu)
                if bound > inc_eff:
                    continue
                # survivor: record (parent, pool, deltas) — the dict
                # aggregates are materialized only if the candidate is
                # actually kept after the dominance sweep
                key_live = tuple(
                    (i,
                     p if i == j else assign_t[i],
                     (_EMPTY_FS if has_children else None) if i == j
                     else (shipped[i] | {p} if i in crossed
                           else shipped.get(i)))
                    for i in live)
                cands.append(((key_live, srcsh), bound, assign_t, p, lab,
                              nu, fj, nen, nlat_dead, nmaxlu, ships,
                              crossed))
                n_expanded += 1
        agg["labels_expanded"] += n_expanded
        # Pareto-dominance pruning within each bucket: labels agreeing on
        # the discrete live signature compare on the continuous
        # aggregates, every one of which enters the score/feasibility
        # monotonically — a dominated label cannot lead anywhere its
        # dominator cannot lead at least as cheaply. Candidates are
        # processed best-bound-first (ties by pool tuple, so full ties
        # keep the canonically smallest assignment); because the bound is
        # itself monotone in the compared aggregates, a dominator always
        # sorts no later than its victims and a one-directional check
        # against the kept front suffices. The width cap (``beam`` /
        # ``max_labels``) and per-bucket front cap turn the sweep into a
        # best-bound beam on inputs whose fronts outgrow them — flagged
        # via ``truncated``, never silent.
        cands.sort(key=lambda c: (c[1], c[2], c[3]))
        cap = beam if beam is not None else max_labels
        buckets: Dict[tuple, list] = {}
        labels = []
        overflow = False
        for cand in cands:
            if len(labels) >= cap:
                overflow = True
                break
            (key, bound, assign_t, p, lab, nu, fj, nen, nlat_dead,
             nmaxlu, ships, crossed) = cand
            front = buckets.get(key)
            if front is None:
                front = buckets[key] = []
            elif len(front) >= _BUCKET_CAP:
                overflow = True
                continue
            utild, linkd, fin = lab[4], lab[5], lab[6]
            dominated = False
            for f in front:
                if (f[1] <= nen and f[2] <= nlat_dead
                        and all(f[6][i] <= (fj if i == j else fin[i])
                                for i in live_flow)
                        and all(v <= (nu if q == p
                                      else utild.get(q, 0.0))
                                for q, v in f[4].items())
                        and all(v <= ships.get(l, linkd.get(l, 0.0))
                                for l, v in f[5].items())):
                    dominated = True
                    break
            if dominated:
                continue
            # --- materialize the kept label -----------------------------
            shipped, srcsh = lab[7], lab[8]
            nlink = dict(linkd) if ships else linkd
            nlink.update(ships)
            nsrcsh = srcsh
            if is_src:
                if p != sidx and p not in srcsh:
                    nsrcsh = nsrcsh | {p}
                if j == last_src:
                    nsrcsh = None
            nfin = dict(fin)
            nshipped = dict(shipped)
            if has_children:
                nfin[j] = fj
                nshipped[j] = _EMPTY_FS
            for i in crossed:
                nshipped[i] = nshipped[i] | {p}
            for i in f_par:
                if last_flow[i] == j:
                    del nfin[i]
                    del nshipped[i]
            nutil = dict(utild)
            nutil[p] = nu
            new_lab = (assign_t + (p,), nen, nlat_dead, nmaxlu, nutil,
                       nlink, nfin, nshipped, nsrcsh, bound)
            front.append(new_lab)
            labels.append(new_lab)
        if overflow and beam is None:
            # the exact sweep hit a cap: the result is a valid plan but
            # optimality is no longer certified
            agg["truncated"] = True
        if len(labels) > agg["labels_peak"]:
            agg["labels_peak"] = len(labels)
        if not labels:
            return []
    return labels


def _dp_final_key(lab, kinds, lw, ew, uw):
    """Selection key over completed labels — the same canonical order
    the enumeration engine uses: (score, |frontier|, pool tuple)."""
    score = lw * lab[2] + ew * lab[1] * 1e-3 + uw * lab[3]
    n_edge = sum(1 for p in lab[0] if kinds[p] == "edge")
    return (score, n_edge, lab[0])


def place_frontier_dp(graph, resources: ResourcesLike, rate: float,
                      objective: Optional[Objective] = None,
                      codecs: Optional[Sequence[str]] = None,
                      *, max_labels: int = 4096,
                      stats: Optional[dict] = None
                      ) -> Tuple[PipelinePlan, FrozenSet[str]]:
    """Polynomial placement over the frontier lattice: the label DP (see
    module docstring) run once per codec candidate, warm-started by its
    own greedy pass and by the best exact score of earlier candidates
    (most-faithful-first, so ties resolve identically to the
    enumeration). Returns ``(plan, frontier)`` exactly like
    :func:`place_frontier`; the winning assignment is re-priced through
    :func:`~repro.core.costmodel.evaluate_graph_plan`, so the returned
    plan is the evaluator's own numbers, not the DP's bookkeeping.

    ``max_labels`` is the per-step label-front width. While the pruned
    fronts fit (every differential-test graph does, by orders of
    magnitude), the sweep is exhaustive over non-dominated labels and
    the result is provably optimal; past it the sweep degrades to a
    best-bound beam of that width — deliberately, never silently:
    ``stats`` (optional dict) receives the diagnostics (``labels_peak``,
    ``labels_expanded``, and ``truncated``, which is True iff any width
    or per-bucket cap clipped an exact sweep, i.e. iff optimality is no
    longer certified). Runtime is O(ops x max_labels x pools) either
    way — the polynomial envelope the exponential enumeration lacked."""
    spec = ClusterSpec.of(resources)
    objective = objective or Objective()
    edges, clouds = spec.edge_pools, spec.cloud_pools
    if not edges or not clouds:
        kinds = sorted({r.kind for r in spec.values()})
        raise ValueError(
            "frontier placement needs at least one 'edge' and one 'cloud' "
            f"pool; ClusterSpec has kinds {kinds or '(empty)'}")
    lw, ew, uw = (objective.latency_weight, objective.energy_weight,
                  objective.uplink_weight)
    edge_names = {r.name for r in edges}
    pidx = {name: i for i, name in enumerate(spec)}
    agg = {"labels_peak": 0, "labels_expanded": 0, "truncated": False}
    best, best_f, best_key = None, frozenset(), None
    incumbent = float("inf")
    for rank, (cname, cspec) in enumerate(_codec_specs(spec, codecs)):
        t = _dp_tables(graph, cspec, rate)
        if any(not any(row) for row in t["ok"]):
            continue            # some op fits no pool: nothing feasible
        inc = incumbent
        greedy = _dp_pass(t, rate, objective, inc, 1, max_labels, agg)
        if greedy:
            gk = min(_dp_final_key(lab, t["kinds"], lw, ew, uw)
                     for lab in greedy)
            inc = min(inc, gk[0])
        final = _dp_pass(t, rate, objective, inc, None, max_labels, agg)
        if not final:
            continue
        win = min(final, key=lambda lab: _dp_final_key(
            lab, t["kinds"], lw, ew, uw))
        assign = {graph.names[i]: t["pool_names"][p]
                  for i, p in enumerate(win[0])}
        plan = _graph_plan(graph, assign, cspec, rate)
        plan.uplink_codec = cname
        s = objective.score(plan)
        frontier = frozenset(nm for nm, r in assign.items()
                             if r in edge_names)
        key = (s, len(frontier), rank,
               tuple(pidx[assign[nm]] for nm in graph.names))
        if best is None or key < best_key:
            best, best_f, best_key = plan, frontier, key
        if s < incumbent:
            incumbent = s
    if stats is not None:
        stats.update(agg)
    if best is None or not best.feasible:
        best, best_f = _all_cloud_fallback(graph, spec, rate, objective,
                                           codecs)
    return best, best_f


def place_graph_exhaustive(graph, resources: ResourcesLike,
                           rate: float,
                           objective: Optional[Objective] = None,
                           *, max_states: int = 1_000_000) -> PipelinePlan:
    """Oracle for DAG placement: every assignment of every op to every
    pool of the spec — including non-downward-closed and cross-kind-
    scrambled ones (exponential; tests and the benchmark harness only).
    With a multi-pool ClusterSpec this is the multi-pool oracle
    :func:`place_frontier` and :func:`place_frontier_dp` are checked
    against. Refuses inputs whose ``pools**ops`` state count exceeds
    ``max_states``."""
    objective = objective or Objective()
    spec = ClusterSpec.of(resources)
    rnames = list(spec)
    _check_state_count("place_graph_exhaustive", len(rnames),
                       len(graph.names), max_states)
    best, best_score = None, float("inf")
    for combo in itertools.product(rnames, repeat=len(graph.names)):
        assign = dict(zip(graph.names, combo))
        plan = _graph_plan(graph, assign, spec, rate)
        s = objective.score(plan)
        if best is None or s < best_score:
            best, best_score = plan, s
    return best


# ---------------------------------------------------------------------------
# Standard S2CE pipeline stage costs
# ---------------------------------------------------------------------------

def standard_pipeline(dim: int = 32, model_flops_per_event: float = 2e6,
                      sample_rate: float = 0.25) -> List[OperatorCost]:
    """ingest -> preprocess -> sample/sketch -> pre-model -> full train.

    A synthetic DL-payload cost-list *exemplar* (placement oracle tests,
    S3 benchmark, edge_cloud example). Executable jobs should not use
    this: build a :class:`repro.core.pipeline.Pipeline` and price it via
    ``Pipeline.costs()`` so the optimizer and the executor consume the
    same op list.
    """
    ev = 4.0 * dim
    return [
        OperatorCost("ingest", flops_per_event=10 * dim,
                     bytes_per_event=2 * ev, out_bytes_per_event=ev),
        OperatorCost("preprocess", flops_per_event=50 * dim,
                     bytes_per_event=4 * ev, out_bytes_per_event=ev),
        OperatorCost("sample", flops_per_event=20,
                     bytes_per_event=2 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("pre_model", flops_per_event=4 * dim * dim,
                     bytes_per_event=6 * ev,
                     out_bytes_per_event=ev * sample_rate),
        OperatorCost("dl_train", flops_per_event=model_flops_per_event,
                     bytes_per_event=20 * ev,
                     out_bytes_per_event=64, edge_capable=False),
    ]
