"""Self-tuning of distributed execution configs (S2CE O1: "Optimization &
Self-Tuning of Cloud Applications").

Given an (arch x shape), the tuner searches (recipe, microbatches, remat,
attention chunk) candidates, scores each by dry-run compile + scan-aware
roofline analysis (no hardware needed), and returns the best config under
a memory cap. This module IS the engine behind the §Perf hillclimb: every
EXPERIMENTS.md §Perf iteration is one tuner candidate with its
hypothesis/measurement recorded.
"""

from __future__ import annotations

import itertools
import json
import pathlib
import time
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple


def _n_events(batch: Dict[str, Any]) -> int:
    """Events in a batch dict: the largest leading dimension over its
    array values (the ``rng`` key is control, not payload)."""
    import jax.numpy as jnp
    n = 0
    for k, v in batch.items():
        if k == "rng":
            continue
        shape = jnp.shape(v)
        if shape and shape[0] > n:
            n = int(shape[0])
    return max(n, 1)


def _pytree_nbytes(tree: Any) -> float:
    import jax
    return float(sum(
        getattr(leaf, "nbytes", 0) for leaf in jax.tree_util.tree_leaves(tree)
        if hasattr(leaf, "nbytes")))


def measure_operator_costs(graph, batch: Dict[str, Any], *,
                           events: Optional[int] = None
                           ) -> Tuple[Dict[str, "Any"], List[str]]:
    """Measured per-op :class:`~repro.core.costmodel.OperatorCost`s from
    one dry-run of ``graph`` over ``batch`` — the self-tuning closure of
    the placement loop (ROADMAP item 5): instead of optimizing the
    hand-written per-op guesses, the placement search prices what the
    compiler actually emits.

    Per op, in graph order (so each op sees the channel env its real
    parents produced):

      * ``flops_per_event`` / ``bytes_per_event`` — compile the op's
        step at its true input signature and divide the compiled
        artifact's cost analysis by the event count
        (:func:`repro.launch.roofline.op_event_costs`);
      * ``out_bytes_per_event`` — execute the op and count the bytes it
        actually writes to its output channels;
      * ``state_bytes`` — the bytes of its post-step state pytree;
      * ``edge_capable`` — NOT measured; the declared semantic flag is
        preserved by :meth:`OpGraph.set_measured_costs`.

    Returns ``(measured, notes)``: a name -> OperatorCost dict holding
    every op whose measurement succeeded, plus human-readable notes for
    ops that kept their declared numbers (analysis can fail per-op —
    e.g. a backend without cost analysis — without poisoning the rest).

    The measurement reuses the ops' pure step fns directly (fresh jit,
    not the graph's cached executables), so it never perturbs a running
    pipeline's compile cache or state.
    """
    import jax

    from repro.launch import roofline

    states = graph.init_states()
    env = dict(batch)
    n_ev = int(events) if events else _n_events(batch)
    measured: Dict[str, Any] = {}
    notes: List[str] = []
    for i, op in enumerate(graph.ops):
        declared = op.cost
        # channel-restricted input for OpGraph ops; a linear Pipeline op
        # (undeclared channels) sees the full batch, exactly as _apply
        # feeds it
        inb = (dict(env) if op.reads is None
               else {k: env[k] for k in op.reads if k in env})
        try:
            compiled = jax.jit(op.fn).lower(states[op.name], inb).compile()
            flops_ev, bytes_ev = roofline.op_event_costs(compiled, n_ev)
        except Exception as e:  # pragma: no cover - backend specific
            notes.append(f"{op.name}: kept declared cost "
                         f"({type(e).__name__}: {e})")
            flops_ev = None
        try:
            states, env = graph._apply(i, states, env, call=op.fn)
        except Exception as e:
            # the op cannot even execute on this batch — downstream ops
            # would see a wrong env, so stop measuring here and keep the
            # declared costs for the rest of the graph
            notes.append(f"{op.name}: execution failed, measurement "
                         f"aborted ({type(e).__name__}: {e})")
            break
        if flops_ev is None:
            continue
        if op.writes is None:
            # linear chain: the op forwards the whole batch downstream
            out_nbytes = _pytree_nbytes(
                {k: v for k, v in env.items() if k != "rng"})
        else:
            out_nbytes = sum(_pytree_nbytes(env[k]) for k in op.writes
                             if k in env)
        measured[op.name] = replace(
            declared,
            flops_per_event=flops_ev,
            bytes_per_event=bytes_ev,
            out_bytes_per_event=out_nbytes / n_ev,
            state_bytes=_pytree_nbytes(states[op.name]),
        )
    return measured, notes


@dataclass
class Candidate:
    overrides: Dict
    recipe: Optional[str] = None
    note: str = ""


@dataclass
class TuneResult:
    candidate: Candidate
    ok: bool
    mem_gib: float = float("inf")
    bound_s: float = float("inf")
    dominant: str = ""
    roofline_fraction: float = 0.0
    useful_ratio: float = 0.0
    error: str = ""
    record: Optional[dict] = None

    def better_than(self, other: "TuneResult", mem_cap_gib: float) -> bool:
        if not self.ok:
            return False
        if not other.ok:
            return True
        a_fits = self.mem_gib <= mem_cap_gib
        b_fits = other.mem_gib <= mem_cap_gib
        if a_fits != b_fits:
            return a_fits
        if a_fits:
            return self.bound_s < other.bound_s
        return self.mem_gib < other.mem_gib


def default_candidates(cfg) -> List[Candidate]:
    """A modest, napkin-math-ordered candidate set (§Perf methodology:
    biggest predicted win first)."""
    cands = [Candidate({}, note="baseline")]
    for mb in (1, 2, 4, 8, 16):
        if mb != cfg.microbatches:
            cands.append(Candidate({"microbatches": mb},
                                   note=f"microbatches={mb}"))
    for chunk in (256, 512, 2048):
        if chunk != cfg.attn_chunk:
            cands.append(Candidate({"attn_chunk": chunk},
                                   note=f"attn_chunk={chunk}"))
    for remat in ("dots",):
        if remat != cfg.remat:
            cands.append(Candidate({"remat": remat}, note=f"remat={remat}"))
    return cands


def evaluate_candidate(arch: str, shape_name: str, cand: Candidate, *,
                       multi_pod: bool = False, tag: str = "tune",
                       save: bool = False) -> TuneResult:
    """Dry-run compile one candidate and extract the roofline verdict.

    NOTE: must run in a process with 512 host devices (launch via
    ``python -m repro.launch.tune`` or from dryrun-like entrypoints)."""
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape_name, multi_pod, recipe=cand.recipe,
                   overrides=cand.overrides or None, tag=tag, save=save,
                   force=True)
    if not rec.get("ok"):
        return TuneResult(cand, False, error=rec.get("error", "?"),
                          record=rec)
    rf = rec["roofline"]
    return TuneResult(
        cand, True,
        mem_gib=rec["memory"]["total_per_device"] / 2**30,
        bound_s=max(rf["t_compute_s"], rf["t_memory_s"], rf["t_collective_s"]),
        dominant=rf["dominant"],
        roofline_fraction=rf["roofline_fraction"],
        useful_ratio=rf["useful_flops_ratio"],
        record=rec,
    )


def tune(arch: str, shape_name: str, candidates: List[Candidate], *,
         mem_cap_gib: float = 16.0, log_path: Optional[str] = None,
         stop_after_no_improve: int = 3) -> Tuple[TuneResult, List[TuneResult]]:
    """Greedy sweep with early stop (3 consecutive <5% improvements)."""
    results: List[TuneResult] = []
    best: Optional[TuneResult] = None
    stale = 0
    for cand in candidates:
        r = evaluate_candidate(arch, shape_name, cand)
        results.append(r)
        if best is None or r.better_than(best, mem_cap_gib):
            improved = best is None or (
                best.bound_s - r.bound_s) > 0.05 * best.bound_s or (
                best.mem_gib > mem_cap_gib >= r.mem_gib)
            best = r
            stale = 0 if improved else stale + 1
        else:
            stale += 1
        if log_path:
            p = pathlib.Path(log_path)
            p.parent.mkdir(parents=True, exist_ok=True)
            with p.open("a") as f:
                f.write(json.dumps({
                    "arch": arch, "shape": shape_name, "note": cand.note,
                    "ok": r.ok, "mem_gib": round(r.mem_gib, 2),
                    "bound_s": r.bound_s, "dominant": r.dominant,
                    "roofline_fraction": r.roofline_fraction,
                    "error": r.error[:200],
                }) + "\n")
        if stale >= stop_after_no_improve:
            break
    return best, results
