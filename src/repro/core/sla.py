"""SLA specification and tracking (S2CE S3: workload shift must not
violate agreed SLAs), plus SLA-driven uplink codec admission: the
orchestrator compresses the edge->cloud uplink with the *cheapest*
:class:`~repro.core.codecs.UplinkCodec` whose tested accumulated-error
bound fits the job's ``error_budget``."""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, Optional


@dataclass(frozen=True)
class SLA:
    max_latency_s: float = 0.5          # end-to-end event latency
    min_throughput: float = 0.0         # events/s
    max_staleness_s: float = 5.0        # model update staleness
    max_error_rate: Optional[float] = None
    # accumulated relative error the uplink codec may introduce (the
    # error-feedback residual bound, normalized by the stream's peak
    # magnitude). 0.0 = lossless uplink required -> identity codec.
    error_budget: float = 0.0


def pick_codec(sla: SLA, candidates: Optional[Iterable] = None):
    """The cheapest uplink codec the SLA admits.

    A codec is admissible when its property-tested ``error_bound`` fits
    within ``sla.error_budget``; among admissible candidates the one with
    the smallest wire ``ratio`` wins (ties broken toward the smaller
    error bound). The identity codec has bound 0.0 and is therefore
    always admissible — a zero budget degrades gracefully to a lossless
    uplink, never to an inadmissible codec.
    """
    from repro.core.codecs import DEFAULT_CODECS, identity_codec
    cands = list(candidates) if candidates is not None else list(DEFAULT_CODECS)
    budget = max(0.0, sla.error_budget)
    admissible = [c for c in cands if c.error_bound <= budget]
    if not admissible:
        return identity_codec()
    return min(admissible, key=lambda c: (c.ratio, c.error_bound))


@dataclass
class SLATracker:
    sla: SLA
    window: int = 100
    latencies: Deque[float] = field(default_factory=lambda: collections.deque(maxlen=1000))
    throughputs: Deque[float] = field(default_factory=lambda: collections.deque(maxlen=1000))
    violations: int = 0
    checks: int = 0

    def observe(self, latency_s: float, throughput: float):
        self.latencies.append(latency_s)
        self.throughputs.append(throughput)
        self.checks += 1
        if (latency_s > self.sla.max_latency_s
                or throughput < self.sla.min_throughput):
            self.violations += 1

    @property
    def p99_latency(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(0.99 * len(xs)))]

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.checks, 1)

    def ok(self) -> bool:
        return self.violation_rate < 0.01

    def report(self) -> Dict[str, float]:
        import numpy as np
        return {
            "p99_latency_s": self.p99_latency,
            "mean_throughput": float(np.mean(self.throughputs)) if self.throughputs else 0.0,
            "violation_rate": self.violation_rate,
        }
