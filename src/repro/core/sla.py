"""SLA specification and tracking (S2CE S3: workload shift must not
violate agreed SLAs), plus SLA-driven uplink codec admission.

Admission has two modes:

* **static** (:func:`pick_codec` without a report) — the cheapest
  :class:`~repro.core.codecs.UplinkCodec` whose tested accumulated-error
  bound fits the job's ``error_budget``. This is the one-shot choice the
  orchestrator makes at job start.
* **rate-aware** (:func:`pick_codec` / :func:`codec_candidates` with a
  ``report``) — re-admission at replan time against *windowed* SLA
  telemetry: when the bottleneck uplink is saturated
  (``uplink_utilization >= UPLINK_SATURATED``) every budget-admissible
  codec is on the table and the plan search escalates toward cheaper
  wire; when violations come from latency/staleness rather than
  bandwidth, or the link has clear headroom
  (``<= UPLINK_RELAXED``), admission de-escalates toward lossless. In
  between the two thresholds the incumbent codec is kept — the
  hysteresis dead band that stops codec flapping when utilization
  hovers around a threshold.

:class:`SLATracker` supplies the telemetry: every statistic it reports
is computed over the last ``window`` observations (rolling violation
counts, windowed deques), so a clean stretch ages earlier violations
out and ``ok()`` recovers — a lifetime violation counter would make the
controller replan forever on stale history.
"""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, Iterable, List, Mapping, Optional, Tuple

# -- rate-aware admission thresholds (shared by the offload controller
# and the tests so the hysteresis band has one definition) -------------------
UPLINK_SATURATED = 0.9   # escalate: modeled bottleneck-link utilization >= this
UPLINK_RELAXED = 0.5     # de-escalate toward lossless below this
VIOLATION_TOLERANCE = 0.01   # windowed violation rate SLATracker.ok allows


@dataclass(frozen=True)
class SLA:
    max_latency_s: float = 0.5          # end-to-end event latency
    min_throughput: float = 0.0         # events/s
    max_staleness_s: float = 5.0        # model update staleness
    max_error_rate: Optional[float] = None
    # accumulated relative error the uplink codec may introduce (the
    # error-feedback residual bound, normalized by the stream's peak
    # magnitude). 0.0 = lossless uplink required -> identity codec.
    error_budget: float = 0.0


def _admissible(sla: SLA, candidates: Optional[Iterable]) -> List:
    from repro.core.codecs import DEFAULT_CODECS, identity_codec
    cands = list(candidates) if candidates is not None else list(DEFAULT_CODECS)
    budget = max(0.0, sla.error_budget)
    admissible = [c for c in cands if c.error_bound <= budget]
    # the identity codec has bound 0.0 and is therefore always admissible
    # — a zero budget degrades gracefully to a lossless uplink, never to
    # an inadmissible codec
    return admissible or [identity_codec()]


def codec_candidates(sla: SLA, report: Optional[Mapping] = None,
                     candidates: Optional[Iterable] = None) -> List:
    """The codec candidate set admission allows, rate-aware.

    Every returned codec fits ``sla.error_budget`` (the hard admission
    invariant — telemetry can narrow the set but never widen it past the
    budget). Without a ``report`` the full budget-admissible set is
    returned. With a windowed report (an :meth:`SLATracker.report` dict,
    optionally extended with the modeled ``uplink_utilization`` of the
    current plan and the incumbent ``codec`` name):

    * ``uplink_utilization >= UPLINK_SATURATED`` — the link is the
      bottleneck: the full admissible set is returned so the plan search
      can escalate to the cheapest wire that restores feasibility;
    * windowed *non-bandwidth* violations without saturation, or
      ``uplink_utilization <= UPLINK_RELAXED`` — compression is not
      buying anything (the violations come from latency/staleness, or
      the link has headroom): de-escalate to the most faithful
      admissible codec (lossless when the budget allows identity). A
      report carrying the per-cause ``latency_violation_rate`` is judged
      on that (throughput violations are bandwidth symptoms — starving
      the wire harder by going lossless would make them worse); a bare
      report falls back to the aggregate ``violation_rate``;
    * otherwise (the hysteresis dead band between the thresholds) — keep
      the incumbent ``report["codec"]`` when it is still admissible.
    """
    admissible = _admissible(sla, candidates)
    if report is None:
        return admissible
    util = float(report.get("uplink_utilization", 0.0))
    vrate = float(report.get("latency_violation_rate",
                             report.get("violation_rate", 0.0)))
    if util >= UPLINK_SATURATED:
        return admissible
    if vrate >= VIOLATION_TOLERANCE or util <= UPLINK_RELAXED:
        return [min(admissible, key=lambda c: (c.error_bound, c.ratio))]
    current = report.get("codec")
    kept = [c for c in admissible if c.name == current]
    return kept or admissible


def pick_codec(sla: SLA, candidates: Optional[Iterable] = None,
               report: Optional[Mapping] = None):
    """The uplink codec the SLA admits — cheapest wire among the
    rate-aware candidate set.

    Without a ``report`` this is the classic static admission: among the
    codecs whose property-tested ``error_bound`` fits
    ``sla.error_budget``, the smallest wire ``ratio`` wins (ties broken
    toward the smaller error bound). With a windowed SLA ``report`` the
    candidate set first passes :func:`codec_candidates`, so the choice
    escalates under bandwidth pressure and de-escalates toward lossless
    when violations are not bandwidth-bound. An admitted codec NEVER
    exceeds the budget.
    """
    cands = codec_candidates(sla, report=report, candidates=candidates)
    return min(cands, key=lambda c: (c.ratio, c.error_bound))


def plan_violation(plan, sla: SLA) -> Optional[str]:
    """Why a modeled :class:`~repro.core.costmodel.PipelinePlan` cannot
    meet ``sla`` — a loud human-readable reason, or ``None`` when the
    plan is admissible. This is the fleet scheduler's admission predicate
    (:mod:`repro.core.fleet`): a tenant whose *best* plan under residual
    capacity trips any clause here is rejected or queued, never silently
    degraded.

    Checks, in order of loudness:

    * placement feasibility (some pool or link over capacity — the plan's
      own ``notes`` carry the specifics);
    * modeled critical-path latency against ``sla.max_latency_s``.

    Throughput is rate-implicit — an infeasible plan at the tenant's
    demand rate *is* the throughput failure — so no separate clause.
    """
    if not plan.feasible:
        detail = "; ".join(plan.notes) if plan.notes else "over capacity"
        return f"infeasible plan: {detail}"
    if plan.latency_s > sla.max_latency_s:
        return (f"modeled latency {plan.latency_s:.4f}s exceeds SLA "
                f"max_latency_s={sla.max_latency_s:.4f}s")
    return None


@dataclass
class SLATracker:
    """Windowed SLA telemetry: every reported statistic covers the last
    ``window`` observations only, so violations age out after a clean
    stretch. ``violations``/``checks`` remain as *lifetime* counters for
    audit/back-compat; decisions (``ok``, ``violation_rate``) are
    strictly windowed."""
    sla: SLA
    window: int = 100
    latencies: Deque[float] = field(default_factory=collections.deque)
    throughputs: Deque[float] = field(default_factory=collections.deque)
    violations: int = 0              # lifetime count (audit only)
    checks: int = 0                  # lifetime count (audit only)

    def __post_init__(self):
        if self.window <= 0:
            raise ValueError(f"window must be positive, got {self.window}")
        # honor `window`: the deques carry exactly the last `window`
        # observations (they used to hardcode maxlen=1000, silently
        # ignoring `window`)
        self.latencies = collections.deque(self.latencies,
                                           maxlen=self.window)
        self.throughputs = collections.deque(self.throughputs,
                                             maxlen=self.window)
        # per-observation violation flags (latency_bad, throughput_bad)
        # with rolling counts so the windowed rates are O(1) per step
        self._flags: Deque[Tuple[bool, bool]] = collections.deque(
            maxlen=self.window)
        self._win_viol = 0
        self._win_lat = 0
        self._win_thr = 0

    def observe(self, latency_s: float, throughput: float):
        self.latencies.append(latency_s)
        self.throughputs.append(throughput)
        self.checks += 1
        lat_bad = latency_s > self.sla.max_latency_s
        thr_bad = throughput < self.sla.min_throughput
        if len(self._flags) == self._flags.maxlen:   # evict the aged-out flag
            old_lat, old_thr = self._flags[0]
            self._win_viol -= int(old_lat or old_thr)
            self._win_lat -= int(old_lat)
            self._win_thr -= int(old_thr)
        self._flags.append((lat_bad, thr_bad))
        self._win_viol += int(lat_bad or thr_bad)
        self._win_lat += int(lat_bad)
        self._win_thr += int(thr_bad)
        if lat_bad or thr_bad:
            self.violations += 1

    @property
    def window_checks(self) -> int:
        return len(self._flags)

    @property
    def p99_latency(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(0.99 * len(xs)))]

    @property
    def violation_rate(self) -> float:
        """Fraction of the last ``window`` observations violating the SLA."""
        return self._win_viol / max(len(self._flags), 1)

    @property
    def latency_violation_rate(self) -> float:
        return self._win_lat / max(len(self._flags), 1)

    @property
    def throughput_violation_rate(self) -> float:
        return self._win_thr / max(len(self._flags), 1)

    def ok(self) -> bool:
        return self.violation_rate < VIOLATION_TOLERANCE

    def report(self) -> Dict[str, float]:
        """The windowed telemetry dict rate-aware codec admission reads
        (:func:`codec_candidates`); the caller may extend it with the
        modeled ``uplink_utilization`` and incumbent ``codec``."""
        import numpy as np
        return {
            "p99_latency_s": self.p99_latency,
            "mean_throughput": (float(np.mean(self.throughputs))
                                if self.throughputs else 0.0),
            "violation_rate": self.violation_rate,
            "latency_violation_rate": self.latency_violation_rate,
            "throughput_violation_rate": self.throughput_violation_rate,
            "window": float(self.window),
            "window_checks": float(self.window_checks),
        }
