"""SLA specification and tracking (S2CE S3: workload shift must not
violate agreed SLAs)."""

from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, Optional


@dataclass(frozen=True)
class SLA:
    max_latency_s: float = 0.5          # end-to-end event latency
    min_throughput: float = 0.0         # events/s
    max_staleness_s: float = 5.0        # model update staleness
    max_error_rate: Optional[float] = None


@dataclass
class SLATracker:
    sla: SLA
    window: int = 100
    latencies: Deque[float] = field(default_factory=lambda: collections.deque(maxlen=1000))
    throughputs: Deque[float] = field(default_factory=lambda: collections.deque(maxlen=1000))
    violations: int = 0
    checks: int = 0

    def observe(self, latency_s: float, throughput: float):
        self.latencies.append(latency_s)
        self.throughputs.append(throughput)
        self.checks += 1
        if (latency_s > self.sla.max_latency_s
                or throughput < self.sla.min_throughput):
            self.violations += 1

    @property
    def p99_latency(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        return xs[min(len(xs) - 1, int(0.99 * len(xs)))]

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.checks, 1)

    def ok(self) -> bool:
        return self.violation_rate < 0.01

    def report(self) -> Dict[str, float]:
        import numpy as np
        return {
            "p99_latency_s": self.p99_latency,
            "mean_throughput": float(np.mean(self.throughputs)) if self.throughputs else 0.0,
            "violation_rate": self.violation_rate,
        }
