"""Dynamic topology: a membership/discovery layer for pools that join,
leave, and fail mid-stream (ROADMAP item 2; ECHO-style adaptive
orchestration, arxiv 1707.00889, and FogFlow-style discovery where edge
devices publish themselves with location metadata).

The :class:`MembershipDirectory` owns the authoritative, **versioned**
:class:`~repro.core.costmodel.ClusterSpec`:

* pools :meth:`register`/:meth:`deregister` at runtime, optionally with
  :class:`Locality` metadata — a registered pool's default link
  latencies to located peers derive from geometric distance, so
  placement prefers nearby pools from the moment they join;
* a **heartbeat/lease** mechanism declares silent pools dead: every
  registered pool must :meth:`heartbeat` within ``lease_ticks`` of the
  directory clock or :meth:`tick` expires it (``pool_failed``). The
  clock is the deterministic simulation step the orchestrator already
  counts — never wall time — so failure scenarios replay bitwise;
* a **probe table** rewrites each :class:`Link`'s latency
  (:meth:`observe_latency`) and bandwidth (:meth:`observe_bandwidth`)
  from observed samples via EWMA, turning the hand-declared link matrix
  into a data-driven one. Announcements (``link_update`` events) are
  hysteresis-gated by a relative tolerance so consumers re-price on
  real shifts, not probe noise.

Every mutation bumps ``version`` and appends a typed
:class:`TopologyEvent`; consumers (:class:`~repro.core.orchestrator.
Orchestrator`, :class:`~repro.core.fleet.FleetOrchestrator`) hold a
:class:`TopologySubscription` cursor and drain events at their own
step boundary. A directory nobody mutates emits nothing — consumers'
trajectories are then bitwise identical to a static-``ClusterSpec``
run (the differential-parity discipline of PRs 6-8).

Seed pools (those the directory is constructed with) are NOT
lease-monitored: a static core topology never expires for want of
heartbeats it was never promised. Only pools that arrive through
:meth:`register` (or that start heartbeating) carry a lease.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Tuple

from repro.core.costmodel import ClusterSpec, Link, Resource

# event kinds
POOL_JOINED = "pool_joined"
POOL_LEFT = "pool_left"        # voluntary deregistration
POOL_FAILED = "pool_failed"    # lease expired (silent death)
LINK_UPDATE = "link_update"    # probe-driven latency rewrite


@dataclass(frozen=True)
class Locality:
    """Where a pool physically sits: coordinates in an abstract plane
    (kilometre-ish units) plus an optional region tag. Distance seeds
    the derived link latency for freshly joined pools; probes refine
    it."""
    x: float = 0.0
    y: float = 0.0
    region: str = ""

    def distance(self, other: "Locality") -> float:
        return math.hypot(self.x - other.x, self.y - other.y)


@dataclass(frozen=True)
class TopologyEvent:
    """One membership change, as consumers see it. ``subject`` is the
    pool name (or ``"src->dst"`` for link updates); ``version`` is the
    spec version AFTER the event, so a consumer that re-reads
    ``directory.spec`` at version >= event.version has already absorbed
    it."""
    kind: str
    subject: str
    version: int
    clock: int
    detail: str = ""


class TopologySubscription:
    """A cursor into the directory's event log. :meth:`poll` returns
    the events appended since the last poll — consumers drain at their
    own step boundary instead of being called back mid-mutation."""

    def __init__(self, directory: "MembershipDirectory", cursor: int):
        self._directory = directory
        self._cursor = cursor

    def poll(self) -> List[TopologyEvent]:
        events = self._directory.events[self._cursor:]
        self._cursor = len(self._directory.events)
        return list(events)


class MembershipDirectory:
    """The authoritative, versioned cluster topology.

    ``lease_ticks`` — a monitored pool silent for MORE than this many
    clock ticks is declared dead by :meth:`tick`.
    ``ewma_alpha`` — weight of each new latency sample.
    ``latency_tol`` — relative latency change required before a
    ``link_update`` event is announced (the probe-noise dead band).
    ``latency_per_km`` / ``base_latency`` — the geometric prior for
    links derived from :class:`Locality` at registration time.
    """

    def __init__(self, cluster: Optional[object] = None, *,
                 lease_ticks: int = 3, ewma_alpha: float = 0.3,
                 latency_tol: float = 0.2,
                 latency_per_km: float = 0.05e-3,
                 base_latency: float = 1e-3):
        if not 0.0 < ewma_alpha <= 1.0:
            raise ValueError(f"ewma_alpha {ewma_alpha} not in (0, 1]")
        if lease_ticks < 1:
            raise ValueError(f"lease_ticks {lease_ticks} must be >= 1")
        self.lease_ticks = int(lease_ticks)
        self.ewma_alpha = float(ewma_alpha)
        self.latency_tol = float(latency_tol)
        self.latency_per_km = float(latency_per_km)
        self.base_latency = float(base_latency)
        self.clock = 0
        self._pools: Dict[str, Resource] = {}
        self._links: Dict[Tuple[str, str], Link] = {}
        self._version = 0
        if cluster is not None:
            seed = ClusterSpec.of(cluster)
            self._pools = dict(seed.pools)
            self._links = {(ln.src, ln.dst): ln for ln in seed.links}
        # lease table: only pools registered (or heartbeating) at
        # runtime are monitored; seed pools never expire silently
        self._last_seen: Dict[str, int] = {}
        self._locality: Dict[str, Locality] = {}
        # probe table: EWMA latency estimate per directed pair, plus
        # the latency last ANNOUNCED via a link_update event (the
        # hysteresis reference)
        self._ewma: Dict[Tuple[str, str], float] = {}
        self._announced: Dict[Tuple[str, str], float] = {}
        # bandwidth-probe twin of the latency table (observe_bandwidth)
        self._bw_ewma: Dict[Tuple[str, str], float] = {}
        self._bw_announced: Dict[Tuple[str, str], float] = {}
        self.events: List[TopologyEvent] = []
        self._spec_cache: Optional[ClusterSpec] = None

    # -- views --------------------------------------------------------------
    @property
    def version(self) -> int:
        return self._version

    @property
    def spec(self) -> ClusterSpec:
        """The current topology as an immutable ClusterSpec snapshot,
        stamped with the directory version."""
        if self._spec_cache is None:
            self._spec_cache = ClusterSpec(dict(self._pools),
                                           list(self._links.values()),
                                           version=self._version)
        return self._spec_cache

    @property
    def pool_names(self) -> List[str]:
        return sorted(self._pools)

    def __contains__(self, name: str) -> bool:
        return name in self._pools

    def monitored(self, name: str) -> bool:
        """Whether ``name`` carries a lease (expires without heartbeats)."""
        return name in self._last_seen

    def locality(self, name: str) -> Optional[Locality]:
        return self._locality.get(name)

    def subscribe(self) -> TopologySubscription:
        """A cursor starting AFTER all past events: a late-joining
        consumer sees only changes from now on (it reads the current
        ``spec`` for the present state)."""
        return TopologySubscription(self, len(self.events))

    # -- internals ----------------------------------------------------------
    def _advance(self, now: Optional[int]) -> int:
        if now is not None:
            self.clock = max(self.clock, int(now))
        return self.clock

    def _emit(self, kind: str, subject: str, detail: str = "") -> None:
        self._version += 1
        self._spec_cache = None
        self.events.append(TopologyEvent(kind, subject, self._version,
                                         self.clock, detail))

    def _drop_pool_state(self, name: str) -> None:
        self._pools.pop(name)
        self._last_seen.pop(name, None)
        self._locality.pop(name, None)
        for key in [k for k in self._links if name in k]:
            self._links.pop(key)
        for key in [k for k in self._ewma if name in k]:
            self._ewma.pop(key)
            self._announced.pop(key, None)
        for key in [k for k in self._bw_ewma if name in k]:
            self._bw_ewma.pop(key)
            self._bw_announced.pop(key, None)

    # -- membership mutations ----------------------------------------------
    def register(self, resource: Resource, links: Iterable[Link] = (),
                 locality: Optional[Locality] = None,
                 now: Optional[int] = None, monitored: bool = True
                 ) -> TopologyEvent:
        """A pool joins mid-run. Declared ``links`` must touch the new
        pool; pairs not declared are derived: from geometric distance
        when both endpoints carry :class:`Locality` (so placement
        prefers nearby pools from the start), else from the spec's
        charge-the-slow-side default at :meth:`ClusterSpec.link` time.
        Registered pools are lease-``monitored`` by default — they must
        heartbeat or :meth:`tick` declares them dead."""
        now = self._advance(now)
        name = resource.name
        if name in self._pools:
            raise ValueError(f"register: pool {name!r} already a member")
        links = list(links)
        # validate BEFORE mutating: a rejected registration must leave
        # the directory exactly as it found it
        for ln in links:
            if name not in (ln.src, ln.dst):
                raise ValueError(
                    f"register {name!r}: link {ln.src}->{ln.dst} does not "
                    "touch the registering pool")
            other = ln.dst if ln.src == name else ln.src
            if other not in self._pools:
                raise ValueError(
                    f"register {name!r}: link peer {other!r} is not a "
                    f"member (known pools: {sorted(self._pools)})")
        self._pools[name] = resource
        if locality is not None:
            self._locality[name] = locality
        for ln in links:
            self._links[(ln.src, ln.dst)] = ln
        # geometric prior: derive links to every located peer that has
        # no declared link yet, both directions, bw = slow side's net_bw
        if locality is not None:
            for peer, ploc in self._locality.items():
                if peer == name:
                    continue
                lat = (self.base_latency
                       + locality.distance(ploc) * self.latency_per_km)
                a, b = self._pools[name], self._pools[peer]
                bw = min(a.net_bw, b.net_bw)
                for key in ((name, peer), (peer, name)):
                    if key not in self._links:
                        self._links[key] = Link(key[0], key[1], bw=bw,
                                                latency=lat)
        if monitored:
            self._last_seen[name] = now
        ev_detail = (f"locality=({locality.x:g},{locality.y:g})"
                     if locality is not None else "")
        self._emit(POOL_JOINED, name, ev_detail)
        return self.events[-1]

    def deregister(self, name: str, now: Optional[int] = None
                   ) -> TopologyEvent:
        """A pool leaves voluntarily: it and every link touching it
        disappear from the spec."""
        self._advance(now)
        if name not in self._pools:
            raise ValueError(f"deregister: unknown pool {name!r} "
                             f"(known pools: {sorted(self._pools)})")
        self._drop_pool_state(name)
        self._emit(POOL_LEFT, name, "deregistered")
        return self.events[-1]

    def heartbeat(self, name: str, now: Optional[int] = None) -> None:
        """Renew ``name``'s lease (and start monitoring it if it was an
        unmonitored seed pool)."""
        now = self._advance(now)
        if name not in self._pools:
            raise ValueError(f"heartbeat: unknown pool {name!r} "
                             f"(known pools: {sorted(self._pools)})")
        self._last_seen[name] = now

    def tick(self, now: Optional[int] = None) -> List[str]:
        """Advance the simulation clock and expire every monitored pool
        silent for more than ``lease_ticks`` — each expiry emits a
        ``pool_failed`` event. Idempotent: re-ticking the same clock
        value expires nothing new. Returns the pools declared dead."""
        now = self._advance(now)
        dead = sorted(name for name, seen in self._last_seen.items()
                      if now - seen > self.lease_ticks)
        for name in dead:
            last = self._last_seen[name]
            self._drop_pool_state(name)
            self._emit(POOL_FAILED, name,
                       f"lease expired (last heartbeat t={last}, "
                       f"lease={self.lease_ticks})")
        return dead

    # -- latency probes ------------------------------------------------------
    def observe_latency(self, src: str, dst: str, sample_s: float,
                        now: Optional[int] = None
                        ) -> Optional[TopologyEvent]:
        """Feed one observed latency sample for ``src -> dst``. The EWMA
        estimate rewrites the link's latency in the spec; a
        ``link_update`` event is announced only when the estimate moved
        more than ``latency_tol`` (relative) from the last announced
        value — probe noise stays silent. Returns the event, if any."""
        self._advance(now)
        for end in (src, dst):
            if end not in self._pools:
                raise ValueError(
                    f"observe_latency {src}->{dst}: unknown pool {end!r} "
                    f"(known pools: {sorted(self._pools)})")
        if sample_s < 0.0:
            raise ValueError(f"observe_latency: negative sample {sample_s}")
        key = (src, dst)
        ln = self._links.get(key) or self.spec.link(src, dst)
        prev = self._ewma.get(key, ln.latency)
        est = self.ewma_alpha * float(sample_s) \
            + (1.0 - self.ewma_alpha) * prev
        self._ewma[key] = est
        self._links[key] = replace(ln, latency=est)
        # the spec must always carry the freshest estimate, even when
        # the move is below the announcement dead band
        self._version += 1
        self._spec_cache = None
        ref = self._announced.get(key, ln.latency)
        if abs(est - ref) > self.latency_tol * max(ref, 1e-12):
            self._announced[key] = est
            self.events.append(TopologyEvent(
                LINK_UPDATE, f"{src}->{dst}", self._version, self.clock,
                f"latency {ref * 1e3:.3g}ms -> {est * 1e3:.3g}ms"))
            return self.events[-1]
        return None

    def observe_bandwidth(self, src: str, dst: str, sample_bps: float,
                          now: Optional[int] = None
                          ) -> Optional[TopologyEvent]:
        """Feed one observed throughput sample (bytes/s) for
        ``src -> dst`` — the bandwidth twin of :meth:`observe_latency`.
        The EWMA estimate rewrites the link's ``bw`` in the spec (so the
        placement DP and :func:`~repro.core.costmodel.migration_cost`
        price wire time against measured, not declared, capacity); a
        ``link_update`` event is announced only when the estimate moved
        more than ``latency_tol`` (relative) from the last announced
        value. Returns the event, if any."""
        self._advance(now)
        for end in (src, dst):
            if end not in self._pools:
                raise ValueError(
                    f"observe_bandwidth {src}->{dst}: unknown pool "
                    f"{end!r} (known pools: {sorted(self._pools)})")
        if sample_bps <= 0.0:
            raise ValueError(
                f"observe_bandwidth: non-positive sample {sample_bps}")
        key = (src, dst)
        ln = self._links.get(key) or self.spec.link(src, dst)
        prev = self._bw_ewma.get(key, ln.bw)
        est = self.ewma_alpha * float(sample_bps) \
            + (1.0 - self.ewma_alpha) * prev
        self._bw_ewma[key] = est
        self._links[key] = replace(ln, bw=est)
        # the spec must always carry the freshest estimate, even when
        # the move is below the announcement dead band
        self._version += 1
        self._spec_cache = None
        ref = self._bw_announced.get(key, ln.bw)
        if abs(est - ref) > self.latency_tol * max(ref, 1e-12):
            self._bw_announced[key] = est
            self.events.append(TopologyEvent(
                LINK_UPDATE, f"{src}->{dst}", self._version, self.clock,
                f"bw {ref / 1e6:.3g}MB/s -> {est / 1e6:.3g}MB/s"))
            return self.events[-1]
        return None

    def probe_estimate(self, src: str, dst: str) -> Optional[float]:
        """The current EWMA latency estimate, or None if never probed."""
        return self._ewma.get((src, dst))

    def bandwidth_estimate(self, src: str, dst: str) -> Optional[float]:
        """The current EWMA bandwidth estimate, or None if never probed."""
        return self._bw_ewma.get((src, dst))

    def __repr__(self) -> str:
        return (f"MembershipDirectory(v{self._version}, t={self.clock}, "
                f"{len(self._pools)} pools, {len(self._last_seen)} "
                f"monitored, {len(self.events)} events)")
