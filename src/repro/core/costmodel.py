"""Analytic cost model + cluster topology API shared by the placement
optimizer, the offload controller, and the self-tuner (S2CE O1/O2 "smart
resource management"). The same v5e constants ground the §Roofline
report, so orchestrator decisions and the perf analysis speak one
language.

Resources are heterogeneous pools (cloud TPU pods, edge nodes); a
:class:`ClusterSpec` names any number of them and the directed
:class:`Link` objects between them (bandwidth, latency, and the uplink
codec compressing bytes on that link); operators are stream-pipeline
stages with per-event flops/bytes/output-bytes costs.

DAG plan latency is the **critical path** over the op DAG: each op
contributes its compute latency, each crossing flow edge the latency of
the link it rides. For a linear chain the critical path is the whole
chain (one path), so chain plans price identically to the historical
per-op sum — the PR 2/3 parity the tests pin down.
"""

from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple, Union

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS


@dataclass(frozen=True)
class Resource:
    name: str
    kind: str                  # "cloud" | "edge"
    chips: int = 1
    flops: float = PEAK_FLOPS  # per chip
    mem_bw: float = HBM_BW
    mem_cap: float = 16e9
    net_bw: float = LINK_BW    # to the next hop (edge->cloud uplink for edge)
    net_latency: float = 1e-3  # seconds per hop
    energy_w: float = 200.0    # watts per chip (coarse; drives O2 decisions)

    @property
    def total_flops(self) -> float:
        return self.chips * self.flops


EDGE_NODE = Resource("edge", "edge", chips=1, flops=2e12, mem_bw=50e9,
                     mem_cap=4e9, net_bw=1e9, net_latency=20e-3, energy_w=15.0)
CLOUD_POD = Resource("cloud", "cloud", chips=256, flops=PEAK_FLOPS,
                     mem_bw=HBM_BW, mem_cap=16e9, net_bw=LINK_BW,
                     net_latency=0.2e-3, energy_w=250.0)


@dataclass(frozen=True)
class Link:
    """A directed network link between two named pools.

    ``codec`` names the :class:`~repro.core.codecs.UplinkCodec` that
    compresses every byte shipped over this link — plans price crossings
    at ``codec.wire_bytes(payload)`` and the orchestrator applies the
    same codec to tensors that actually cross at runtime.

    ``energy_per_byte`` (joules per *wire* byte; radio/NIC transmit
    energy) is priced into the plan's energy aggregate: every crossing
    adds ``wire_bytes * rate * energy_per_byte`` watts, so placement can
    trade latency against uplink energy. The default 0.0 is bitwise
    neutral — links that don't declare it price exactly as before.
    """
    src: str
    dst: str
    bw: float                  # bytes/s
    latency: float             # seconds per message
    codec: str = "identity"
    energy_per_byte: float = 0.0   # J per wire byte (0.0 = unpriced)

    def wire_bytes(self, raw_bytes: float) -> float:
        from repro.core.codecs import get_codec
        return get_codec(self.codec).wire_bytes(raw_bytes)


class ClusterSpec(Mapping):
    """First-class cluster topology: named :class:`Resource` pools (any
    number of edge pools and cloud pods) plus explicit directed
    :class:`Link` objects between them.

    The spec is a ``Mapping[str, Resource]`` over its pools, so legacy
    call sites that iterate a flat resource dict keep working unchanged;
    every cost/placement entry point coerces through :meth:`of`, which
    wraps a plain dict in a spec with *derived default links*: for any
    ``(src, dst)`` pair without a declared link, bandwidth is the slower
    side's ``net_bw`` and latency the slower side's ``net_latency`` —
    exactly the historical "charge the slow side" rule, so a wrapped
    two-pool dict prices identically to the old flat-dict model.

    ``version`` is the topology generation stamp: a static spec stays at
    0 forever; a :class:`~repro.core.membership.MembershipDirectory`
    bumps it on every join/leave/failure/probe so consumers can tell a
    re-derived snapshot from the one their plan was priced under.
    Derived specs (:meth:`with_uplink_codec`, :meth:`residual`) carry
    their base's version — they re-price the SAME topology generation.
    """

    def __init__(self, pools: Union[Dict[str, Resource], Sequence[Resource]],
                 links: Iterable[Link] = (), *, version: int = 0):
        self.version = int(version)
        if isinstance(pools, Mapping):
            self.pools: Dict[str, Resource] = dict(pools)
        else:
            seq = tuple(pools)
            self.pools = {r.name: r for r in seq}
            if len(self.pools) != len(seq):
                raise ValueError("duplicate pool names in ClusterSpec")
        self._links: Dict[Tuple[str, str], Link] = {}
        for ln in links:
            for end in (ln.src, ln.dst):
                if end not in self.pools:
                    raise ValueError(f"link {ln.src}->{ln.dst} references "
                                     f"unknown pool {end!r}")
            try:    # fail at construction, not deep inside cost evaluation
                from repro.core.codecs import get_codec
                get_codec(ln.codec)
            except KeyError as e:
                raise ValueError(
                    f"link {ln.src}->{ln.dst}: {e.args[0]}") from None
            self._links[(ln.src, ln.dst)] = ln

    # -- construction helpers ----------------------------------------------
    @classmethod
    def of(cls, resources: Union["ClusterSpec", Dict[str, Resource]]
           ) -> "ClusterSpec":
        """Coerce a flat ``{name: Resource}`` dict (the deprecated two-pool
        style) or an existing spec into a ClusterSpec."""
        if isinstance(resources, cls):
            return resources
        return cls(dict(resources))

    @classmethod
    def edge_cloud(cls, edge: Resource = EDGE_NODE,
                   cloud: Resource = CLOUD_POD) -> "ClusterSpec":
        """The classic one-edge/one-cloud topology (back-compat shim for
        pre-ClusterSpec call sites; prefer declaring pools + links)."""
        return cls({edge.name: edge, cloud.name: cloud})

    # -- Mapping interface over pools --------------------------------------
    def __getitem__(self, name: str) -> Resource:
        return self.pools[name]

    def __iter__(self) -> Iterator[str]:
        return iter(self.pools)

    def __len__(self) -> int:
        return len(self.pools)

    # -- topology views -----------------------------------------------------
    @property
    def links(self) -> Tuple[Link, ...]:
        return tuple(self._links.values())

    def pools_of_kind(self, kind: str) -> List[Resource]:
        return [r for r in self.pools.values() if r.kind == kind]

    @property
    def edge_pools(self) -> List[Resource]:
        return self.pools_of_kind("edge")

    @property
    def cloud_pools(self) -> List[Resource]:
        return self.pools_of_kind("cloud")

    def default_source(self) -> str:
        """Where the stream originates: the first edge pool (S2CE ingests
        at the edge gateway), or "" when the spec has no edge pools."""
        edges = self.edge_pools
        return edges[0].name if edges else ""

    def link(self, src: str, dst: str) -> Link:
        """The declared link ``src -> dst``, or the derived default: the
        slower endpoint's ``net_bw``/``net_latency`` and the identity
        codec (the historical charge-the-slow-side rule).

        An unknown endpoint raises ``ValueError`` naming the missing
        pool AND the known pool set — under membership churn a stale
        plan's pool name must fail loudly here, not as an ambiguous
        ``KeyError`` deep inside a cost evaluation."""
        ln = self._links.get((src, dst))
        if ln is not None:
            return ln
        for end in (src, dst):
            if end not in self.pools:
                raise ValueError(
                    f"link {src}->{dst}: unknown pool {end!r} (known "
                    f"pools: {sorted(self.pools)}); the pool may have "
                    "deregistered or failed since this plan was priced")
        a, b = self.pools[src], self.pools[dst]
        # strict <: on equal net_bw the historical rule charged the
        # destination side (``prev if prev.net_bw < res.net_bw else res``)
        slow = a if a.net_bw < b.net_bw else b
        return Link(src, dst, bw=slow.net_bw, latency=slow.net_latency)

    def with_uplink_codec(self, codec: str,
                          override: bool = False) -> "ClusterSpec":
        """A copy of this spec with ``codec`` attached to every
        edge<->cloud wire — the edge->cloud uplink AND the cloud->edge
        downlink, which carries ``downlink_ok`` traffic (the KV cache of
        a cloud-prefill/edge-decode split) and must price the same codec
        the runtime wire round-trip applies on that crossing. Declared
        links keep their bw/latency; missing ones are materialized from
        the derived defaults. This is how the SLA-chosen codec is
        attached to the topology. Edge<->edge and cloud<->cloud links
        are never touched.

        By default only links that don't already declare a lossy codec
        are rewritten — a user's per-link codec declaration wins over
        the blanket choice; pass ``override=True`` to replace those too.
        """
        links = dict(self._links)
        for e in self.edge_pools:
            for c in self.cloud_pools:
                for src, dst in ((e.name, c.name), (c.name, e.name)):
                    ln = self.link(src, dst)
                    if override or ln.codec == "identity":
                        links[(src, dst)] = replace(ln, codec=codec)
        return ClusterSpec(self.pools, links.values(), version=self.version)

    def without_pool(self, name: str) -> "ClusterSpec":
        """The topology with ``name`` (and every link touching it)
        removed and the version bumped — how a consumer derives the
        candidate set AFTER a pool left or failed, so the dead pool is
        excluded before any placement search runs."""
        if name not in self.pools:
            raise ValueError(
                f"without_pool: unknown pool {name!r} (known pools: "
                f"{sorted(self.pools)})")
        pools = {n: r for n, r in self.pools.items() if n != name}
        links = [ln for ln in self._links.values()
                 if name not in (ln.src, ln.dst)]
        return ClusterSpec(pools, links, version=self.version + 1)

    def residual(self,
                 pool_load: Optional[Mapping] = None,
                 link_load: Optional[Mapping] = None,
                 pool_state_bytes: Optional[Mapping] = None
                 ) -> "ClusterSpec":
        """A derived spec pricing a tenant against **residual** capacity
        — the heart of multi-tenant fleet scheduling (core/fleet): other
        tenants' reservations shrink what this tenant's placement search
        may assume, so ``evaluate_graph_plan`` on the residual spec
        prices against what is actually left, not the whole cluster.

        * ``pool_load``: ``{pool: fraction}`` of each pool's original
          compute/memory bandwidth already reserved. The pool's per-chip
          ``flops`` and ``mem_bw`` scale by ``1 - fraction`` — a tenant
          sharing a pool gets a proportional slice, so its utilization,
          compute latency, and energy all price at the fair-share rate,
          and ``utilization > 1`` on the residual pool is exactly
          "does not fit in what is left".
        * ``link_load``: ``{(src, dst): bytes_per_second}`` of wire
          bandwidth already reserved per directed link. The link's ``bw``
          drops by that amount (undeclared pairs are materialized from
          the derived defaults first), so link feasibility on the
          residual spec encodes the shared-capacity split.
        * ``pool_state_bytes``: ``{pool: bytes}`` of resident state
          other tenants hold on the pool; shrinks ``mem_cap``.

        Zero/absent loads return the pool and link objects *unchanged*
        (not merely equal), so a fleet of one tenant prices bitwise
        identically to the standalone spec.
        """
        pool_load = dict(pool_load or {})
        link_load = dict(link_load or {})
        state = dict(pool_state_bytes or {})
        for name in (*pool_load, *state):
            if name not in self.pools:
                raise ValueError(f"residual: unknown pool {name!r}")
        pools: Dict[str, Resource] = {}
        for name, r in self.pools.items():
            f = pool_load.get(name, 0.0)
            sb = state.get(name, 0.0)
            if f < -1e-9 or f > 1.0 + 1e-9:
                raise ValueError(
                    f"residual: pool {name!r} load {f:.4g} not in [0, 1]")
            if f <= 0.0 and sb <= 0.0:
                pools[name] = r
                continue
            # a fully-reserved pool keeps an epsilon share: placement
            # then prices any op there as over-capacity (infeasible)
            # instead of dividing by zero
            share = max(1.0 - f, 1e-9)
            pools[name] = replace(
                r, flops=r.flops * share, mem_bw=r.mem_bw * share,
                mem_cap=max(r.mem_cap - sb / max(r.chips, 1), 0.0))
        links: Dict[Tuple[str, str], Link] = dict(self._links)
        for key in link_load:
            src, dst = key
            if src not in self.pools or dst not in self.pools:
                raise ValueError(f"residual: unknown link {src}->{dst}")
            if key not in links:
                links[key] = self.link(src, dst)
        out = []
        for key, ln in links.items():
            b = link_load.get(key, 0.0)
            out.append(replace(ln, bw=max(ln.bw - b, 1e-9)) if b > 0.0
                       else ln)
        return ClusterSpec(pools, out, version=self.version)

    def __repr__(self) -> str:
        pools = ", ".join(f"{n}:{r.kind}" for n, r in self.pools.items())
        return (f"ClusterSpec(v{self.version}; {pools}; "
                f"{len(self._links)} declared links)")


ResourcesLike = Union[ClusterSpec, Dict[str, Resource]]


@dataclass(frozen=True)
class OperatorCost:
    """Per-event costs of a pipeline stage."""
    name: str
    flops_per_event: float
    bytes_per_event: float          # memory traffic
    out_bytes_per_event: float      # bytes emitted downstream
    state_bytes: float = 0.0        # resident state
    edge_capable: bool = True       # some stages (full DL train) are not
    # True -> this op may consume a cloud-resident producer from an edge
    # pool: the cloud->edge crossing is priced as a normal (costed) link
    # hop instead of being marked infeasible backhaul. This is a semantic
    # declaration like edge_capable — a decode op explicitly designed to
    # receive its KV-cache over the downlink (cloud-prefill/edge-decode)
    # sets it; stream analytics ops never should.
    downlink_ok: bool = False


def stage_time(op: OperatorCost, res: Resource, rate: float) -> float:
    """Seconds-per-second of stream time spent by `op` on `res` at `rate`
    events/s (utilization; >1 means the stage cannot keep up)."""
    t_compute = op.flops_per_event * rate / res.total_flops
    t_memory = op.bytes_per_event * rate / (res.mem_bw * res.chips)
    return max(t_compute, t_memory)


def transfer_time(bytes_per_event: float, rate: float, res: Resource) -> float:
    return bytes_per_event * rate / res.net_bw


def op_placement_terms(op: OperatorCost, res: Resource, rate: float
                       ) -> Tuple[float, float, float]:
    """The per-(op, pool) scalars every placement evaluation accumulates:
    ``(utilization, node compute latency, energy watts)``. Shared by
    :func:`evaluate_graph_plan` and the placement DP
    (:func:`repro.core.placement.place_frontier_dp`) so the two paths
    price an op on a pool with bit-identical arithmetic — the DP's
    incremental bookkeeping must reproduce the evaluator's numbers, not
    merely approximate them."""
    u = stage_time(op, res, rate)
    return u, op.flops_per_event / res.total_flops, u * res.energy_w * res.chips


@dataclass
class PipelinePlan:
    """Assignment of each stage to a resource + derived metrics."""
    assignment: Dict[str, str]            # op name -> pool name
    utilization: Dict[str, float] = field(default_factory=dict)
    latency_s: float = 0.0
    uplink_utilization: float = 0.0       # bottleneck link utilization
    link_utilization: Dict[Tuple[str, str], float] = field(
        default_factory=dict)             # per directed link
    energy_w: float = 0.0
    feasible: bool = True
    notes: List[str] = field(default_factory=list)
    # the blanket uplink codec this plan was priced under when the codec
    # is part of the plan search (placement.frontier_plans codecs=...);
    # None -> whatever the ClusterSpec's links declare
    uplink_codec: Optional[str] = None


def evaluate_plan(ops: List[OperatorCost], assign: Dict[str, str],
                  resources: ResourcesLike, rate: float,
                  source: Optional[str] = None) -> PipelinePlan:
    """Evaluate a linear pipeline: stage order = list order; data crosses
    the network wherever consecutive stages sit on different pools, priced
    on the connecting :class:`Link` (codec-compressed bytes, link latency).

    ``source`` names the pool the stream *originates* at — by default the
    spec's first edge pool (S2CE ingests at the edge gateway), so an
    all-cloud plan pays the raw-event uplink instead of getting it for
    free. Without this charge every placement degenerates to all-cloud
    and the cut never moves. Pass ``source=""`` to disable (data already
    at rest in the cloud).
    """
    spec = ClusterSpec.of(resources)
    if source is None:
        source = spec.default_source()
    plan = PipelinePlan(dict(assign))
    latency = 0.0
    energy = 0.0
    link_bytes: Dict[Tuple[str, str], float] = {}
    per_res_util: Dict[str, float] = {r: 0.0 for r in spec}
    prev = source if source else None
    in_bytes = ops[0].bytes_per_event if ops else 0.0
    for op in ops:
        rname = assign[op.name]
        res = spec.pools[rname]
        if not op.edge_capable and res.kind == "edge":
            plan.feasible = False
            plan.notes.append(f"{op.name} not edge-capable")
        u = stage_time(op, res, rate)
        per_res_util[rname] = per_res_util.get(rname, 0.0) + u
        latency += op.flops_per_event / res.total_flops
        energy += u * res.energy_w * res.chips
        if prev is not None and prev != rname:
            ln = spec.link(prev, rname)
            wire = ln.wire_bytes(in_bytes)
            link_bytes[(prev, rname)] = (link_bytes.get((prev, rname), 0.0)
                                         + wire)
            latency += ln.latency
            energy += wire * rate * ln.energy_per_byte
        in_bytes = op.out_bytes_per_event
        prev = rname
        if op.state_bytes > res.mem_cap * res.chips:
            plan.feasible = False
            plan.notes.append(f"{op.name} state exceeds {rname} memory")
    plan.utilization = per_res_util
    plan.latency_s = latency
    plan.link_utilization = {
        key: b * rate / spec.link(*key).bw for key, b in link_bytes.items()}
    plan.uplink_utilization = (max(plan.link_utilization.values())
                               if plan.link_utilization else 0.0)
    plan.energy_w = energy
    return _finalize_capacity(plan)


def _finalize_capacity(plan: PipelinePlan) -> PipelinePlan:
    for r, u in plan.utilization.items():
        if u > 1.0:
            plan.feasible = False
            plan.notes.append(f"{r} over capacity ({u:.2f})")
    for (src, dst), u in plan.link_utilization.items():
        if u > 1.0:
            plan.feasible = False
            plan.notes.append(f"link {src}->{dst} over capacity ({u:.2f})")
    return plan


@dataclass(frozen=True)
class MigrationCost:
    """The one-shot price of moving resident op state at replan time."""
    seconds: float = 0.0
    bytes: float = 0.0
    moves: Tuple[Tuple[str, str, str], ...] = ()   # (op, src pool, dst pool)


def migration_cost(ops: List[OperatorCost],
                   old_assign: Mapping, new_assign: Mapping,
                   resources: ResourcesLike) -> MigrationCost:
    """Price the state transfer a plan change implies: every op whose pool
    changed ships its ``state_bytes`` over the old->new :class:`Link`
    (plus one link-latency hop per moved op). State moves *raw* — learner
    params/opt-state and KV caches must arrive bit-exact, so the link's
    lossy stream codec does not apply to migration traffic.

    Ops present in only one of the two assignments (a job being admitted
    or drained) move no state. A move *off a pool that has already left
    the spec* (crash/deregistration replans) is recorded but priced at
    zero wire cost: there is nothing left to ship — the op restarts from
    checkpoint at the destination. The offload controller attaches this
    to every repartition decision so a migration's amortization against
    the steady-state win is visible, not implicit."""
    spec = ClusterSpec.of(resources)
    seconds = 0.0
    total = 0.0
    moves: List[Tuple[str, str, str]] = []
    for op in ops:
        src = old_assign.get(op.name)
        dst = new_assign.get(op.name)
        if src is None or dst is None or src == dst:
            continue
        moves.append((op.name, src, dst))
        if src not in spec.pools or dst not in spec.pools:
            continue
        ln = spec.link(src, dst)
        total += op.state_bytes
        seconds += op.state_bytes / ln.bw + ln.latency
    return MigrationCost(seconds, total, tuple(moves))


def evaluate_graph_plan(ops: List[OperatorCost],
                        edges: Sequence[Tuple[str, str]],
                        assign: Dict[str, str],
                        resources: ResourcesLike, rate: float,
                        source: Optional[str] = None,
                        source_consumers: Sequence[str] = (),
                        source_bytes: Optional[float] = None
                        ) -> PipelinePlan:
    """Evaluate an operator *DAG* over a :class:`ClusterSpec`: ``edges``
    are the dataflow edges ``(producer, consumer)``, given with ``ops``
    in topological list order; bytes cross the network on every edge
    whose endpoints sit on different pools, priced at the producer's
    ``out_bytes_per_event`` compressed by the crossing :class:`Link`'s
    codec — per crossing edge, not at one cut point. A producer feeding
    several consumers on the same remote pool ships its output once per
    link (multicast), so crossings are grouped by ``(producer, remote
    pool)``. Link *bandwidth* feasibility is tracked per directed link
    (``link_utilization``; ``uplink_utilization`` reports the bottleneck
    link).

    Plan latency is the **critical path** of the op DAG: each op node
    weighs its compute latency, each crossing edge adds the latency of
    the link it rides, and the plan's latency is the longest source-to-
    sink path. Parallel branches therefore overlap instead of summing —
    and a linear chain (one path) reproduces the historical per-op-sum
    price exactly, which keeps chain plans parity-identical to
    :func:`evaluate_plan`.

    ``source`` names the pool the stream originates at (default: the
    spec's first edge pool); ``source_consumers`` are the ops that read
    raw-stream channels no op produces, and the raw event
    (``source_bytes``) is shipped once to every remote pool one of them
    sits on — an all-cloud plan pays the raw-event uplink.

    Backhaul is not a supported data path: a flow edge from a cloud pool
    down to an edge pool (routing a high-rate stream back over the
    constrained link so a *slower* node can consume it) marks the plan
    infeasible — unless the consumer declares
    ``OperatorCost.downlink_ok``, in which case the crossing is a
    legitimate *downlink* (cloud-prefill/edge-decode serving) and is
    priced like any other hop. The edge-resident set of any feasible
    assignment is therefore downward-closed under the graph's *closure*
    relation (flow parents of downlink-ok consumers excluded), which is
    what makes the frontier search (over frontiers x within-kind pool
    choices) provably complete against the exhaustive oracle.
    """
    spec = ClusterSpec.of(resources)
    if source is None:
        source = spec.default_source()
    by_name = {op.name: op for op in ops}
    plan = PipelinePlan(dict(assign))
    energy = 0.0
    per_res_util: Dict[str, float] = {r: 0.0 for r in spec}
    node_lat: Dict[str, float] = {}
    for op in ops:
        rname = assign[op.name]
        res = spec.pools[rname]
        if not op.edge_capable and res.kind == "edge":
            plan.feasible = False
            plan.notes.append(f"{op.name} not edge-capable")
        u, lat, e = op_placement_terms(op, res, rate)
        per_res_util[rname] = per_res_util.get(rname, 0.0) + u
        node_lat[op.name] = lat
        energy += e
        if op.state_bytes > res.mem_cap * res.chips:
            plan.feasible = False
            plan.notes.append(f"{op.name} state exceeds {rname} memory")
    # -- network: bytes per crossing (grouped per (producer, remote pool)
    # for multicast), bandwidth per directed link, codec-compressed ------
    link_bytes: Dict[Tuple[str, str], float] = {}

    def ship(src: str, dst: str, raw_bytes: float):
        nonlocal energy
        ln = spec.link(src, dst)
        wire = ln.wire_bytes(raw_bytes)
        link_bytes[(src, dst)] = link_bytes.get((src, dst), 0.0) + wire
        energy += wire * rate * ln.energy_per_byte

    source_hop: Dict[str, float] = {}    # consumer pool -> entry latency
    if source:
        sb = (source_bytes if source_bytes is not None else
              max((by_name[c].bytes_per_event for c in source_consumers),
                  default=0.0))
        for rname in sorted({assign[c] for c in source_consumers
                             if assign[c] != source}):
            ship(source, rname, sb)
            source_hop[rname] = spec.link(source, rname).latency
    crossings = sorted({(p, assign[c]) for p, c in edges
                        if assign[p] != assign[c]})
    # a cloud->edge flow crossing is backhaul (infeasible) unless the
    # CONSUMER declares downlink_ok — then it is a legitimate downlink
    # (cloud-prefill/edge-decode) and prices like any other hop below
    backhaul = sorted({(p, assign[c]) for p, c in edges
                       if assign[p] != assign[c]
                       and spec.pools[assign[p]].kind == "cloud"
                       and spec.pools[assign[c]].kind == "edge"
                       and not by_name[c].downlink_ok})
    for p, rname in backhaul:
        plan.feasible = False
        plan.notes.append(f"backhaul {p}->{rname} (cloud->edge) "
                          "not supported")
    for p, rname in crossings:
        ship(assign[p], rname, by_name[p].out_bytes_per_event)
    # -- latency: critical path over (node compute + crossing-link hops).
    # ops is in topological order, so one forward sweep suffices.
    finish: Dict[str, float] = {}
    parents: Dict[str, List[str]] = {}
    for p, c in edges:
        parents.setdefault(c, []).append(p)
    src_consumers = set(source_consumers)
    for op in ops:
        start = 0.0
        if source and op.name in src_consumers:
            start = source_hop.get(assign[op.name], 0.0)
        for p in parents.get(op.name, ()):
            t = finish.get(p, node_lat.get(p, 0.0))
            if assign[p] != assign[op.name]:
                t += spec.link(assign[p], assign[op.name]).latency
            start = max(start, t)
        finish[op.name] = start + node_lat[op.name]
    plan.utilization = per_res_util
    plan.latency_s = max(finish.values()) if finish else 0.0
    plan.link_utilization = {
        key: b * rate / spec.link(*key).bw for key, b in link_bytes.items()}
    plan.uplink_utilization = (max(plan.link_utilization.values())
                               if plan.link_utilization else 0.0)
    plan.energy_w = energy
    return _finalize_capacity(plan)
