"""Analytic three-term cost model shared by the placement optimizer, the
offload controller, and the self-tuner (S2CE O1/O2 "smart resource
management"). The same v5e constants ground the §Roofline report, so
orchestrator decisions and the perf analysis speak one language.

Resources are heterogeneous pools (cloud TPU pods, edge nodes); operators
are stream-pipeline stages with per-event flops/bytes/output-bytes costs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS


@dataclass(frozen=True)
class Resource:
    name: str
    kind: str                  # "cloud" | "edge"
    chips: int = 1
    flops: float = PEAK_FLOPS  # per chip
    mem_bw: float = HBM_BW
    mem_cap: float = 16e9
    net_bw: float = LINK_BW    # to the next hop (edge->cloud uplink for edge)
    net_latency: float = 1e-3  # seconds per hop
    energy_w: float = 200.0    # watts per chip (coarse; drives O2 decisions)

    @property
    def total_flops(self) -> float:
        return self.chips * self.flops


EDGE_NODE = Resource("edge", "edge", chips=1, flops=2e12, mem_bw=50e9,
                     mem_cap=4e9, net_bw=1e9, net_latency=20e-3, energy_w=15.0)
CLOUD_POD = Resource("cloud", "cloud", chips=256, flops=PEAK_FLOPS,
                     mem_bw=HBM_BW, mem_cap=16e9, net_bw=LINK_BW,
                     net_latency=0.2e-3, energy_w=250.0)


@dataclass(frozen=True)
class OperatorCost:
    """Per-event costs of a pipeline stage."""
    name: str
    flops_per_event: float
    bytes_per_event: float          # memory traffic
    out_bytes_per_event: float      # bytes emitted downstream
    state_bytes: float = 0.0        # resident state
    edge_capable: bool = True       # some stages (full DL train) are not


def stage_time(op: OperatorCost, res: Resource, rate: float) -> float:
    """Seconds-per-second of stream time spent by `op` on `res` at `rate`
    events/s (utilization; >1 means the stage cannot keep up)."""
    t_compute = op.flops_per_event * rate / res.total_flops
    t_memory = op.bytes_per_event * rate / (res.mem_bw * res.chips)
    return max(t_compute, t_memory)


def transfer_time(bytes_per_event: float, rate: float, res: Resource) -> float:
    return bytes_per_event * rate / res.net_bw


@dataclass
class PipelinePlan:
    """Assignment of each stage to a resource + derived metrics."""
    assignment: Dict[str, str]            # op name -> resource name
    utilization: Dict[str, float] = field(default_factory=dict)
    latency_s: float = 0.0
    uplink_utilization: float = 0.0
    energy_w: float = 0.0
    feasible: bool = True
    notes: List[str] = field(default_factory=list)


def evaluate_plan(ops: List[OperatorCost], assign: Dict[str, str],
                  resources: Dict[str, Resource], rate: float,
                  source: Optional[str] = None) -> PipelinePlan:
    """Evaluate a linear pipeline: stage order = list order; data crosses
    the uplink wherever consecutive stages sit on different resources.

    ``source`` names the resource the stream *originates* at — by default
    the first edge pool (S2CE ingests at the edge gateway), so an all-cloud
    plan pays the raw-event uplink instead of getting it for free. Without
    this charge every placement degenerates to all-cloud and the cut never
    moves. Pass ``source=""`` to disable (data already at rest in the
    cloud).
    """
    if source is None:
        source = next((r.name for r in resources.values()
                       if r.kind == "edge"), "")
    plan = PipelinePlan(dict(assign))
    latency = 0.0
    energy = 0.0
    uplink = 0.0
    per_res_util: Dict[str, float] = {r: 0.0 for r in resources}
    prev_res = resources[source] if source else None
    in_bytes = ops[0].bytes_per_event if ops else 0.0
    for op in ops:
        res = resources[assign[op.name]]
        if not op.edge_capable and res.kind == "edge":
            plan.feasible = False
            plan.notes.append(f"{op.name} not edge-capable")
        u = stage_time(op, res, rate)
        per_res_util[res.name] = per_res_util.get(res.name, 0.0) + u
        latency += op.flops_per_event / res.total_flops
        energy += u * res.energy_w * res.chips
        if prev_res is not None and prev_res.name != res.name:
            # hop between pools: uplink cost on the slower side
            slow = prev_res if prev_res.net_bw < res.net_bw else res
            uplink += transfer_time(in_bytes, rate, slow)
            latency += slow.net_latency
        in_bytes = op.out_bytes_per_event
        prev_res = res
        if op.state_bytes > res.mem_cap * res.chips:
            plan.feasible = False
            plan.notes.append(f"{op.name} state exceeds {res.name} memory")
    plan.utilization = per_res_util
    plan.latency_s = latency
    plan.uplink_utilization = uplink
    plan.energy_w = energy
    return _finalize_capacity(plan)


def _finalize_capacity(plan: PipelinePlan) -> PipelinePlan:
    for r, u in plan.utilization.items():
        if u > 1.0:
            plan.feasible = False
            plan.notes.append(f"{r} over capacity ({u:.2f})")
    if plan.uplink_utilization > 1.0:
        plan.feasible = False
        plan.notes.append(
            f"uplink over capacity ({plan.uplink_utilization:.2f})")
    return plan


def evaluate_graph_plan(ops: List[OperatorCost],
                        edges: Sequence[Tuple[str, str]],
                        assign: Dict[str, str],
                        resources: Dict[str, Resource], rate: float,
                        source: Optional[str] = None,
                        source_consumers: Sequence[str] = (),
                        source_bytes: Optional[float] = None
                        ) -> PipelinePlan:
    """Evaluate an operator *DAG*: ``edges`` are the dataflow edges
    ``(producer, consumer)``; bytes cross the uplink on every edge whose
    endpoints sit on different resources, priced at the producer's
    ``out_bytes_per_event`` — per crossing edge, not at one cut point. A
    producer feeding several consumers on the same remote resource ships
    its output once per link (multicast), so crossings are grouped by
    ``(producer, remote resource)``; ``net_latency`` is paid once per
    distinct resource link (parallel messages share the hop), which for a
    chain's single cut point is exactly the linear model's one-hop charge.

    ``source`` names the resource the stream originates at (default: the
    first edge pool, as in :func:`evaluate_plan`); ``source_consumers``
    are the ops that read raw-stream channels no op produces, and the raw
    event (``source_bytes``) is shipped once to every remote resource one
    of them sits on — an all-cloud plan pays the raw-event uplink.

    Backhaul is not a supported data path: a flow edge from a cloud pool
    down to an edge pool (routing a high-rate stream back over the
    constrained link so a *slower* node can consume it) marks the plan
    infeasible. Feasible assignments are therefore exactly the
    downward-closed frontier cuts, which is what makes the frontier
    search provably complete against the exhaustive oracle.

    For a chain (edges = consecutive pairs, source consumed by the first
    op) this reproduces :func:`evaluate_plan` exactly on any
    backhaul-free assignment.
    """
    if source is None:
        source = next((r.name for r in resources.values()
                       if r.kind == "edge"), "")
    by_name = {op.name: op for op in ops}
    plan = PipelinePlan(dict(assign))
    latency = 0.0
    energy = 0.0
    uplink = 0.0
    per_res_util: Dict[str, float] = {r: 0.0 for r in resources}
    for op in ops:
        res = resources[assign[op.name]]
        if not op.edge_capable and res.kind == "edge":
            plan.feasible = False
            plan.notes.append(f"{op.name} not edge-capable")
        u = stage_time(op, res, rate)
        per_res_util[res.name] = per_res_util.get(res.name, 0.0) + u
        latency += op.flops_per_event / res.total_flops
        energy += u * res.energy_w * res.chips
        if op.state_bytes > res.mem_cap * res.chips:
            plan.feasible = False
            plan.notes.append(f"{op.name} state exceeds {res.name} memory")
    # Bytes are charged per crossing edge (bandwidth is consumed per
    # message), but net_latency once per distinct resource *link*: all
    # crossings of one uplink ride it in parallel, not serially.
    links = set()
    # the raw stream crosses once to every remote pool a source-consuming
    # op was placed on
    if source:
        sb = (source_bytes if source_bytes is not None else
              max((by_name[c].bytes_per_event for c in source_consumers),
                  default=0.0))
        src = resources[source]
        for rname in sorted({assign[c] for c in source_consumers
                             if assign[c] != source}):
            res = resources[rname]
            slow = src if src.net_bw < res.net_bw else res
            uplink += transfer_time(sb, rate, slow)
            links.add(frozenset((source, rname)))
    # each crossing edge ships the producer's output on the slower side
    crossings = sorted({(p, assign[c]) for p, c in edges
                        if assign[p] != assign[c]})
    for p, rname in crossings:
        rp, rc = resources[assign[p]], resources[rname]
        if rp.kind == "cloud" and rc.kind == "edge":
            plan.feasible = False
            plan.notes.append(f"backhaul {p}->{rname} (cloud->edge) "
                              "not supported")
        slow = rp if rp.net_bw < rc.net_bw else rc
        uplink += transfer_time(by_name[p].out_bytes_per_event, rate, slow)
        links.add(frozenset((rp.name, rname)))
    for link in links:
        slow = min((resources[r] for r in link), key=lambda r: r.net_bw)
        latency += slow.net_latency
    plan.utilization = per_res_util
    plan.latency_s = latency
    plan.uplink_utilization = uplink
    plan.energy_w = energy
    return _finalize_capacity(plan)
