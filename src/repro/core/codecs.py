"""Uplink codecs: the pluggable compression protocol attached to
:class:`~repro.core.costmodel.Link` objects in a ``ClusterSpec``.

A :class:`UplinkCodec` bundles the three views one compression scheme
needs across the stack:

  * **pricing** — ``ratio`` (wire bytes as a fraction of the raw fp32
    payload) lets :func:`~repro.core.costmodel.evaluate_graph_plan`
    charge codec-compressed bytes on every crossing link;
  * **admission** — ``error_bound`` is the codec's accumulated relative
    error bound (the telescoping error-feedback residual, normalized by
    the stream's peak magnitude). :func:`repro.core.sla.pick_codec`
    admits a codec only when this bound fits the SLA error budget; the
    bounds are property-tested in ``tests/test_cluster.py`` against the
    same EF round-trip identities ``tests/test_dist.py`` checks for the
    raw primitives;
  * **execution** — ``roundtrip(residual, x) -> (decoded, residual)`` is
    the wire transform with error-feedback carry the orchestrator applies
    to batch tensors crossing the edge->cloud boundary.

All codecs are built from the existing :mod:`repro.dist.compression`
primitives; ``topk_int8_ef`` is the composed scheme (sparsify first,
then int8-quantize the survivors) sharing ONE residual so the
telescoping identity ``sum(decoded) + residual == sum(true)`` holds for
the composition exactly as it does for each half.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist.compression import (dequantize_int8, ef_init, ef_roundtrip,
                                    ef_topk_roundtrip, quantize_int8,
                                    topk_densify, topk_sparsify)
from repro.kernels import ops as kops

# one int8 quantum, relative to the tensor's peak magnitude: the EF carry
# keeps accumulated error under ~2 quanta (see ef_roundtrip's bounded-
# error test), so the admission bound is 2/127.
_INT8_QUANTUM = 1.0 / 127.0

Roundtrip = Callable[[jax.Array, jax.Array], Tuple[jax.Array, jax.Array]]


@dataclass(frozen=True)
class UplinkCodec:
    """One uplink compression scheme: pricing ratio, tested accumulated
    error bound, and the error-feedback wire transform.

    ``error_bound`` is relative: after any number of round-trips over a
    stream of tensors, ``max|cum(decoded) - cum(true)| <= error_bound *
    max|x|`` (by the telescoping EF identity the accumulated error IS the
    carried residual, so this is a bound on the residual magnitude).
    """
    name: str
    ratio: float                   # wire bytes / raw fp32 payload bytes
    error_bound: float             # accumulated relative error (tested)
    roundtrip: Roundtrip = field(repr=False, compare=False,
                                 default=lambda r, x: (x, r))

    @property
    def lossless(self) -> bool:
        return self.error_bound == 0.0

    def wire_bytes(self, raw_bytes: float) -> float:
        """Bytes that actually cross the link for a raw fp32 payload."""
        return raw_bytes * self.ratio

    def init_residual(self, x: jax.Array) -> jax.Array:
        return ef_init(x)


def _identity_roundtrip(residual, x):
    return x, residual


def _topk_int8_roundtrip(residual, x, k_frac: float):
    """Composed sparsify-then-quantize wire round-trip with ONE shared
    error-feedback residual: the dropped coordinates AND the quantization
    error of the survivors are both carried to the next round.

    Where Pallas runs, the mask/amax/quantize/carry chain is one fused
    ``kernels.ef_codec`` pass (selection by magnitude threshold —
    identical to exact top-k for tie-free inputs, and the EF telescoping
    identity holds for any selection, so ``error_bound`` is unchanged)."""
    size = int(jnp.size(x))
    k = max(1, int(round(k_frac * size)))
    if kops.pallas_available():
        return kops.ef_topk_int8_roundtrip(residual, x, k=k)
    xc = x.astype(jnp.float32) + residual
    v, i = topk_sparsify(xc, k)
    vq = dequantize_int8(*quantize_int8(v))      # int8 the survivors
    dec = topk_densify(vq, i, jnp.shape(xc))
    return dec.astype(x.dtype), xc - dec


def identity_codec() -> UplinkCodec:
    """Lossless pass-through (the default on every link)."""
    return UplinkCodec("identity", ratio=1.0, error_bound=0.0,
                       roundtrip=_identity_roundtrip)


def int8_ef_codec() -> UplinkCodec:
    """Symmetric per-tensor int8 with error feedback: 4x fewer bytes,
    accumulated error bounded by ~2 quanta of the peak magnitude."""
    return UplinkCodec("int8_ef", ratio=0.25,
                       error_bound=2.0 * _INT8_QUANTUM,
                       roundtrip=ef_roundtrip)


def _parameterized_name(base: str, k_frac: float) -> str:
    """Codec names must be bijective with behavior: Link stores only the
    name, so a non-default ``k_frac`` gets its own registry entry (e.g.
    ``topk_ef_k0.25``) and pricing resolves the codec that actually
    runs, not the default-parameter one."""
    return base if k_frac == 0.1 else f"{base}_k{k_frac:g}"


def topk_ef_codec(k_frac: float = 0.1) -> UplinkCodec:
    """Top-k sparsification with error feedback: ship ``(value fp32,
    index int32)`` pairs for the ``k_frac`` largest coordinates (8 bytes
    each vs 4 per dense fp32 -> ratio ``2*k_frac``). The EF carry bounds
    the accumulated error by one round-robin sweep of dropped mass:
    ``(1/k_frac) * max|x|`` (the ``ef_topk_roundtrip`` tested bound)."""
    if not 0.0 < k_frac <= 1.0:
        raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")

    def rt(residual, x):
        k = max(1, int(round(k_frac * int(jnp.size(x)))))
        return ef_topk_roundtrip(residual, x, k)

    return _register(UplinkCodec(
        _parameterized_name("topk_ef", k_frac), ratio=2.0 * k_frac,
        error_bound=1.0 / k_frac, roundtrip=rt))


def topk_int8_ef_codec(k_frac: float = 0.1) -> UplinkCodec:
    """The composed codec: top-k sparsify, then int8-quantize the
    surviving values (1-byte value + 4-byte index per kept coordinate ->
    ratio ``1.25*k_frac``; a third of ``int8_ef`` at k=10%). One shared
    residual carries both error sources, so the bounds add:
    ``1/k_frac + 2/127`` (property-tested under composition)."""
    if not 0.0 < k_frac <= 1.0:
        raise ValueError(f"k_frac must be in (0, 1], got {k_frac}")

    def rt(residual, x):
        return _topk_int8_roundtrip(residual, x, k_frac)

    return _register(UplinkCodec(
        _parameterized_name("topk_int8_ef", k_frac), ratio=1.25 * k_frac,
        error_bound=1.0 / k_frac + 2.0 * _INT8_QUANTUM, roundtrip=rt))


# -- KV-cache codecs ---------------------------------------------------------
# Serving ships *state* over the wire (the prefill op's KV cache riding
# the cloud->edge downlink), not an accumulating gradient stream: every
# wave's payload is fresh, so these codecs carry NO error feedback — the
# residual passes through untouched (zeros) and the per-payload bound IS
# the accumulated bound. They are registered but deliberately NOT in
# DEFAULT_CODECS: gradient jobs keep their EF ladder; serving jobs pass
# the KV ladder explicitly (StreamJob.uplink_codecs / KV_CODECS).

def _kv_int8_roundtrip(residual, x):
    dec = dequantize_int8(*quantize_int8(x))
    return dec.astype(x.dtype), residual


def kv_int8_codec() -> UplinkCodec:
    """Symmetric per-tensor int8 over attention state (KV cache), no
    error feedback: each shipped cache decodes within one int8 quantum
    of its peak magnitude (``1/127``), independently per wave."""
    return _register(UplinkCodec("kv_int8", ratio=0.25,
                                 error_bound=_INT8_QUANTUM,
                                 roundtrip=_kv_int8_roundtrip))


# fixed seeded orthonormal bases for the latent projection, cached per
# (feature dim, latent dim) — both wire endpoints derive the identical
# basis from the seed, so only the int8 latent crosses the link
_KV_BASES: Dict[Tuple[int, int], jax.Array] = {}


def _latent_basis(d: int, r: int) -> jax.Array:
    key = (d, r)
    basis = _KV_BASES.get(key)
    if basis is None:
        import numpy as np
        rng = np.random.default_rng(20260809 + 1000 * d + r)
        q, _ = np.linalg.qr(rng.standard_normal((d, r)))
        basis = jnp.asarray(q, dtype=jnp.float32)
        _KV_BASES[key] = basis
    return basis


def _kv_latent_roundtrip(residual, x, r_frac: float):
    """Project the head/feature (last) axis onto a fixed seeded
    orthonormal ``r = r_frac * D`` basis (the MLA-style latent view of
    attention state), int8 the latent, and reconstruct."""
    d = int(x.shape[-1]) if jnp.ndim(x) else 1
    r = max(1, int(round(r_frac * d)))
    if jnp.ndim(x) == 0 or r >= d:
        dec = dequantize_int8(*quantize_int8(x))
        return dec.astype(x.dtype), residual
    basis = _latent_basis(d, r)
    z = x.astype(jnp.float32) @ basis
    zq = dequantize_int8(*quantize_int8(z))
    dec = zq @ basis.T
    return dec.astype(x.dtype), residual


def kv_latent_codec(r_frac: float = 0.5) -> UplinkCodec:
    """Latent-projected int8 KV compression: rank ``r_frac * D`` down the
    feature axis, then int8 the latent — ``0.25 * r_frac`` of the raw
    bytes. The declared bound is *distributional*: for approximately
    isotropic attention state a random rank-r orthonormal projection
    keeps ``r/D`` of the energy in expectation, so the relative RMS
    reconstruction error concentrates near ``sqrt(1 - r_frac)`` (plus
    one int8 quantum on the latent); property-tested with margin on
    Gaussian and real zoo KV tensors. Adversarial inputs concentrated in
    the discarded subspace can exceed it — a serving budget admitting
    this codec accepts that distributional (not worst-case) contract."""
    if not 0.0 < r_frac <= 1.0:
        raise ValueError(f"r_frac must be in (0, 1], got {r_frac}")

    def rt(residual, x):
        return _kv_latent_roundtrip(residual, x, r_frac)

    name = "kv_latent" if r_frac == 0.5 else f"kv_latent_r{r_frac:g}"
    return _register(UplinkCodec(
        name, ratio=0.25 * r_frac,
        error_bound=(1.0 - r_frac) ** 0.5 + _INT8_QUANTUM, roundtrip=rt))


# The registry Link codec names resolve through. Constructors register
# their instances (parameterized variants under k_frac-qualified names),
# so pricing always resolves the codec whose roundtrip actually runs.
_REGISTRY: Dict[str, UplinkCodec] = {}


def _register(codec: UplinkCodec) -> UplinkCodec:
    return _REGISTRY.setdefault(codec.name, codec)


# The candidate set sla.pick_codec chooses from. Ordered loosely by
# fidelity; pick_codec sorts by ratio itself.
DEFAULT_CODECS: Sequence[UplinkCodec] = (
    _register(identity_codec()),
    _register(int8_ef_codec()),
    topk_ef_codec(),
    topk_int8_ef_codec(),
)

# The serving ladder: the candidate set a KV-shipping job hands to
# admission (most faithful -> cheapest wire). Not part of
# DEFAULT_CODECS — gradient-uplink jobs never silently admit a
# distributional-bound codec.
KV_CODECS: Sequence[UplinkCodec] = (
    _REGISTRY["identity"],
    kv_int8_codec(),
    kv_latent_codec(),
)


_PARAM_NAME = re.compile(r"^(topk_ef|topk_int8_ef)_k([0-9.eE+-]+)$")
_PARAM_CTORS = {"topk_ef": topk_ef_codec, "topk_int8_ef": topk_int8_ef_codec}
_KV_PARAM_NAME = re.compile(r"^kv_latent_r([0-9.eE+-]+)$")


def get_codec(name: str) -> UplinkCodec:
    """Resolve a codec by its registry name (as stored on a Link).

    Parameterized names following the ``_parameterized_name`` scheme
    (``topk_ef_k0.25``) are constructed on demand, so a name arriving
    from config/serialization resolves without the matching constructor
    having run in this process."""
    codec = _REGISTRY.get(name)
    if codec is not None:
        return codec
    m = _PARAM_NAME.match(name)
    if m is not None:
        try:
            return _PARAM_CTORS[m.group(1)](float(m.group(2)))
        except ValueError as e:
            raise KeyError(f"bad uplink codec name {name!r}: {e}") from None
    m = _KV_PARAM_NAME.match(name)
    if m is not None:
        try:
            return kv_latent_codec(float(m.group(1)))
        except ValueError as e:
            raise KeyError(f"bad uplink codec name {name!r}: {e}") from None
    raise KeyError(f"unknown uplink codec {name!r}; known: "
                   f"{sorted(_REGISTRY)} (or a parameterized "
                   f"'topk_ef_k<frac>' / 'topk_int8_ef_k<frac>' / "
                   f"'kv_latent_r<frac>' name)")
