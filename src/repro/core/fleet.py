"""Multi-tenant fleet scheduling: N concurrent stream jobs over ONE
shared :class:`~repro.core.costmodel.ClusterSpec` (S2CE's "many
concurrent ML/DL workloads" promise; the multi-application elasticity
problem of the resource-elasticity survey, arxiv 1709.01363, and ECHO's
adaptive multi-dataflow orchestration, arxiv 1707.00889).

Three layers:

* :class:`FleetLedger` — per-tenant reservations against the shared
  topology. Each admitted tenant holds a fraction of every pool it uses
  and bytes/s on every link it crosses, all expressed against the
  ORIGINAL capacities, so the invariant "no link's summed per-tenant
  reserved bytes exceeds its capacity" (and likewise pool fractions
  vs. 1.0) is checkable by direct summation. The ledger derives the
  **residual** :class:`ClusterSpec` a tenant's placement search may
  assume — :meth:`ClusterSpec.residual` shrinks pool rates by the other
  tenants' shares and link bandwidth by their reserved bytes, so
  ``evaluate_graph_plan`` prices the tenant against what is actually
  left, not the whole cluster.

* :class:`FleetScheduler` — admission control and fleet-batched replan
  arbitration over :class:`~repro.core.offload.OffloadController`
  handles. Admission probes the tenant's best plan (the controller's
  own placement engine, ``place_frontier(method="dp")`` for DAGs) under
  residual capacity and REJECTS (or queues) a tenant whose best plan
  cannot meet its SLA — with a loud reason, never a silent degrade.
  Replans batch globally: each arbitration pass collects every tenant's
  replan trigger (:meth:`OffloadController.wants_replan`), grants them
  in priority order under per-tenant fleet cooldowns, and holds the
  rest — one tenant's codec escalation or migration re-prices ITS
  residual slice without stampeding the others into replans they did
  not ask for.

* :class:`FleetOrchestrator` — steps all admitted jobs round-robin
  (each tenant a real :class:`~repro.core.orchestrator.Orchestrator`
  with its own `SLATracker` window and `JobMetrics`), routing every
  control decision through one arbitration pass per round. Tenants may
  join and leave mid-run; a departure returns its reservations to the
  ledger and immediately re-attempts admission for queued tenants (the
  "within one arbitration pass" contract).

Differential contract (tested): a fleet of ONE tenant prices against a
residual spec with zero foreign load — :meth:`ClusterSpec.residual`
then returns the very same pool/link objects — and the fleet round
drives exactly the standalone run-loop sequence (execute ->
wants_replan/replan-or-hold -> apply -> elastic), so plans, codec
trajectory, and migration history are identical to a standalone
:class:`StreamJob` on the same spec.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.costmodel import ClusterSpec, PipelinePlan
from repro.core.offload import OffloadController, OffloadDecision
from repro.core.orchestrator import JobMetrics, Orchestrator, StreamJob
from repro.core.sla import SLA, SLATracker, plan_violation


@dataclass(frozen=True)
class TenantSpec:
    """What a tenant asks the fleet for."""
    name: str
    priority: int = 1          # tier: LOWER is more important (0 = premium)
    sla: SLA = field(default_factory=SLA)
    demand_rate: float = 1e4   # events/s admission must support
    # fleet-level hysteresis: arbitration passes a granted replan blocks
    # further grants for this tenant. 0 = only the controller's own
    # cooldown/codec_cooldown govern (the single-tenant parity default).
    replan_cooldown: int = 0


@dataclass
class AdmissionResult:
    name: str
    admitted: bool
    reason: str                      # "admitted" or the loud rejection
    queued: bool = False
    decision: Optional[OffloadDecision] = None


@dataclass
class Reservation:
    """One tenant's booked slice, in ORIGINAL-capacity units (fractions
    of each pool, bytes/s of each link, resident state bytes per pool)
    so fleet-wide sums are directly comparable to the spec's capacity."""
    pool_frac: Dict[str, float] = field(default_factory=dict)
    link_bytes: Dict[Tuple[str, str], float] = field(default_factory=dict)
    state_bytes: Dict[str, float] = field(default_factory=dict)


class FleetLedger:
    """Per-tenant capacity bookkeeping over one shared ClusterSpec.

    Reservations are derived from a plan priced on the tenant's residual
    spec: a pool utilization ``u`` of the residual capacity converts to
    ``u * (1 - sum(others))`` of the original pool, and a link
    utilization ``lu`` of the residual bandwidth to ``lu * (orig_bw -
    others_bytes)`` bytes/s — so feasible plans (``u, lu <= 1``) can
    never push a fleet-wide sum past the original capacity (the sums
    telescope). Infeasible plans are clamped at the residual remainder
    and flagged, never silently over-booked.
    """

    def __init__(self, spec) -> None:
        self.spec = ClusterSpec.of(spec)
        self.reservations: Dict[str, Reservation] = {}

    # -- aggregate loads (optionally excluding one tenant) ------------------
    def pool_load(self, exclude: Optional[str] = None) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name, r in self.reservations.items():
            if name == exclude:
                continue
            for pool, f in r.pool_frac.items():
                out[pool] = min(out.get(pool, 0.0) + f, 1.0)
        return out

    def link_load(self, exclude: Optional[str] = None
                  ) -> Dict[Tuple[str, str], float]:
        out: Dict[Tuple[str, str], float] = {}
        for name, r in self.reservations.items():
            if name == exclude:
                continue
            for key, b in r.link_bytes.items():
                out[key] = out.get(key, 0.0) + b
        return out

    def state_load(self, exclude: Optional[str] = None) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for name, r in self.reservations.items():
            if name == exclude:
                continue
            for pool, b in r.state_bytes.items():
                out[pool] = out.get(pool, 0.0) + b
        return out

    def residual_spec(self, exclude: Optional[str] = None) -> ClusterSpec:
        """The spec a tenant's placement may assume: everything minus the
        OTHER tenants' reservations (zero foreign load returns the pool
        and link objects of the base spec unchanged — the single-tenant
        bitwise-parity path)."""
        return self.spec.residual(pool_load=self.pool_load(exclude),
                                  link_load=self.link_load(exclude),
                                  pool_state_bytes=self.state_load(exclude))

    # -- booking ------------------------------------------------------------
    def reserve(self, tenant: str, plan: PipelinePlan,
                state_bytes: Optional[Mapping[str, float]] = None
                ) -> Reservation:
        """Book ``tenant``'s slice from a plan priced on its residual
        spec (replacing any prior booking). Returns the reservation; an
        infeasible plan books the clamped residual remainder and the
        clamp is recorded in the reservation maps by construction."""
        others_pool = self.pool_load(exclude=tenant)
        others_link = self.link_load(exclude=tenant)
        pool_frac = {}
        for pool, u in plan.utilization.items():
            if u <= 0.0:
                continue
            share = max(1.0 - others_pool.get(pool, 0.0), 0.0)
            pool_frac[pool] = min(u, 1.0) * share
        link_bytes = {}
        for key, lu in plan.link_utilization.items():
            if lu <= 0.0:
                continue
            orig = self.spec.link(*key).bw
            resid = max(orig - others_link.get(key, 0.0), 0.0)
            link_bytes[key] = min(lu, 1.0) * resid
        res = Reservation(pool_frac, link_bytes,
                          {p: float(b) for p, b in (state_bytes or {}).items()
                           if b > 0.0})
        self.reservations[tenant] = res
        return res

    def release(self, tenant: str) -> Optional[Reservation]:
        return self.reservations.pop(tenant, None)

    # -- membership churn ----------------------------------------------------
    def set_spec(self, spec) -> None:
        """Re-point the ledger at a new topology generation (a join or a
        probe-driven latency refresh). Every existing reservation must
        still reference known pools — a DEPARTURE must go through
        :meth:`drop_pool`, which scrubs the dead pool's bookings."""
        new = ClusterSpec.of(spec)
        for name, r in self.reservations.items():
            missing = sorted(
                (set(r.pool_frac) | set(r.state_bytes)
                 | {p for key in r.link_bytes for p in key})
                - set(new.pools))
            if missing:
                raise ValueError(
                    f"set_spec: tenant {name!r} still books on pool(s) "
                    f"{missing} absent from the new spec; scrub "
                    "departures through drop_pool")
        self.spec = new

    def drop_pool(self, pool: str, spec=None) -> List[str]:
        """A pool left or failed: scrub every reservation's bookings on
        it (pool fraction, resident state, link bytes on any link
        touching it) and re-point at the survivor spec (derived via
        :meth:`ClusterSpec.without_pool` unless given). Returns the
        tenants whose bookings were touched — exactly the set whose
        plans the scheduler must re-probe."""
        new = ClusterSpec.of(spec) if spec is not None else \
            self.spec.without_pool(pool)
        if pool in new.pools:
            raise ValueError(
                f"drop_pool: new spec still contains pool {pool!r}")
        touched = []
        for name, r in self.reservations.items():
            hit = r.pool_frac.pop(pool, None) is not None
            hit = (r.state_bytes.pop(pool, None) is not None) or hit
            for key in [k for k in r.link_bytes if pool in k]:
                r.link_bytes.pop(key)
                hit = True
            if hit:
                touched.append(name)
        self.spec = new
        return touched

    # -- invariants (property-tested) ---------------------------------------
    def check(self, tol: float = 1e-9) -> List[str]:
        """Capacity-invariant violations across ALL tenants (empty =
        healthy): summed pool fractions vs 1.0 and summed link bytes/s
        vs each link's original bandwidth."""
        bad = []
        for pool, f in self.pool_load().items():
            if f > 1.0 + tol:
                bad.append(f"pool {pool!r} booked {f:.6f} > 1.0")
        for (src, dst), b in self.link_load().items():
            cap = self.spec.link(src, dst).bw
            if b > cap + tol * max(cap, 1.0):
                bad.append(f"link {src}->{dst} booked {b:.6g} B/s "
                           f"> capacity {cap:.6g} B/s")
        return bad


class _Tenant:
    """Internal per-tenant scheduler state."""

    def __init__(self, spec: TenantSpec, controller: OffloadController,
                 tracker: Optional[SLATracker] = None) -> None:
        self.spec = spec
        self.controller = controller
        self.tracker = tracker
        self.last_grant: Optional[int] = None


class FleetScheduler:
    """Admission control + fleet-batched replan arbitration.

    Works on bare :class:`OffloadController` handles so it can be
    driven without executing pipelines (property tests, capacity
    planning); :class:`FleetOrchestrator` wires it to real running
    jobs. ``log`` carries the loud audit trail (admissions, rejections,
    grants, cooldown holds, clamped over-capacity replans)."""

    def __init__(self, spec) -> None:
        self.ledger = FleetLedger(spec)
        self.tenants: Dict[str, _Tenant] = {}
        # rejected-but-queued tenants, FIFO within priority
        self.queue: List[_Tenant] = []
        self.log: List[str] = []

    @property
    def admitted(self) -> List[str]:
        return list(self.tenants)

    @property
    def queued(self) -> List[str]:
        return [t.spec.name for t in self.queue]

    def _state_bytes(self, t: _Tenant, plan: PipelinePlan
                     ) -> Dict[str, float]:
        by_name = {op.name: op.state_bytes for op in t.controller.ops}
        out: Dict[str, float] = {}
        for op, pool in plan.assignment.items():
            out[pool] = out.get(pool, 0.0) + by_name.get(op, 0.0)
        return out

    def _try_admit(self, t: _Tenant) -> AdmissionResult:
        spec = t.spec
        residual = self.ledger.residual_spec()
        t.controller.set_resources(residual)
        plan, _ = t.controller.probe_plan(spec.demand_rate)
        why = plan_violation(plan, spec.sla)
        if why is not None:
            reason = (f"tenant {spec.name!r} cannot be admitted at "
                      f"demand_rate={spec.demand_rate:g} ev/s: {why}")
            return AdmissionResult(spec.name, False, reason)
        d = t.controller.initial_plan(spec.demand_rate)
        self.ledger.reserve(spec.name, d.plan,
                            self._state_bytes(t, d.plan))
        self.tenants[spec.name] = t
        self.log.append(f"admit {spec.name} (tier {spec.priority}, "
                        f"rate {spec.demand_rate:g})")
        return AdmissionResult(spec.name, True, "admitted", decision=d)

    def submit(self, spec: TenantSpec, controller: OffloadController,
               tracker: Optional[SLATracker] = None,
               queue: bool = True) -> AdmissionResult:
        """Admission-control a tenant. On rejection the tenant is queued
        (unless ``queue=False``) and re-considered whenever capacity
        returns (:meth:`leave`)."""
        if spec.name in self.tenants or spec.name in self.queued:
            raise ValueError(f"tenant {spec.name!r} already submitted")
        t = _Tenant(spec, controller, tracker)
        res = self._try_admit(t)
        if not res.admitted:
            self.log.append(res.reason + ("; queued" if queue else ""))
            if queue:
                self.queue.append(t)
                res.queued = True
        return res

    def drain_queue(self) -> List[AdmissionResult]:
        """Re-attempt admission for queued tenants in priority order
        (FIFO within a tier). Runs inside :meth:`leave` so a departure
        re-admits waiting tenants within the same arbitration pass."""
        admitted: List[AdmissionResult] = []
        remaining: List[_Tenant] = []
        for t in sorted(self.queue, key=lambda t: t.spec.priority):
            res = self._try_admit(t)
            if res.admitted:
                admitted.append(res)
            else:
                remaining.append(t)
        # preserve original FIFO order among the still-queued
        self.queue = [t for t in self.queue if t in remaining]
        return admitted

    def leave(self, name: str) -> List[AdmissionResult]:
        """A tenant departs: release its reservations and immediately
        re-attempt admission for the queue. Returns the re-admissions."""
        t = self.tenants.pop(name, None)
        if t is None:
            # allow cancelling a queued tenant too
            self.queue = [q for q in self.queue if q.spec.name != name]
            return []
        self.ledger.release(name)
        self.log.append(f"leave {name}")
        return self.drain_queue()

    # -- membership churn ----------------------------------------------------
    def pool_joined(self, spec) -> List[AdmissionResult]:
        """Capacity joined the fleet: re-point the shared ledger at the
        new topology and immediately re-attempt admission for the queue
        (priority order, FIFO within a tier — the same contract as a
        departure's re-admission pass)."""
        self.ledger.set_spec(spec)
        self.log.append(
            f"topology: capacity joined (spec v{self.ledger.spec.version})"
            "; re-draining queue")
        return self.drain_queue()

    def pool_lost(self, pool: str, spec, step: int,
                  offered: Optional[Mapping[str, float]] = None
                  ) -> Dict[str, OffloadDecision]:
        """A pool left or failed: scrub its ledger bookings, then force
        a replan for every admitted tenant whose EXECUTING plan touched
        it — in priority order, each re-priced against its residual
        slice of the survivor spec and re-booked. Unaffected tenants
        keep their plans and reservations untouched (their controllers
        pick up the survivor spec at their next granted replan).
        Returns the forced decisions, keyed by tenant."""
        offered = dict(offered or {})
        self.ledger.drop_pool(pool, spec)
        affected = sorted(
            (t.spec.priority, i, name)
            for i, (name, t) in enumerate(self.tenants.items())
            if pool in set(t.controller.assignment.values()))
        decisions: Dict[str, OffloadDecision] = {}
        for _, _, name in affected:
            t = self.tenants[name]
            rate = float(offered.get(name, t.spec.demand_rate))
            self.ledger.release(name)
            t.controller.set_resources(self.ledger.residual_spec())
            d = t.controller.replan(step, rate, t.tracker,
                                    reason="pool_lost")
            self.ledger.reserve(name, d.plan,
                                self._state_bytes(t, d.plan))
            t.last_grant = step
            decisions[name] = d
            note = "" if d.plan.feasible else \
                " [OVER CAPACITY: booked clamped residual remainder]"
            self.log.append(
                f"{step}: pool {pool!r} lost -> forced replan {name} "
                f"codec={d.codec} cut={d.cut}{note}")
        if not affected:
            self.log.append(
                f"{step}: pool {pool!r} lost; no admitted plan touched it")
        return decisions

    def arbitrate(self, step: int, offered: Mapping[str, float]
                  ) -> Dict[str, OffloadDecision]:
        """ONE fleet-batched control pass: collect every admitted
        tenant's replan trigger, grant the triggered ones in priority
        order (each re-priced against its residual spec, its reservation
        re-booked), hold everyone else. Per-tenant ``replan_cooldown``
        blocks back-to-back grants; an over-capacity replan books the
        clamped remainder and is logged loudly. Returns a decision per
        admitted tenant — exactly what ``controller.observe`` would have
        produced, but synchronized fleet-wide."""
        decisions: Dict[str, OffloadDecision] = {}
        wants: List[Tuple[int, int, str, str, float]] = []
        for i, (name, t) in enumerate(self.tenants.items()):
            rate = float(offered.get(name, t.spec.demand_rate))
            reason = t.controller.wants_replan(step, rate, t.tracker)
            if reason is None:
                decisions[name] = t.controller.hold_decision(step, rate)
            elif (t.spec.replan_cooldown > 0 and t.last_grant is not None
                  and step - t.last_grant < t.spec.replan_cooldown):
                decisions[name] = t.controller.hold_decision(step, rate)
                self.log.append(
                    f"{step}: {name} wants replan ({reason}) but fleet "
                    f"cooldown holds until "
                    f"{t.last_grant + t.spec.replan_cooldown}")
            else:
                wants.append((t.spec.priority, i, name, reason, rate))
        # priority tiers first (lower tier number wins), admission order
        # within a tier — deterministic, no stampede: each grant re-prices
        # only ITS tenant; the others keep their plans and reservations
        for _, _, name, reason, rate in sorted(wants):
            t = self.tenants[name]
            self.ledger.release(name)
            t.controller.set_resources(self.ledger.residual_spec())
            d = t.controller.replan(step, rate, t.tracker, reason)
            self.ledger.reserve(name, d.plan,
                                self._state_bytes(t, d.plan))
            t.last_grant = step
            decisions[name] = d
            note = "" if d.plan.feasible else \
                " [OVER CAPACITY: booked clamped residual remainder]"
            self.log.append(f"{step}: grant {name} replan ({reason}) "
                            f"codec={d.codec} cut={d.cut}{note}")
        return decisions


class FleetOrchestrator:
    """Round-robin execution of admitted tenant jobs over one shared
    cluster, with fleet-arbitrated control.

    Per round each tenant executes one batch through its own
    :class:`Orchestrator` (own pipeline state, `SLATracker` window,
    `JobMetrics`), then ONE :meth:`FleetScheduler.arbitrate` pass
    produces every tenant's decision, which is applied alongside the
    tenant's elastic sizing step — the standalone run-loop order, fleet
    synchronized."""

    def __init__(self, cluster=None, membership=None) -> None:
        if (cluster is None) == (membership is None):
            raise ValueError("FleetOrchestrator takes exactly one of "
                             "cluster= (static) or membership= (live "
                             "MembershipDirectory)")
        self.membership = membership
        # the fleet drains topology events CENTRALLY (one subscription,
        # one ledger scrub, one forced-replan pass) — tenant jobs get
        # static spec snapshots, not their own subscriptions
        self._topo_sub = (membership.subscribe()
                          if membership is not None else None)
        self.cluster = ClusterSpec.of(
            membership.spec if membership is not None else cluster)
        self.scheduler = FleetScheduler(self.cluster)
        self.orchestrators: Dict[str, Orchestrator] = {}
        # queued tenants waiting for capacity: name -> (spec, orch, seed)
        self._waiting: Dict[str, Tuple[TenantSpec, Orchestrator, int]] = {}
        self.step = 0

    def add_tenant(self, spec: TenantSpec, job: StreamJob,
                   seed: int = 0) -> AdmissionResult:
        """Admission-control a job into the fleet. The job runs over the
        SHARED cluster (its own ``cluster`` field, if set, must be the
        fleet's). Admitted jobs are armed immediately (the admission
        decision IS the initial plan — taken once, through the job's own
        controller); rejected jobs queue for capacity."""
        if job.membership is not None:
            raise ValueError(
                f"tenant {spec.name!r} job carries its own membership "
                "directory; the fleet drains topology events centrally "
                "— pass membership= to FleetOrchestrator instead")
        if job.cluster is None:
            job = replace(job, cluster=self.cluster, sla=spec.sla)
        elif ClusterSpec.of(job.cluster) is not self.cluster and \
                dict(ClusterSpec.of(job.cluster).pools) != \
                dict(self.cluster.pools):
            raise ValueError(
                f"tenant {spec.name!r} job declares a different cluster "
                "than the fleet's shared spec")
        orch = Orchestrator(job)
        res = self.scheduler.submit(spec, orch.controller, tracker=orch.sla)
        if res.admitted:
            orch.begin(spec.demand_rate, seed=seed, decision=res.decision)
            self.orchestrators[spec.name] = orch
        elif res.queued:
            self._waiting[spec.name] = (spec, orch, seed)
        return res

    def _activate(self, admissions: List[AdmissionResult]) -> None:
        for res in admissions:
            spec, orch, seed = self._waiting.pop(res.name)
            if self.membership is not None:
                # the tenant may have queued under an older topology
                # generation; align it with the spec it was admitted on
                orch.set_cluster(self.cluster)
            orch.begin(spec.demand_rate, seed=seed, decision=res.decision)
            self.orchestrators[spec.name] = orch

    def leave(self, name: str
              ) -> Tuple[Optional[JobMetrics], List[AdmissionResult]]:
        """A tenant departs mid-run: finalize its metrics, return its
        capacity, and activate any queued tenants the freed capacity
        admits — all within this one pass."""
        orch = self.orchestrators.pop(name, None)
        metrics = orch.finish() if orch is not None else None
        admissions = self.scheduler.leave(name)
        self._activate(admissions)
        return metrics, admissions

    def step_round(self, batches: Mapping[str, object],
                   rates: Optional[Mapping[str, float]] = None,
                   record_outputs: bool = False) -> Dict[str, float]:
        """One fleet round: every admitted tenant with a batch executes
        it, then one arbitration pass decides and applies all control.
        ``rates`` optionally overrides the offered rate per tenant (the
        standalone ``rate_fn`` analogue); default is the measured rate.
        Returns the measured rates."""
        step = self.step
        # membership churn first: a dead pool's ledger bookings, plans,
        # and meshes must be scrubbed before any batch executes this
        # round; joined capacity re-admits the queue before it steps
        if self._topo_sub is not None:
            self.membership.tick(step)
            for ev in self._topo_sub.poll():
                self._apply_topology_event(step, ev, rates or {})
        measured: Dict[str, float] = {}
        for name, orch in self.orchestrators.items():
            if name in batches:
                measured[name] = orch.execute_batch(
                    step, batches[name], record_outputs)
        offered = {
            name: float((rates or {}).get(name, measured.get(
                name, self.scheduler.tenants[name].spec.demand_rate)))
            for name in self.orchestrators}
        decisions = self.scheduler.arbitrate(step, offered)
        for name, orch in self.orchestrators.items():
            d = decisions.get(name)
            if d is not None:
                orch.apply_decision(step, d)
            if name in measured:
                orch.elastic_step(step, offered[name], measured[name])
        self.step += 1
        return measured

    def _apply_topology_event(self, step: int, ev,
                              offered: Mapping[str, float]) -> None:
        """React to one membership event fleet-wide: the scheduler
        scrubs the ledger and forces replans (pool loss) or re-drains
        the queue (join); each affected tenant orchestrator rides the
        involuntary checkpoint-rescale path before adopting its forced
        decision; every orchestrator's candidate set moves to the new
        topology generation."""
        from repro.core import membership as ms
        spec_now = self.membership.spec
        self.cluster = spec_now
        if ev.kind in (ms.POOL_FAILED, ms.POOL_LEFT):
            lost = ev.subject
            decisions = self.scheduler.pool_lost(lost, spec_now, step,
                                                 offered)
            for name, orch in self.orchestrators.items():
                d = decisions.get(name)
                orch.metrics.decisions.append(
                    f"{step}:topology {ev.kind} {lost} v{ev.version}"
                    + (" [in plan]" if d is not None else ""))
                if d is not None and \
                        lost in set(orch._exec_assignment.values()):
                    plan = orch.elastic.involuntary(
                        step, reason=f"pool {lost} {ev.kind}")
                    orch._apply_rescale(step, plan)
                orch.set_cluster(spec_now)
                if d is not None:
                    orch.apply_decision(step, d)
        elif ev.kind == ms.POOL_JOINED:
            for orch in self.orchestrators.values():
                orch.metrics.decisions.append(
                    f"{step}:topology pool_joined {ev.subject} "
                    f"v{ev.version}")
                orch.set_cluster(spec_now)
            self._activate(self.scheduler.pool_joined(spec_now))
        elif ev.kind == ms.LINK_UPDATE:
            self.scheduler.ledger.set_spec(spec_now)
            for orch in self.orchestrators.values():
                orch.set_cluster(spec_now)

    def finish(self) -> Dict[str, JobMetrics]:
        """Finalize all still-admitted tenants (does not release their
        reservations — call :meth:`leave` per tenant for churn)."""
        return {name: orch.finish()
                for name, orch in self.orchestrators.items()}
