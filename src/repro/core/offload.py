"""Dynamic cloud<->edge workload shifting (S2CE O2, S3).

A hysteresis controller re-plans operator placement when the observed
event rate leaves the band the current plan was built for, or the SLA
tracker reports violations. Replanning uses the same cost model as static
placement; hysteresis (enter/exit thresholds + cooldown) prevents
thrashing when the rate oscillates around a cut point.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.costmodel import OperatorCost, PipelinePlan, Resource
from repro.core.placement import Objective, place
from repro.core.sla import SLATracker


@dataclass
class OffloadDecision:
    step: int
    rate: float
    cut: int                 # stages[:cut] on edge
    reason: str
    plan: PipelinePlan


@dataclass
class OffloadController:
    ops: List[OperatorCost]
    resources: Dict[str, Resource]
    objective: Objective = field(default_factory=Objective)
    headroom: float = 1.3      # replan when rate moves x1.3 outside band
    cooldown: int = 5          # min decisions between migrations
    planned_rate: float = 0.0
    cut: int = 0
    _last_change: int = -10**9
    history: List[OffloadDecision] = field(default_factory=list)

    def initial_plan(self, rate: float) -> OffloadDecision:
        plan, cut = place(self.ops, self.resources, rate, self.objective)
        self.planned_rate, self.cut = rate, cut
        d = OffloadDecision(0, rate, cut, "initial", plan)
        self.history.append(d)
        return d

    def observe(self, step: int, rate: float,
                sla: Optional[SLATracker] = None) -> OffloadDecision:
        """Called periodically with the measured ingest rate."""
        out_of_band = (rate > self.planned_rate * self.headroom
                       or rate < self.planned_rate / self.headroom)
        sla_bad = sla is not None and not sla.ok()
        if (not out_of_band and not sla_bad) or \
                step - self._last_change < self.cooldown:
            d = OffloadDecision(step, rate, self.cut, "hold",
                                self.history[-1].plan)
            return d
        plan, cut = place(self.ops, self.resources, rate, self.objective)
        reason = "sla" if sla_bad else (
            "rate_up" if rate > self.planned_rate else "rate_down")
        if cut != self.cut:
            self._last_change = step
        self.planned_rate, self.cut = rate, cut
        d = OffloadDecision(step, rate, cut, reason, plan)
        self.history.append(d)
        return d

    def migrations(self) -> int:
        cuts = [d.cut for d in self.history]
        return sum(1 for a, b in zip(cuts, cuts[1:]) if a != b)
