"""Dynamic cloud<->edge workload shifting (S2CE O2, S3).

A hysteresis controller re-plans operator placement when the observed
event rate leaves the band the current plan was built for, or the SLA
tracker reports violations. Replanning uses the same cost model as static
placement; hysteresis (enter/exit thresholds + cooldown) prevents
thrashing when the rate oscillates around a cut point.

Decisions carry the full *assignment* — the ``frontier``: the
downward-closed set of op names resident on the edge — not just a cut
index. For a linear pipeline the frontier is exactly the prefix
``ops[:cut]`` and ``cut`` keeps its old meaning; for an operator DAG the
frontier can hold parallel branches independently and ``cut`` reports its
size. Hysteresis and the migration count key on frontier *identity* (the
plan actually changing where ops run), not on the scalar index.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional

from repro.core.costmodel import OperatorCost, PipelinePlan, Resource
from repro.core.placement import Objective, place, place_frontier
from repro.core.sla import SLATracker


@dataclass
class OffloadDecision:
    step: int
    rate: float
    cut: int                 # edge-resident op count (prefix cut if linear)
    reason: str
    plan: PipelinePlan
    frontier: FrozenSet[str] = frozenset()   # op names on the edge


@dataclass
class OffloadController:
    ops: List[OperatorCost]
    resources: Dict[str, Resource]
    objective: Objective = field(default_factory=Objective)
    # an OpGraph to plan over frontier cuts; None -> prefix cuts over `ops`
    graph: Optional[object] = None
    headroom: float = 1.3      # replan when rate moves x1.3 outside band
    cooldown: int = 5          # min decisions between migrations
    planned_rate: float = 0.0
    cut: int = 0
    frontier: FrozenSet[str] = frozenset()
    _last_change: int = -10**9
    history: List[OffloadDecision] = field(default_factory=list)

    def _plan(self, rate: float):
        if self.graph is not None:
            plan, frontier = place_frontier(self.graph, self.resources,
                                            rate, self.objective)
            return plan, frontier
        plan, cut = place(self.ops, self.resources, rate, self.objective)
        return plan, frozenset(op.name for op in self.ops[:cut])

    def initial_plan(self, rate: float) -> OffloadDecision:
        plan, frontier = self._plan(rate)
        self.planned_rate, self.frontier = rate, frontier
        self.cut = len(frontier)
        d = OffloadDecision(0, rate, self.cut, "initial", plan, frontier)
        self.history.append(d)
        return d

    def observe(self, step: int, rate: float,
                sla: Optional[SLATracker] = None) -> OffloadDecision:
        """Called periodically with the measured ingest rate."""
        out_of_band = (rate > self.planned_rate * self.headroom
                       or rate < self.planned_rate / self.headroom)
        sla_bad = sla is not None and not sla.ok()
        if (not out_of_band and not sla_bad) or \
                step - self._last_change < self.cooldown:
            d = OffloadDecision(step, rate, self.cut, "hold",
                                self.history[-1].plan, self.frontier)
            return d
        plan, frontier = self._plan(rate)
        reason = "sla" if sla_bad else (
            "rate_up" if rate > self.planned_rate else "rate_down")
        if frontier != self.frontier:
            self._last_change = step
        self.planned_rate, self.frontier = rate, frontier
        self.cut = len(frontier)
        d = OffloadDecision(step, rate, self.cut, reason, plan, frontier)
        self.history.append(d)
        return d

    def migrations(self) -> int:
        fs = [d.frontier for d in self.history]
        return sum(1 for a, b in zip(fs, fs[1:]) if a != b)
