"""Dynamic cloud<->edge workload shifting (S2CE O2, S3).

A hysteresis controller re-plans operator placement when the observed
event rate leaves the band the current plan was built for, or the SLA
tracker reports violations. Replanning uses the same cost model as static
placement; hysteresis (enter/exit thresholds + cooldown) prevents
thrashing when the rate oscillates around a cut point.

Decisions carry the full *assignment* — op name -> pool name over the
job's :class:`~repro.core.costmodel.ClusterSpec` — plus the ``frontier``
view: the downward-closed set of op names resident on *any* edge pool.
For a linear pipeline the frontier is exactly the prefix ``ops[:cut]``
and ``cut`` keeps its old meaning; for an operator DAG the frontier can
hold parallel branches independently and ``cut`` reports its size.
Hysteresis and the migration count key on **plan identity** — the pool
assignment (which pool each op runs on, not merely which side of the
cut) together with the uplink codec — so a multi-pool rebalance that
keeps the frontier set but moves ops between pods still counts as a
migration.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Tuple

from repro.core.costmodel import (ClusterSpec, OperatorCost, PipelinePlan,
                                  ResourcesLike)
from repro.core.placement import Objective, place, place_frontier
from repro.core.sla import SLATracker


@dataclass
class OffloadDecision:
    step: int
    rate: float
    cut: int                 # edge-resident op count (prefix cut if linear)
    reason: str
    plan: PipelinePlan
    frontier: FrozenSet[str] = frozenset()   # op names on any edge pool
    assignment: Dict[str, str] = field(default_factory=dict)
    codec: str = "identity"                  # uplink codec in force


@dataclass
class OffloadController:
    ops: List[OperatorCost]
    resources: ResourcesLike
    objective: Objective = field(default_factory=Objective)
    # an OpGraph to plan over frontier cuts; None -> prefix cuts over `ops`
    graph: Optional[object] = None
    # uplink codec the plan executes with (part of plan identity)
    codec: str = "identity"
    headroom: float = 1.3      # replan when rate moves x1.3 outside band
    cooldown: int = 5          # min decisions between migrations
    planned_rate: float = 0.0
    cut: int = 0
    frontier: FrozenSet[str] = frozenset()
    assignment: Dict[str, str] = field(default_factory=dict)
    _last_change: int = -10**9
    history: List[OffloadDecision] = field(default_factory=list)

    def __post_init__(self):
        self.resources = ClusterSpec.of(self.resources)
        self._edge_pools = {r.name for r in self.resources.edge_pools}

    def _identity(self, assignment: Dict[str, str]
                  ) -> Tuple[Tuple[Tuple[str, str], ...], str]:
        """Plan identity: pool assignment + codec (hashable)."""
        return tuple(sorted(assignment.items())), self.codec

    def _frontier_of(self, assignment: Dict[str, str]) -> FrozenSet[str]:
        return frozenset(n for n, r in assignment.items()
                         if r in self._edge_pools)

    def _plan(self, rate: float):
        if self.graph is not None:
            plan, _ = place_frontier(self.graph, self.resources,
                                     rate, self.objective)
        else:
            plan, _ = place(self.ops, self.resources, rate, self.objective)
        return plan, self._frontier_of(plan.assignment)

    def _decide(self, step: int, rate: float, reason: str,
                plan: PipelinePlan, frontier: FrozenSet[str]
                ) -> OffloadDecision:
        return OffloadDecision(step, rate, len(frontier), reason, plan,
                               frontier, dict(plan.assignment), self.codec)

    def initial_plan(self, rate: float) -> OffloadDecision:
        plan, frontier = self._plan(rate)
        self.planned_rate, self.frontier = rate, frontier
        self.assignment = dict(plan.assignment)
        self.cut = len(frontier)
        d = self._decide(0, rate, "initial", plan, frontier)
        self.history.append(d)
        return d

    def observe(self, step: int, rate: float,
                sla: Optional[SLATracker] = None) -> OffloadDecision:
        """Called periodically with the measured ingest rate."""
        out_of_band = (rate > self.planned_rate * self.headroom
                       or rate < self.planned_rate / self.headroom)
        sla_bad = sla is not None and not sla.ok()
        if (not out_of_band and not sla_bad) or \
                step - self._last_change < self.cooldown:
            return OffloadDecision(step, rate, self.cut, "hold",
                                   self.history[-1].plan, self.frontier,
                                   dict(self.assignment), self.codec)
        plan, frontier = self._plan(rate)
        reason = "sla" if sla_bad else (
            "rate_up" if rate > self.planned_rate else "rate_down")
        if self._identity(plan.assignment) != self._identity(self.assignment):
            self._last_change = step
        self.planned_rate, self.frontier = rate, frontier
        self.assignment = dict(plan.assignment)
        self.cut = len(frontier)
        d = self._decide(step, rate, reason, plan, frontier)
        self.history.append(d)
        return d

    def migrations(self) -> int:
        ids = [(tuple(sorted(d.assignment.items())), d.codec)
               for d in self.history]
        return sum(1 for a, b in zip(ids, ids[1:]) if a != b)
