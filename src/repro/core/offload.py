"""Dynamic cloud<->edge workload shifting (S2CE O2, S3).

A hysteresis controller re-plans operator placement when the observed
event rate leaves the band the current plan was built for, or the SLA
tracker reports violations. Replanning uses the same cost model as static
placement; hysteresis (enter/exit thresholds + cooldown) prevents
thrashing when the rate oscillates around a cut point.

Decisions carry the full *assignment* — op name -> pool name over the
job's :class:`~repro.core.costmodel.ClusterSpec` — plus the ``frontier``
view: the downward-closed set of op names resident on *any* edge pool.
For a linear pipeline the frontier is exactly the prefix ``ops[:cut]``
and ``cut`` keeps its old meaning; for an operator DAG the frontier can
hold parallel branches independently and ``cut`` reports its size.
Hysteresis and the migration count key on **plan identity** — the pool
assignment (which pool each op runs on, not merely which side of the
cut) together with the uplink codec — so a multi-pool rebalance that
keeps the frontier set but moves ops between pods still counts as a
migration.

**Rate-adaptive codec control** (``sla_spec`` + ``codec_candidates``):
the uplink codec is a runtime control dimension, not a construction-time
constant. On every replan event (``rate_up``/``rate_down``/``sla``) the
controller re-runs codec admission against the *windowed* SLA report
(:func:`repro.core.sla.codec_candidates`), extended with the modeled
bottleneck-link utilization of the *current* plan at the new rate: when
the uplink saturates, every budget-admissible codec enters the plan
search and the winning (frontier, pool-assignment, codec) triple
escalates toward cheaper wire; when violations come from latency or the
link has headroom, admission de-escalates toward lossless. Codec changes
carry their own hysteresis (``codec_cooldown`` decisions between swaps,
plus the saturated/relaxed dead band) so codec flapping cannot thrash.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

from repro.core.costmodel import (ClusterSpec, MigrationCost, OperatorCost,
                                  PipelinePlan, ResourcesLike,
                                  migration_cost)
from repro.core.placement import (Objective, place, place_frontier,
                                  stale_pools)
from repro.core.sla import SLA, SLATracker
from repro.core.sla import codec_candidates as sla_codec_candidates


@dataclass
class OffloadDecision:
    step: int
    rate: float
    cut: int                 # edge-resident op count (prefix cut if linear)
    reason: str
    plan: PipelinePlan
    frontier: FrozenSet[str] = frozenset()   # op names on any edge pool
    assignment: Dict[str, str] = field(default_factory=dict)
    codec: str = "identity"                  # uplink codec in force
    # the one-shot price of adopting this decision from the previous
    # plan: every moved op ships its resident state_bytes (raw — state
    # never takes the lossy codec) over the old->new link. Empty for
    # holds, initial plans, and codec-only swaps.
    migration: MigrationCost = field(default_factory=MigrationCost)


@dataclass
class OffloadController:
    ops: List[OperatorCost]
    resources: ResourcesLike
    objective: Objective = field(default_factory=Objective)
    # an OpGraph to plan over frontier cuts; None -> prefix cuts over `ops`
    graph: Optional[object] = None
    # uplink codec the plan executes with (part of plan identity)
    codec: str = "identity"
    # rate-adaptive codec control: the SLA whose error budget gates
    # admission, and the candidate codec names re-admission may pick
    # from. sla_spec=None (or a single candidate) pins the codec — the
    # historical fixed-codec behavior.
    sla_spec: Optional[SLA] = None
    codec_candidates: Optional[List[str]] = None
    headroom: float = 1.3      # replan when rate moves x1.3 outside band
    cooldown: int = 5          # min decisions between migrations
    codec_cooldown: int = 10   # min decisions between codec swaps
    # placement engine for DAG replans ("auto" | "enumerate" | "dp").
    # The controller replans inside the control loop, so it defaults to
    # the polynomial DP — cost-identical to the enumeration with the
    # same canonical tie-break, but it stays fast when the graph or the
    # ClusterSpec grows past toy sizes.
    placement_method: str = "dp"
    planned_rate: float = 0.0
    cut: int = 0
    frontier: FrozenSet[str] = frozenset()
    assignment: Dict[str, str] = field(default_factory=dict)
    _last_change: int = -10**9
    _last_codec_change: int = -10**9
    history: List[OffloadDecision] = field(default_factory=list)

    def __post_init__(self):
        self.set_resources(self.resources)
        if self.codec_candidates is None:
            if self.sla_spec is not None:
                self.codec_candidates = [
                    c.name for c in sla_codec_candidates(self.sla_spec)]
            else:
                self.codec_candidates = [self.codec]
        if self.codec not in self.codec_candidates:
            self.codec_candidates = [self.codec, *self.codec_candidates]

    def set_resources(self, resources: ResourcesLike) -> None:
        """Swap the topology replans run over. The fleet scheduler calls
        this with a *residual* :class:`ClusterSpec` (the shared cluster
        minus other tenants' reservations) before every fleet-arbitrated
        replan, so a tenant controller prices exactly what is left for
        it. Membership churn may also swap in a spec that DROPS a pool
        the incumbent plan uses: :meth:`wants_replan` then fires
        ``pool_lost`` unconditionally and :meth:`hold_decision` refuses,
        so the stale plan can never be silently held."""
        self.resources = ClusterSpec.of(resources)
        self._edge_pools = {r.name for r in self.resources.edge_pools}

    @property
    def _adaptive(self) -> bool:
        return self.sla_spec is not None and len(self.codec_candidates) > 1

    def _identity(self, assignment: Dict[str, str], codec: str
                  ) -> Tuple[Tuple[Tuple[str, str], ...], str]:
        """Plan identity: pool assignment + codec (hashable)."""
        return tuple(sorted(assignment.items())), codec

    def _frontier_of(self, assignment: Dict[str, str]) -> FrozenSet[str]:
        return frozenset(n for n, r in assignment.items()
                         if r in self._edge_pools)

    def _plan(self, rate: float, codecs: Optional[Sequence[str]] = None):
        """Best plan at ``rate`` over the codec candidate names (default:
        the codec currently in force). ``plan.uplink_codec`` records the
        winning codec."""
        codecs = list(codecs) if codecs else [self.codec]
        if self.graph is not None:
            plan, _ = place_frontier(self.graph, self.resources, rate,
                                     self.objective, codecs=codecs,
                                     method=self.placement_method)
        else:
            plan = None
            best_score = float("inf")
            for cname in codecs:
                spec = self.resources.with_uplink_codec(cname)
                cand, _ = place(self.ops, spec, rate, self.objective)
                cand.uplink_codec = cname
                s = self.objective.score(cand)
                if plan is None or s < best_score:
                    plan, best_score = cand, s
        return plan, self._frontier_of(plan.assignment)

    def probe_plan(self, rate: float):
        """Side-effect-free placement probe: the plan :meth:`initial_plan`
        at ``rate`` WOULD take over the current resources, without
        touching controller state. The fleet scheduler's admission check
        prices a candidate tenant through this (after
        :meth:`set_resources` with the residual spec) and only commits
        via :meth:`initial_plan` when the probe meets the SLA."""
        return self._plan(rate)

    def _replan_codecs(self, rate: float, sla: Optional[SLATracker]):
        """A replan with codec re-admission. The saturation signal is
        the bottleneck-link utilization of the best plan under the MOST
        FAITHFUL admissible codec — "what would the lossless wire see" —
        so a compressed incumbent cannot mask a saturated link into a
        bogus de-escalation (an infeasible faithful plan counts as fully
        saturated; a purely compute-infeasible plan escalates too, but
        the search then keeps the most faithful candidate because
        compression does not improve its score)."""
        from repro.core.codecs import get_codec
        cands = [get_codec(n) for n in self.codec_candidates]
        faithful = min(cands, key=lambda c: (c.error_bound, c.ratio)).name
        plan_f, frontier_f = self._plan(rate, [faithful])
        report = dict(sla.report()) if sla is not None else {}
        report.setdefault("violation_rate", 0.0)
        report["codec"] = self.codec
        report["uplink_utilization"] = (
            plan_f.uplink_utilization if plan_f.feasible else float("inf"))
        names = [c.name for c in sla_codec_candidates(
            self.sla_spec, report=report, candidates=cands)]
        if names == [faithful]:
            return plan_f, frontier_f
        # the faithful probe is already the best plan for its codec:
        # search only the remaining candidates and keep the probe when
        # it scores no worse (ties resolve most-faithful-first, matching
        # the combined search) — halves the escalation-path search cost
        rest = [n for n in names if n != faithful]
        plan_r, frontier_r = self._plan(rate, rest)
        if len(rest) < len(names) and \
                self.objective.score(plan_f) <= self.objective.score(plan_r):
            return plan_f, frontier_f
        return plan_r, frontier_r

    def _decide(self, step: int, rate: float, reason: str,
                plan: PipelinePlan, frontier: FrozenSet[str]
                ) -> OffloadDecision:
        return OffloadDecision(step, rate, len(frontier), reason, plan,
                               frontier, dict(plan.assignment), self.codec)

    def initial_plan(self, rate: float, step: int = 0) -> OffloadDecision:
        plan, frontier = self._plan(rate)
        # the initial admission starts the codec-hysteresis clock: the
        # first swap also has to wait out codec_cooldown
        self._last_codec_change = step
        self.planned_rate, self.frontier = rate, frontier
        self.assignment = dict(plan.assignment)
        self.cut = len(frontier)
        d = self._decide(step, rate, "initial", plan, frontier)
        self.history.append(d)
        return d

    def wants_replan(self, step: int, rate: float,
                     sla: Optional[SLATracker] = None) -> Optional[str]:
        """Pure trigger check (no state change): the replan reason a call
        to :meth:`observe` at these arguments would act on, or ``None``
        for a hold. Split out so a fleet scheduler can *collect* triggers
        across tenants and batch them into one arbitration pass instead
        of letting every tenant replan the moment it fires."""
        if not self.history:
            return "initial"
        if stale_pools(self.assignment, self.resources):
            # membership churn removed a pool the incumbent plan still
            # references: replan unconditionally — no band or cooldown
            # gate may hold a plan whose pool no longer exists
            return "pool_lost"
        out_of_band = (rate > self.planned_rate * self.headroom
                       or rate < self.planned_rate / self.headroom)
        sla_bad = sla is not None and not sla.ok()
        if (not out_of_band and not sla_bad) or \
                step - self._last_change < self.cooldown:
            return None
        return "sla" if sla_bad else (
            "rate_up" if rate > self.planned_rate else "rate_down")

    def hold_decision(self, step: int, rate: float) -> OffloadDecision:
        """The no-change decision (not appended to history, matching the
        historical observe() hold path). Raises when the incumbent plan
        references a pool that left the topology — holding such a plan
        would execute ops on a pool that no longer exists."""
        stale = stale_pools(self.assignment, self.resources)
        if stale:
            raise ValueError(
                f"cannot hold a plan placed on departed pool(s) {stale}: "
                "the topology no longer contains them; replan first")
        return OffloadDecision(step, rate, self.cut, "hold",
                               self.history[-1].plan, self.frontier,
                               dict(self.assignment), self.codec)

    def replan(self, step: int, rate: float,
               sla: Optional[SLATracker] = None,
               reason: Optional[str] = None) -> OffloadDecision:
        """Execute a replan event: re-run codec admission against the
        windowed SLA report; when admission widens or moves the candidate
        set, the (frontier x pool x codec) search decides. Codec
        hysteresis: within codec_cooldown of the last swap only the
        incumbent codec is searched. Callers normally go through
        :meth:`observe`; the fleet scheduler calls this directly (after
        :meth:`set_resources` with the tenant's residual spec) for the
        tenants its arbitration pass granted a replan."""
        if not self.history:
            return self.initial_plan(rate, step=step)
        if reason is None:
            reason = ("sla" if sla is not None and not sla.ok() else
                      "rate_up" if rate > self.planned_rate else "rate_down")
        old_identity = self._identity(self.assignment, self.codec)
        old_assign = dict(self.assignment)
        if self._adaptive and \
                step - self._last_codec_change >= self.codec_cooldown:
            plan, frontier = self._replan_codecs(rate, sla)
        else:
            plan, frontier = self._plan(rate)
        new_codec = plan.uplink_codec or self.codec
        if new_codec != self.codec:
            self.codec = new_codec
            self._last_codec_change = step
        mig = MigrationCost()
        if self._identity(plan.assignment, self.codec) != old_identity:
            self._last_change = step
            # price the state move this adoption implies (ops whose pool
            # changed ship their resident bytes over the old->new link)
            mig = migration_cost(self.ops, old_assign, plan.assignment,
                                 self.resources)
        self.planned_rate, self.frontier = rate, frontier
        self.assignment = dict(plan.assignment)
        self.cut = len(frontier)
        d = self._decide(step, rate, reason, plan, frontier)
        d.migration = mig
        self.history.append(d)
        return d

    def observe(self, step: int, rate: float,
                sla: Optional[SLATracker] = None) -> OffloadDecision:
        """Called periodically with the measured ingest rate."""
        if not self.history:
            # observe() before initial_plan() used to IndexError on
            # history[-1]; take the initial plan lazily instead
            return self.initial_plan(rate, step=step)
        reason = self.wants_replan(step, rate, sla)
        if reason is None:
            return self.hold_decision(step, rate)
        return self.replan(step, rate, sla, reason)

    def migrations(self) -> int:
        ids = [(tuple(sorted(d.assignment.items())), d.codec)
               for d in self.history]
        return sum(1 for a, b in zip(ids, ids[1:]) if a != b)
