"""Composable operator-graph pipeline IR (S2CE O2): one op list that the
cost model, placement search, offload controller, and executor all consume,
so a placement decision *is* an execution plan.

An :class:`Op` declares a pure ``(state, batch) -> (state, batch)`` step
function (``batch`` is a dict of arrays — a jax pytree), an initial-state
factory, and the :class:`~repro.core.costmodel.OperatorCost` profile the
placement optimizer prices it with. A :class:`Pipeline` is an ordered op
list that can be partitioned at any prefix cut ``k``: ``ops[:k]`` fuse
into the edge segment and ``ops[k:]`` into the cloud segment, each jitted
separately. When the offload controller migrates the cut, the segments
are re-fused; a small compile cache keyed by ``(segment, batch shapes)``
makes revisiting a cut free.

Cut-invariance: in the default ``fuse="op"`` mode each op is its own XLA
compilation unit and segments compose the *shared* per-op executables, so
an op computes bitwise-identically no matter which segment it lands in —
migrating the cut never perturbs learner state, and every cut reproduces
the unpartitioned reference exactly (``tests/test_property.py`` checks
every cut). ``fuse="xla"`` instead jits each segment as one fused XLA
program (op boundaries pinned with ``lax.optimization_barrier``): higher
throughput for stable placements, but whole-program fusion context can
shift reduction codegen by an ulp across cuts, so migrations are only
allclose, not bitwise — choose it when the placement is expected to be
static or the learner tolerates ulp-level perturbation.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.costmodel import OperatorCost
from repro.ml import metrics as mmetrics
from repro.ml import online
from repro.streams import drift as drift_mod
from repro.streams import preprocess as prep
from repro.streams import sampling as samp
from repro.streams import sketches as sk

Batch = Dict[str, jax.Array]
StepFn = Callable[[Any, Batch], Tuple[Any, Batch]]


def _no_state():
    return ()


@dataclass(frozen=True)
class Op:
    """One pipeline stage: a pure ``(state, batch) -> (state, batch)`` fn
    plus the cost profile placement prices it with.

    ``on_drift`` (optional) maps state -> state when the orchestrator's
    drift response fires; ``metrics`` (optional) maps state -> dict for
    the Output Interface at end of run.
    """
    name: str
    fn: StepFn
    cost: OperatorCost
    init: Callable[[], Any] = _no_state
    on_drift: Optional[Callable[[Any], Any]] = None
    metrics: Optional[Callable[[Any], dict]] = None


class Pipeline:
    """An ordered list of :class:`Op`, executable under any prefix cut."""

    def __init__(self, ops: Sequence[Op], fuse: str = "op"):
        ops = tuple(ops)
        if not ops:
            raise ValueError("pipeline needs at least one op")
        names = [op.name for op in ops]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate op names: {names}")
        if fuse not in ("op", "xla"):
            raise ValueError(f"fuse mode {fuse!r} not in ('op', 'xla')")
        self.ops = ops
        self.fuse = fuse
        self._segments: Dict[tuple, Callable] = {}   # (lo, hi, sig) -> fn
        self._op_fns: Dict[int, Callable] = {}       # op idx -> jitted step
        self.compiles = 0          # cache misses (segment re-fusions)
        self.cache_hits = 0

    # -- IR views ----------------------------------------------------------
    @property
    def names(self) -> List[str]:
        return [op.name for op in self.ops]

    @property
    def n_cuts(self) -> int:
        """Valid cuts are 0..len(ops): ops[:k] edge, ops[k:] cloud."""
        return len(self.ops) + 1

    def costs(self) -> List[OperatorCost]:
        """The cost-model view — what placement/offload optimize over."""
        return [op.cost for op in self.ops]

    def init_states(self) -> Dict[str, Any]:
        return {op.name: op.init() for op in self.ops}

    def op(self, name: str) -> Op:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    # -- partitioned execution ---------------------------------------------
    @staticmethod
    def _sig(batch: Batch) -> tuple:
        return tuple(sorted((k, jnp.shape(v), jnp.result_type(v).name)
                            for k, v in batch.items()))

    def _op_fn(self, i: int) -> Callable:
        """The per-op compiled step — shared by every segment that contains
        op ``i``, which is what makes cut migration bitwise-safe. One jit
        wrapper per op; jax itself specializes per batch signature."""
        fn = self._op_fns.get(i)
        if fn is None:
            fn = jax.jit(self.ops[i].fn)
            self._op_fns[i] = fn
        return fn

    def _fuse_xla(self, lo: int, hi: int) -> Callable:
        """ops[lo:hi] as one fused XLA program; barriers pin op boundaries
        (keeps op semantics, but fusion context is still cut-dependent)."""
        ops = self.ops[lo:hi]

        def segment(states: Dict[str, Any], batch: Batch):
            states = dict(states)
            for op in ops:
                st, batch = op.fn(states[op.name], batch)
                st, batch = jax.lax.optimization_barrier((st, batch))
                states[op.name] = st
            return states, batch

        return jax.jit(segment)

    def _fuse_ops(self, lo: int, hi: int) -> Callable:
        """ops[lo:hi] as a dispatch-level composition of the shared per-op
        executables (the default, cut-invariant segment form)."""
        def segment(states: Dict[str, Any], batch: Batch):
            states = dict(states)
            for i in range(lo, hi):
                op = self.ops[i]
                st, batch = self._op_fn(i)(states[op.name], batch)
                states[op.name] = st
            return states, batch

        return segment

    def _segment_fn(self, lo: int, hi: int, batch: Batch) -> Callable:
        """Re-fuse (or fetch) the segment for ops[lo:hi] at this batch
        signature — the compile cache that makes cut revisits free."""
        key = (lo, hi, self._sig(batch))
        fn = self._segments.get(key)
        if fn is None:
            fn = (self._fuse_xla(lo, hi) if self.fuse == "xla"
                  else self._fuse_ops(lo, hi))
            self._segments[key] = fn
            self.compiles += 1
        else:
            self.cache_hits += 1
        return fn

    def run(self, states: Dict[str, Any], batch: Batch, cut: int
            ) -> Tuple[Dict[str, Any], Batch]:
        """Execute under prefix cut ``cut``: ops[:cut] as the edge segment,
        ops[cut:] as the cloud segment (either may be empty)."""
        if not 0 <= cut <= len(self.ops):
            raise ValueError(f"cut {cut} outside [0, {len(self.ops)}]")
        for lo, hi in ((0, cut), (cut, len(self.ops))):
            if lo == hi:
                continue
            sub = {op.name: states[op.name] for op in self.ops[lo:hi]}
            fn = self._segment_fn(lo, hi, batch)
            sub, batch = fn(sub, batch)
            states = {**states, **sub}
        return states, batch

    def run_reference(self, states: Dict[str, Any], batch: Batch
                      ) -> Tuple[Dict[str, Any], Batch]:
        """Unpartitioned execution: the whole pipeline as one fused jit.
        Any cut must reproduce this bitwise."""
        return self.run(states, batch, cut=0)


# ---------------------------------------------------------------------------
# Standard op wrappers around streams/ and ml/ — the same functions the
# hard-coded orchestrator stages used to call, now declared as IR nodes.
# ---------------------------------------------------------------------------

def _ev(dim: int) -> float:
    return 4.0 * dim        # fp32 bytes per event at width `dim`


def normalize_op(dim: int) -> Op:
    """Welford running normalization (edge preprocessing)."""
    def fn(state, batch):
        state, xn = prep.norm_update_apply(state, batch["x"])
        return state, {**batch, "x": xn}
    cost = OperatorCost("normalize", flops_per_event=50 * dim,
                        bytes_per_event=4 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("normalize", fn, cost, init=lambda: prep.norm_init(dim))


def sketch_op(dim: int) -> Op:
    """Streaming moments sketch (edge-side summary)."""
    def fn(state, batch):
        return sk.moments_update(state, batch["x"]), batch
    cost = OperatorCost("sketch", flops_per_event=20 * dim,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("sketch", fn, cost, init=lambda: sk.moments_init(dim))


def sample_op(dim: int, rate: float, reservoir_k: int = 256) -> Op:
    """Reservoir update + Bernoulli thinning; emits the keep `mask` and
    threads the stream `rng`."""
    def fn(state, batch):
        state = samp.reservoir_update(state, batch["x"], batch["y"])
        mask, rng = samp.bernoulli_thin(batch["rng"], batch["x"], rate)
        return state, {**batch, "mask": mask, "rng": rng}
    cost = OperatorCost("sample", flops_per_event=20,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim) * rate)
    return Op("sample", fn, cost,
              init=lambda: samp.reservoir_init(reservoir_k, dim))


def logreg_train_op(dim: int, lr: float = 0.5,
                    flops_per_event: float = 2e6) -> Op:
    """Prequential test-then-train online logistic regression. Predicts on
    the full batch, updates on the sampled (masked) rows, and writes the
    per-event error stream for a downstream drift op."""
    def fn(state, batch):
        model, preq = state
        x, y = batch["x"], batch["y"]
        p = online.logreg_predict(model, x)
        err = (jnp.where(p > 0.5, 1, 0) != y).astype(jnp.float32)
        preq = mmetrics.preq_update(preq, p, y)
        mask = batch.get("mask", jnp.ones(x.shape[:1], bool))
        w = mask.astype(jnp.float32)
        model = online.logreg_update(model, x * w[:, None], y * mask, lr=lr)
        return (model, preq), {**batch, "p": p, "err": err}
    # emits model/metric deltas, not events: the uplink-compressing stage.
    # Cheap rates place it on the edge (a paper-style pre-model); its
    # 2e6 flops/event saturate the edge pool near 1e6 ev/s, which is what
    # pushes the cut down (training offloads to cloud) under rate spikes.
    cost = OperatorCost("train", flops_per_event=flops_per_event,
                        bytes_per_event=20 * _ev(dim),
                        out_bytes_per_event=8.0)
    return Op("train", fn, cost,
              init=lambda: (online.logreg_init(dim), mmetrics.preq_init()),
              on_drift=lambda s: (online.logreg_reset_soft(s[0]), s[1]),
              metrics=lambda s: mmetrics.preq_metrics(s[1]))


def drift_op(detector: str = "ddm") -> Op:
    """Concept-drift detection over the op-emitted error stream. Model
    management is a cloud concern, so this op is not edge-capable (it
    also anchors at least one stage on the cloud pool)."""
    init_fn, step_fn = {
        "ddm": (drift_mod.ddm_init, drift_mod.ddm_step),
        "eddm": (drift_mod.eddm_init, drift_mod.eddm_step),
        "ph": (drift_mod.ph_init, drift_mod.ph_step),
        "adwin": (drift_mod.adwin_init, drift_mod.adwin_step),
    }[detector]

    def fn(state, batch):
        state, levels = jax.lax.scan(step_fn, state, batch["err"])
        drifted = jnp.any(levels == drift_mod.DRIFT)
        return state, {**batch, "drifted": drifted}
    cost = OperatorCost("drift", flops_per_event=50, bytes_per_event=64,
                        out_bytes_per_event=8, edge_capable=False)
    return Op("drift", fn, cost, init=init_fn)


# -- scenario-diversity ops -------------------------------------------------

def hash_op(dim: int, seed: int = 17) -> Op:
    """Signed feature hashing: sparse (ids, vals) -> dense x."""
    def fn(state, batch):
        x = prep.hash_features(batch["ids"], batch["vals"], dim, seed=seed)
        out = {k: v for k, v in batch.items() if k not in ("ids", "vals")}
        return state, {**out, "x": x}
    cost = OperatorCost("hash", flops_per_event=10 * dim,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("hash", fn, cost)


def pca_op(dim: int, k: int, lr: float = 1e-2, seed: int = 0) -> Op:
    """Streaming PCA (Oja's rule): project x from `dim` to `k` dims."""
    def fn(state, batch):
        state, z = prep.oja_update_project(state, batch["x"], lr=lr)
        return state, {**batch, "x": z}
    cost = OperatorCost("pca", flops_per_event=4 * dim * k,
                        bytes_per_event=6 * _ev(dim),
                        out_bytes_per_event=4.0 * k)
    return Op("pca", fn, cost, init=lambda: prep.oja_init(dim, k, seed))


def concat_op(key: str, out_dim: int) -> Op:
    """Concatenate a fused column (e.g. a WindowJoin output) onto x —
    the fusion-fed pipeline entry point."""
    def fn(state, batch):
        x = jnp.concatenate([batch["x"], batch[key]], axis=-1)
        out = {k: v for k, v in batch.items() if k != key}
        return state, {**out, "x": x}
    cost = OperatorCost("concat", flops_per_event=2 * out_dim,
                        bytes_per_event=2 * _ev(out_dim),
                        out_bytes_per_event=_ev(out_dim))
    return Op("concat", fn, cost)


def anomaly_op(dim: int, m: int = 8, seed: int = 0) -> Op:
    """Random-projection histogram anomaly scorer; writes `score`."""
    def fn(state, batch):
        state = online.anomaly_update(state, batch["x"])
        score = online.anomaly_score(state, batch["x"])
        return state, {**batch, "score": score}
    cost = OperatorCost("anomaly", flops_per_event=2 * dim * m,
                        bytes_per_event=4 * _ev(dim),
                        out_bytes_per_event=4.0)
    return Op("anomaly", fn, cost, init=lambda: online.anomaly_init(dim, m=m,
                                                                    seed=seed))


def standard_stream_pipeline(dim: int, sample_rate: float = 0.5,
                             drift_detector: str = "ddm",
                             reservoir_k: int = 256) -> Pipeline:
    """The default S2CE job: normalize -> sketch -> sample -> train -> drift
    (the op-graph form of the orchestrator's old hard-coded stages)."""
    return Pipeline([
        normalize_op(dim),
        sketch_op(dim),
        sample_op(dim, sample_rate, reservoir_k),
        logreg_train_op(dim),
        drift_op(drift_detector),
    ])
