"""Operator-DAG pipeline IR (S2CE O2): one dataflow graph that the cost
model, placement search, offload controller, and executor all consume, so
a placement decision *is* an execution plan.

An :class:`Op` declares a pure ``(state, batch) -> (state, batch)`` step
function (``batch`` is a dict of arrays — a jax pytree), an initial-state
factory, the :class:`~repro.core.costmodel.OperatorCost` profile the
placement optimizer prices it with, and — for DAG composition — its named
I/O channels: the batch keys it ``reads``, ``writes``, and ``deletes``.

An :class:`OpGraph` is a dataflow graph over such ops. Dependency edges
are inferred from the channel declarations (producer -> consumer for each
read key, plus write-after-read/write hazards), so fused sources can fan
out to parallel sketches, samplers, and learners whose outputs rejoin —
the Fig. 2 workflow shapes a linear chain cannot express. The graph is
partitioned at any *downward-closed cut set* ("frontier"): a set of ops
that contains all of its own ancestors runs on the edge, its upward-closed
complement on the cloud, and the cost model prices the uplink per crossing
edge (``out_bytes_per_event`` of each edge-side producer feeding a cloud
consumer) instead of at one cut point.

:class:`Pipeline` is retained as the linear special case: an ordered op
list whose frontiers are exactly the prefix cuts ``ops[:k]``, with the
same ``run(states, batch, cut)`` API, prefix-cut placement, and plan
costs as before — every existing call site keeps working unchanged.

Cut-invariance: in the default ``fuse="op"`` mode each op is its own XLA
compilation unit and segments compose the *shared* per-op executables, so
an op computes bitwise-identically no matter which segment it lands in —
migrating the frontier never perturbs learner state, and every
downward-closed cut reproduces the unpartitioned reference exactly
(``tests/test_property.py`` checks every cut). ``OpGraph`` additionally
restricts each op's input dict to its declared ``reads``, so the per-op
executable sees the same input signature under every frontier.
``fuse="xla"`` instead jits each segment as one fused XLA program (op
boundaries pinned with ``lax.optimization_barrier``): higher throughput
for stable placements, but whole-program fusion context can shift
reduction codegen by an ulp across cuts, so migrations are only allclose,
not bitwise — choose it when the placement is expected to be static or
the learner tolerates ulp-level perturbation.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import (Any, Callable, Dict, FrozenSet, Iterable, Iterator, List,
                    Optional, Sequence, Tuple)

import jax
import jax.numpy as jnp

from repro.core.costmodel import OperatorCost
from repro.ml import metrics as mmetrics
from repro.ml import online
from repro.streams import drift as drift_mod
from repro.streams import preprocess as prep
from repro.streams import sampling as samp
from repro.streams import sketches as sk

Batch = Dict[str, jax.Array]
StepFn = Callable[[Any, Batch], Tuple[Any, Batch]]


def _no_state():
    return ()


@dataclass(frozen=True)
class Op:
    """One pipeline stage: a pure ``(state, batch) -> (state, batch)`` fn
    plus the cost profile placement prices it with.

    ``reads``/``writes``/``deletes`` declare the op's named channels —
    the batch keys it consumes, produces, and removes. :class:`OpGraph`
    requires them (they define the dataflow edges); :class:`Pipeline`
    treats an undeclared op conservatively as reading and writing
    everything, which is exactly the linear-chain dependency structure.

    ``on_drift`` (optional) maps state -> state when the orchestrator's
    drift response fires; ``metrics`` (optional) maps state -> dict for
    the Output Interface at end of run.

    ``jit=False`` marks a *host op*: the graph calls ``fn`` directly
    instead of wrapping it in ``jax.jit``. This is how an op that
    manages its own compiled executables composes into the graph — a
    decode op looping over a serve engine's donated-buffer decode step
    must reuse that exact executable to stay bitwise-identical to the
    standalone engine (and to keep buffer donation legal). Host ops are
    only valid under ``fuse="op"``.
    """
    name: str
    fn: StepFn
    cost: OperatorCost
    init: Callable[[], Any] = _no_state
    on_drift: Optional[Callable[[Any], Any]] = None
    metrics: Optional[Callable[[Any], dict]] = None
    reads: Optional[Tuple[str, ...]] = None
    writes: Optional[Tuple[str, ...]] = None
    deletes: Tuple[str, ...] = ()
    jit: bool = True

    def __post_init__(self):
        for f in ("reads", "writes", "deletes"):
            v = getattr(self, f)
            if v is not None and not isinstance(v, tuple):
                object.__setattr__(self, f, tuple(v))


class OpGraph:
    """A dataflow graph of :class:`Op`, executable under any frontier cut.

    Ops are given in a topological list order (the reference execution
    order); every op must declare its channels. Dependencies are inferred
    per key with full hazard analysis over that order:

      * true dependency — the last writer of a key feeds each reader
        (these are the *flow edges* the cost model prices bytes on),
      * anti dependency — a reader must precede the key's next writer,
      * output dependency — writers of the same key stay ordered.

    A *frontier* is a downward-closed op set (every member's dependencies
    are members): the edge-resident part of a partition. Executing the
    edge segment then the cloud segment is then a valid topological
    linearization, so any frontier reproduces the reference bitwise under
    ``fuse="op"``.
    """

    def __init__(self, ops: Sequence[Op], fuse: str = "op"):
        ops = tuple(ops)
        if not ops:
            raise ValueError("graph needs at least one op")
        names = [op.name for op in ops]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate op names: {names}")
        if fuse not in ("op", "xla"):
            raise ValueError(f"fuse mode {fuse!r} not in ('op', 'xla')")
        if fuse == "xla":
            host = [op.name for op in ops if not op.jit]
            if host:
                raise ValueError(
                    f"fuse='xla' cannot fuse host ops (jit=False): {host}; "
                    "host ops manage their own executables and only "
                    "compose under fuse='op'")
        self.ops = ops
        self.fuse = fuse
        self._segments: Dict[tuple, Callable] = {}   # (idxs, sig) -> fn
        self._op_fns: Dict[int, Callable] = {}       # op idx -> jitted step
        self.compiles = 0          # cache misses (segment re-fusions)
        self.cache_hits = 0
        # measured per-op costs (core/selftune.measure_operator_costs)
        # overriding the declared OperatorCost guesses in costs()
        self._cost_overrides: Dict[str, OperatorCost] = {}
        self._build_deps()

    # -- dependency inference ----------------------------------------------
    def _build_deps(self):
        undeclared = [op.name for op in self.ops
                      if op.reads is None or op.writes is None]
        if undeclared:
            raise ValueError(
                f"OpGraph ops must declare reads/writes channels; missing "
                f"on: {undeclared} (use Pipeline for undeclared linear "
                f"chains)")
        parents: List[set] = [set() for _ in self.ops]
        flow_parents: List[set] = [set() for _ in self.ops]
        flow: set = set()
        last_writer: Dict[str, int] = {}
        readers: Dict[str, set] = {}
        source_reads: List[str] = []
        source_consumers: List[str] = []
        all_writers: Dict[str, int] = {}
        for j, op in enumerate(self.ops):
            for k in op.writes + op.deletes:
                all_writers.setdefault(k, j)
        for j, op in enumerate(self.ops):
            for k in op.reads:
                i = last_writer.get(k)
                if i is None:
                    w = all_writers.get(k)
                    if w is not None and w != j:
                        raise ValueError(
                            f"op {op.name!r} reads channel {k!r} which is "
                            f"only written by the later op "
                            f"{self.ops[w].name!r}; order ops topologically")
                    if k not in source_reads:
                        source_reads.append(k)
                    if op.name not in source_consumers:
                        source_consumers.append(op.name)
                else:
                    parents[j].add(i)
                    flow_parents[j].add(i)
                    flow.add((i, j))
                readers.setdefault(k, set()).add(j)
            for k in op.writes + op.deletes:
                i = last_writer.get(k)
                if i is not None and i != j:
                    parents[j].add(i)              # write-after-write
                for r in readers.get(k, ()):
                    if r != j:
                        parents[j].add(r)          # write-after-read
                last_writer[k] = j
                readers[k] = set()
        self._parents: Tuple[FrozenSet[int], ...] = tuple(
            frozenset(p) for p in parents)
        # the *closure* relation frontier enumeration (and the placement
        # DP) is downward-closed under: identical to the full hazard
        # relation, except that a downlink-ok op drops its flow parents
        # (its inputs may legitimately arrive over a cloud->edge
        # downlink — the evaluator prices that crossing instead of
        # forbidding it). Pure WAR/WAW hazard parents are kept. Graphs
        # without downlink ops have closure == hazard parents, so every
        # existing frontier family is unchanged.
        self._closure: Tuple[FrozenSet[int], ...] = tuple(
            frozenset(p - flow_parents[j])
            if self.ops[j].cost.downlink_ok else frozenset(p)
            for j, p in enumerate(parents))
        self._flow_pairs: Tuple[Tuple[int, int], ...] = tuple(sorted(flow))
        self.flow_edges: Tuple[Tuple[str, str], ...] = tuple(sorted(
            (self.ops[i].name, self.ops[j].name) for i, j in flow))
        self.source_reads = tuple(source_reads)
        self.source_consumers = tuple(source_consumers)

    @property
    def source_bytes_per_event(self) -> float:
        """Raw-event size the source crossing is priced at: the first
        source-consuming op's input traffic (for a chain this is
        ``ops[0].bytes_per_event`` — the linear model's charge)."""
        if not self.source_consumers:
            return 0.0
        return self.cost_of(self.source_consumers[0]).bytes_per_event

    # -- IR views ----------------------------------------------------------
    @property
    def names(self) -> List[str]:
        return [op.name for op in self.ops]

    @property
    def hazard_parent_indices(self) -> Tuple[FrozenSet[int], ...]:
        """Per-op index sets of ALL dependency parents (true flow deps
        plus write-after-read/write hazards) — the full ordering
        relation. For frontier enumeration and the placement DP, use
        :attr:`closure_parent_indices` (equal to this unless an op
        declares ``downlink_ok``)."""
        return self._parents

    @property
    def closure_parent_indices(self) -> Tuple[FrozenSet[int], ...]:
        """The relation :meth:`frontiers` enumerates downward-closed
        sets under and the placement DP enforces: hazard parents, minus
        the flow parents of ``downlink_ok`` ops (those inputs may ride
        the cloud->edge downlink, so the parent need not be
        edge-resident). Every frontier it admits is executable —
        :meth:`run` interleaves sides in list order when a frontier is
        not closed under the full hazard relation."""
        return self._closure

    @property
    def flow_pairs(self) -> Tuple[Tuple[int, int], ...]:
        """The true-dependency edges as (producer idx, consumer idx)
        pairs — the index view of :attr:`flow_edges` (the edges the cost
        model prices bytes on)."""
        return self._flow_pairs

    def count_frontiers(self, limit: Optional[int] = None) -> int:
        """Number of downward-closed frontiers, enumerated lazily and
        capped at ``limit`` (the dispatch heuristic in
        ``placement.place_frontier`` needs "more than N?", never the
        exact — potentially exponential — count)."""
        n = 0
        for _ in self.frontiers():
            n += 1
            if limit is not None and n >= limit:
                break
        return n

    def costs(self) -> List[OperatorCost]:
        """The cost-model view — what placement/offload optimize over.
        Measured overrides (:meth:`set_measured_costs`) win over the
        declared per-op guesses."""
        return [self._cost_overrides.get(op.name, op.cost)
                for op in self.ops]

    def cost_of(self, name: str) -> OperatorCost:
        return self._cost_overrides.get(name) or self.op(name).cost

    def set_measured_costs(
            self, costs: Optional[Dict[str, OperatorCost]]) -> None:
        """Install measured per-op costs (from
        :func:`repro.core.selftune.measure_operator_costs`) so placement
        optimizes against measurement instead of the hand-written
        declarations. ``None`` clears back to the declared costs.

        Edge-capability and downlink tolerance are *semantic*
        declarations (model management must stay in the cloud; only a
        decode op designed for it may consume over the downlink), not
        something a dry-run can measure, so the declared flags always
        survive the override."""
        if costs is None:
            self._cost_overrides = {}
            return
        unknown = sorted(set(costs) - set(self.names))
        if unknown:
            raise ValueError(f"measured costs name unknown ops: {unknown}")
        self._cost_overrides = {
            name: replace(c, name=name,
                          edge_capable=self.op(name).cost.edge_capable,
                          downlink_ok=self.op(name).cost.downlink_ok)
            for name, c in costs.items()}

    def init_states(self) -> Dict[str, Any]:
        return {op.name: op.init() for op in self.ops}

    def op(self, name: str) -> Op:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    def parents_of(self, name: str) -> FrozenSet[str]:
        i = self.names.index(name)
        return frozenset(self.ops[p].name for p in self._parents[i])

    # -- frontier cuts ------------------------------------------------------
    def check_frontier(self, frontier: Iterable[str]) -> FrozenSet[str]:
        """Validate ``frontier`` is a known, downward-closed op set."""
        f = frozenset(frontier)
        unknown = f - set(self.names)
        if unknown:
            raise ValueError(f"unknown ops in frontier: {sorted(unknown)}")
        idx = {op.name: i for i, op in enumerate(self.ops)}
        for name in f:
            for p in self._closure[idx[name]]:
                if self.ops[p].name not in f:
                    raise ValueError(
                        f"frontier not downward-closed: {name!r} depends on "
                        f"{self.ops[p].name!r} which is not in the frontier")
        return f

    def frontiers(self) -> Iterator[FrozenSet[str]]:
        """Enumerate every downward-closed cut set (edge-side op set)
        under :attr:`closure_parent_indices`. For a chain these are
        exactly the ``n+1`` prefixes; a graph with downlink-ok ops
        additionally admits frontiers whose members receive inputs over
        the cloud->edge downlink (e.g. ``{decode}`` with prefill in the
        cloud)."""
        n = len(self.ops)
        names = self.names
        parents = self._closure

        def rec(i: int, cur: set) -> Iterator[FrozenSet[str]]:
            if i == n:
                yield frozenset(names[j] for j in cur)
                return
            yield from rec(i + 1, cur)          # op i on the cloud side
            if parents[i] <= cur:               # edge only if deps on edge
                cur.add(i)
                yield from rec(i + 1, cur)
                cur.remove(i)

        yield from rec(0, set())

    # -- partitioned execution ---------------------------------------------
    @staticmethod
    def _sig(batch: Batch) -> tuple:
        # channels may carry whole pytrees (a KV cache, a param tree),
        # not just arrays — the signature is treedef + per-leaf
        # shape/dtype, which degenerates to the old (shape, dtype) key
        # for plain array channels.
        out = []
        for k in sorted(batch):
            leaves, treedef = jax.tree_util.tree_flatten(batch[k])
            out.append((k, str(treedef),
                        tuple((jnp.shape(l), jnp.result_type(l).name)
                              for l in leaves)))
        return tuple(out)

    def _op_fn(self, i: int) -> Callable:
        """The per-op compiled step — shared by every segment that contains
        op ``i``, which is what makes frontier migration bitwise-safe. One
        jit wrapper per op; jax itself specializes per batch signature.
        Host ops (``jit=False``) run their fn directly — they own their
        compiled executables."""
        fn = self._op_fns.get(i)
        if fn is None:
            op = self.ops[i]
            fn = jax.jit(op.fn) if op.jit else op.fn
            self._op_fns[i] = fn
        return fn

    def _apply(self, i: int, states: Dict[str, Any], env: Batch,
               call: Optional[Callable] = None
               ) -> Tuple[Dict[str, Any], Batch]:
        """Run op ``i`` with channel semantics: feed only its declared
        ``reads`` (the per-op input signature is therefore identical under
        every frontier), merge back only its declared ``writes``, and drop
        its ``deletes``."""
        op = self.ops[i]
        inb = {k: env[k] for k in op.reads if k in env}
        st, out = (call or self._op_fn(i))(states[op.name], inb)
        states[op.name] = st
        if op.deletes:
            env = {k: v for k, v in env.items() if k not in op.deletes}
        else:
            env = dict(env)
        env.update({k: out[k] for k in op.writes if k in out})
        return states, env

    def _fuse_xla(self, idxs: Tuple[int, ...]) -> Callable:
        """The segment as one fused XLA program; barriers pin op boundaries
        (keeps op semantics, but fusion context is still cut-dependent)."""
        def segment(states: Dict[str, Any], env: Batch):
            states = dict(states)
            for i in idxs:
                states, env = self._apply(i, states, env,
                                          call=self.ops[i].fn)
                st, env = jax.lax.optimization_barrier(
                    (states[self.ops[i].name], env))
                states[self.ops[i].name] = st
            return states, env

        return jax.jit(segment)

    def _fuse_ops(self, idxs: Tuple[int, ...]) -> Callable:
        """The segment as a dispatch-level composition of the shared
        per-op executables (the default, cut-invariant segment form)."""
        def segment(states: Dict[str, Any], env: Batch):
            states = dict(states)
            for i in idxs:
                states, env = self._apply(i, states, env)
            return states, env

        return segment

    def _segment_fn(self, idxs: Tuple[int, ...], batch: Batch) -> Callable:
        """Re-fuse (or fetch) the segment for the op subset ``idxs`` at
        this batch signature — the compile cache that makes frontier
        revisits free."""
        key = (idxs, self._sig(batch))
        fn = self._segments.get(key)
        if fn is None:
            fn = (self._fuse_xla(idxs) if self.fuse == "xla"
                  else self._fuse_ops(idxs))
            self._segments[key] = fn
            self.compiles += 1
        else:
            self.cache_hits += 1
        return fn

    def _run_segments(self, states: Dict[str, Any], batch: Batch,
                      segments: Sequence[Tuple[int, ...]],
                      uplink: Optional[Callable[[Batch], Batch]] = None,
                      sides: Optional[Sequence[str]] = None
                      ) -> Tuple[Dict[str, Any], Batch]:
        """Execute ``segments`` in order, applying ``uplink`` (the wire
        codec round-trip) on every *side change*. ``sides`` labels each
        segment "edge"/"cloud"; without it the historical two-segment
        rule applies (first segment edge, the rest cloud). The stream
        source sits on the edge side, so an empty edge segment still
        crosses the wire entering the cloud — the all-cloud plan's
        priced raw-event crossing."""
        if sides is None:
            sides = ["edge"] + ["cloud"] * (len(segments) - 1)
        prev_side = "edge"    # where the stream originates
        for idxs, side in zip(segments, sides):
            if not idxs:
                continue
            if side != prev_side and uplink is not None:
                # the batch crosses the edge<->cloud wire (uplink, or —
                # for downlink-ok consumers — the cloud->edge downlink):
                # apply the link codec's round-trip.
                batch = uplink(batch)
            prev_side = side
            sub = {self.ops[i].name: states[self.ops[i].name] for i in idxs}
            fn = self._segment_fn(tuple(idxs), batch)
            sub, batch = fn(sub, batch)
            states = {**states, **sub}
        return states, batch

    def run(self, states: Dict[str, Any], batch: Batch,
            frontier: Iterable[str] = (),
            uplink: Optional[Callable[[Batch], Batch]] = None
            ) -> Tuple[Dict[str, Any], Batch]:
        """Execute under the downward-closed cut ``frontier``: member ops
        form the edge segment, the rest the cloud segment (either may be
        empty); within each segment ops run in graph list order.
        ``uplink`` (optional) transforms the batch dict where it crosses
        between the sides — the orchestrator passes the SLA-chosen
        uplink codec's wire round-trip here.

        A frontier that is downward-closed under the *full* hazard
        relation runs as the historical two segments (edge then cloud —
        one wire crossing). A frontier admitted only by the relaxed
        closure (downlink-ok ops with cloud-resident flow parents, e.g.
        edge-decode under cloud-prefill) cannot be grouped that way
        without reordering a flow edge, so it executes as maximal
        same-side runs in graph list order — always a valid topological
        linearization — and the wire codec applies on every side
        change, pricing the downlink crossing too."""
        f = self.check_frontier(frontier)
        edge = tuple(i for i, op in enumerate(self.ops) if op.name in f)
        eset = frozenset(edge)
        if all(self._parents[i] <= eset for i in edge):
            cloud = tuple(i for i in range(len(self.ops)) if i not in eset)
            return self._run_segments(states, batch, (edge, cloud), uplink)
        segments: List[List[int]] = []
        sides: List[str] = []
        for i in range(len(self.ops)):
            side = "edge" if i in eset else "cloud"
            if sides and sides[-1] == side:
                segments[-1].append(i)
            else:
                segments.append([i])
                sides.append(side)
        return self._run_segments(
            states, batch, [tuple(s) for s in segments], uplink, sides)

    def run_reference(self, states: Dict[str, Any], batch: Batch
                      ) -> Tuple[Dict[str, Any], Batch]:
        """Unpartitioned execution: every op in one (cloud) segment, in
        graph list order — under the default ``fuse="op"`` this is the
        composition of the shared per-op executables (one jit *per op*,
        not one fused program; use ``fuse="xla"`` for whole-segment jit).
        Any downward-closed cut must reproduce this bitwise."""
        return self.run(states, batch, frontier=())


class Pipeline(OpGraph):
    """An ordered list of :class:`Op`, executable under any prefix cut —
    the linear special case of :class:`OpGraph`.

    The dependency structure is the chain itself (op ``i`` precedes op
    ``i+1``), so frontiers are exactly the prefixes and placement reduces
    to the prefix-cut search; channel declarations are not required, and
    each op receives the full batch dict exactly as before."""

    def __init__(self, ops: Sequence[Op], fuse: str = "op"):
        ops = tuple(ops)
        if not ops:
            raise ValueError("pipeline needs at least one op")
        super().__init__(ops, fuse=fuse)

    def _build_deps(self):
        # a chain: each op depends on its predecessor; bytes flow along
        # consecutive edges and the raw stream enters at ops[0].
        n = len(self.ops)
        self._parents = tuple(frozenset(() if i == 0 else (i - 1,))
                              for i in range(n))
        # prefix cuts only: the linear chain keeps the strict relation
        # even for downlink-ok ops (a non-prefix edge set has no `cut`).
        self._closure = self._parents
        self._flow_pairs = tuple((i, i + 1) for i in range(n - 1))
        self.flow_edges = tuple((self.ops[i].name, self.ops[i + 1].name)
                                for i in range(n - 1))
        self.source_reads = ()
        self.source_consumers = (self.ops[0].name,)

    @property
    def source_bytes_per_event(self) -> float:
        return self.cost_of(self.ops[0].name).bytes_per_event

    @property
    def n_cuts(self) -> int:
        """Valid cuts are 0..len(ops): ops[:k] edge, ops[k:] cloud."""
        return len(self.ops) + 1

    def _apply(self, i: int, states: Dict[str, Any], env: Batch,
               call: Optional[Callable] = None
               ) -> Tuple[Dict[str, Any], Batch]:
        # linear threading: the op sees (and returns) the full batch dict,
        # no channel restriction — byte-compatible with undeclared ops.
        op = self.ops[i]
        st, env = (call or self._op_fn(i))(states[op.name], env)
        states[op.name] = st
        return states, env

    def run(self, states: Dict[str, Any], batch: Batch, cut: int,
            uplink: Optional[Callable[[Batch], Batch]] = None
            ) -> Tuple[Dict[str, Any], Batch]:
        """Execute under prefix cut ``cut``: ops[:cut] as the edge segment,
        ops[cut:] as the cloud segment (either may be empty). ``uplink``
        (optional) transforms the batch where it crosses the segments —
        the orchestrator's codec hook."""
        if not 0 <= cut <= len(self.ops):
            raise ValueError(f"cut {cut} outside [0, {len(self.ops)}]")
        return self._run_segments(
            states, batch, (tuple(range(0, cut)),
                            tuple(range(cut, len(self.ops)))), uplink)

    def run_reference(self, states: Dict[str, Any], batch: Batch
                      ) -> Tuple[Dict[str, Any], Batch]:
        """Unpartitioned execution: the whole chain as one (cloud) segment
        — under the default ``fuse="op"`` that is the per-op composition
        at cut 0, not a single fused jit (``fuse="xla"`` fuses it). Any
        cut must reproduce this bitwise."""
        return self.run(states, batch, cut=0)


# ---------------------------------------------------------------------------
# Standard op wrappers around streams/ and ml/ — the same functions the
# hard-coded orchestrator stages used to call, now declared as IR nodes
# with named channels so they compose into DAGs as well as chains.
# ---------------------------------------------------------------------------

def _ev(dim: int) -> float:
    return 4.0 * dim        # fp32 bytes per event at width `dim`


def normalize_op(dim: int) -> Op:
    """Welford running normalization (edge preprocessing)."""
    def fn(state, batch):
        state, xn = prep.norm_update_apply(state, batch["x"])
        return state, {**batch, "x": xn}
    cost = OperatorCost("normalize", flops_per_event=50 * dim,
                        bytes_per_event=4 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("normalize", fn, cost, init=lambda: prep.norm_init(dim),
              reads=("x",), writes=("x",))


def sketch_op(dim: int) -> Op:
    """Streaming moments sketch (edge-side summary; state-only sink)."""
    def fn(state, batch):
        return sk.moments_update(state, batch["x"]), batch
    cost = OperatorCost("sketch", flops_per_event=20 * dim,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("sketch", fn, cost, init=lambda: sk.moments_init(dim),
              reads=("x",), writes=())


def sample_op(dim: int, rate: float, reservoir_k: int = 256) -> Op:
    """Reservoir update + Bernoulli thinning; emits the keep `mask` and
    threads the stream `rng`."""
    def fn(state, batch):
        state = samp.reservoir_update(state, batch["x"], batch["y"])
        mask, rng = samp.bernoulli_thin(batch["rng"], batch["x"], rate)
        return state, {**batch, "mask": mask, "rng": rng}
    cost = OperatorCost("sample", flops_per_event=20,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim) * rate)
    return Op("sample", fn, cost,
              init=lambda: samp.reservoir_init(reservoir_k, dim),
              reads=("x", "y", "rng"), writes=("mask", "rng"))


def logreg_train_op(dim: int, lr: float = 0.5,
                    flops_per_event: float = 2e6) -> Op:
    """Prequential test-then-train online logistic regression. Predicts on
    the full batch, updates on the sampled (masked) rows, and writes the
    per-event error stream for a downstream drift op."""
    def fn(state, batch):
        model, preq = state
        x, y = batch["x"], batch["y"]
        p = online.logreg_predict(model, x)
        err = (jnp.where(p > 0.5, 1, 0) != y).astype(jnp.float32)
        preq = mmetrics.preq_update(preq, p, y)
        mask = batch.get("mask", jnp.ones(x.shape[:1], bool))
        w = mask.astype(jnp.float32)
        model = online.logreg_update(model, x * w[:, None], y * mask, lr=lr)
        return (model, preq), {**batch, "p": p, "err": err}
    # emits model/metric deltas, not events: the uplink-compressing stage.
    # Cheap rates place it on the edge (a paper-style pre-model); its
    # 2e6 flops/event saturate the edge pool near 1e6 ev/s, which is what
    # pushes the cut down (training offloads to cloud) under rate spikes.
    cost = OperatorCost("train", flops_per_event=flops_per_event,
                        bytes_per_event=20 * _ev(dim),
                        out_bytes_per_event=8.0)
    return Op("train", fn, cost,
              init=lambda: (online.logreg_init(dim), mmetrics.preq_init()),
              on_drift=lambda s: (online.logreg_reset_soft(s[0]), s[1]),
              metrics=lambda s: mmetrics.preq_metrics(s[1]),
              reads=("x", "y", "mask"), writes=("p", "err"))


def drift_op(detector: str = "ddm") -> Op:
    """Concept-drift detection over the op-emitted error stream. Model
    management is a cloud concern, so this op is not edge-capable (it
    also anchors at least one stage on the cloud pool)."""
    init_fn, step_fn = {
        "ddm": (drift_mod.ddm_init, drift_mod.ddm_step),
        "eddm": (drift_mod.eddm_init, drift_mod.eddm_step),
        "ph": (drift_mod.ph_init, drift_mod.ph_step),
        "adwin": (drift_mod.adwin_init, drift_mod.adwin_step),
    }[detector]

    def fn(state, batch):
        state, levels = jax.lax.scan(step_fn, state, batch["err"])
        drifted = jnp.any(levels == drift_mod.DRIFT)
        return state, {**batch, "drifted": drifted}
    cost = OperatorCost("drift", flops_per_event=50, bytes_per_event=64,
                        out_bytes_per_event=8, edge_capable=False)
    return Op("drift", fn, cost, init=init_fn,
              reads=("err",), writes=("drifted",))


# -- scenario-diversity ops -------------------------------------------------

def hash_op(dim: int, seed: int = 17) -> Op:
    """Signed feature hashing: sparse (ids, vals) -> dense x."""
    def fn(state, batch):
        x = prep.hash_features(batch["ids"], batch["vals"], dim, seed=seed)
        out = {k: v for k, v in batch.items() if k not in ("ids", "vals")}
        return state, {**out, "x": x}
    cost = OperatorCost("hash", flops_per_event=10 * dim,
                        bytes_per_event=2 * _ev(dim),
                        out_bytes_per_event=_ev(dim))
    return Op("hash", fn, cost,
              reads=("ids", "vals"), writes=("x",), deletes=("ids", "vals"))


def pca_op(dim: int, k: int, lr: float = 1e-2, seed: int = 0) -> Op:
    """Streaming PCA (Oja's rule): project x from `dim` to `k` dims."""
    def fn(state, batch):
        state, z = prep.oja_update_project(state, batch["x"], lr=lr)
        return state, {**batch, "x": z}
    cost = OperatorCost("pca", flops_per_event=4 * dim * k,
                        bytes_per_event=6 * _ev(dim),
                        out_bytes_per_event=4.0 * k)
    return Op("pca", fn, cost, init=lambda: prep.oja_init(dim, k, seed),
              reads=("x",), writes=("x",))


def concat_op(key: str, out_dim: int) -> Op:
    """Concatenate a fused column (e.g. a WindowJoin output) onto x —
    the fusion-fed pipeline entry point."""
    def fn(state, batch):
        x = jnp.concatenate([batch["x"], batch[key]], axis=-1)
        out = {k: v for k, v in batch.items() if k != key}
        return state, {**out, "x": x}
    cost = OperatorCost("concat", flops_per_event=2 * out_dim,
                        bytes_per_event=2 * _ev(out_dim),
                        out_bytes_per_event=_ev(out_dim))
    return Op("concat", fn, cost,
              reads=("x", key), writes=("x",), deletes=(key,))


def anomaly_op(dim: int, m: int = 8, seed: int = 0) -> Op:
    """Random-projection histogram anomaly scorer; writes `score`."""
    def fn(state, batch):
        state = online.anomaly_update(state, batch["x"])
        score = online.anomaly_score(state, batch["x"])
        return state, {**batch, "score": score}
    cost = OperatorCost("anomaly", flops_per_event=2 * dim * m,
                        bytes_per_event=4 * _ev(dim),
                        out_bytes_per_event=4.0)
    return Op("anomaly", fn, cost,
              init=lambda: online.anomaly_init(dim, m=m, seed=seed),
              reads=("x",), writes=("score",))


def alert_op(threshold: float = 3.0) -> Op:
    """Rejoin head: fuses the anomaly branch's `score` with the learner
    branch's `drifted` flag into a per-batch `alert` — the downstream
    consumer a fan-out graph re-converges on."""
    def fn(state, batch):
        hot = jnp.mean((batch["score"] > threshold).astype(jnp.float32))
        alert = jnp.logical_or(hot > 0.5, batch["drifted"])
        return state, {**batch, "alert": alert}
    cost = OperatorCost("alert", flops_per_event=4, bytes_per_event=16,
                        out_bytes_per_event=1.0)
    return Op("alert", fn, cost, reads=("score", "drifted"),
              writes=("alert",))


def standard_stream_pipeline(dim: int, sample_rate: float = 0.5,
                             drift_detector: str = "ddm",
                             reservoir_k: int = 256,
                             fuse: str = "op") -> Pipeline:
    """The default S2CE job: normalize -> sketch -> sample -> train -> drift
    (the op-graph form of the orchestrator's old hard-coded stages).

    ``fuse="op"`` (default) keeps every cut bitwise-identical to the
    reference — required when the placement migrates live state — and is
    the measured winner on CPU (``pipeline_step_cut4_xla`` in the perf
    trajectory tracks the ratio; ~0.94x there, so whole-segment fusion
    buys nothing for these small ops). ``fuse="xla"`` jits each segment
    as one fused program: pick it only where the trajectory row shows a
    win on your backend AND the placement is static (cuts are only
    allclose under fusion, not bitwise)."""
    return Pipeline([
        normalize_op(dim),
        sketch_op(dim),
        sample_op(dim, sample_rate, reservoir_k),
        logreg_train_op(dim),
        drift_op(drift_detector),
    ], fuse=fuse)


def fanout_stream_graph(dim: int, sample_rate: float = 0.5,
                        drift_detector: str = "ddm",
                        reservoir_k: int = 256,
                        anomaly_threshold: float = 3.0,
                        fuse: str = "op") -> OpGraph:


    """The Fig. 2 fan-out/rejoin workflow a linear pipeline cannot express:

    ::

        normalize ──> sketch                      (summary branch)
              ├─────> anomaly ──────────┐         (scoring branch)
              └─────> sample -> train -> drift    (learner branch)
                                 score │  │ drifted
                                       └──┴─> alert

    The normalized stream fans out to a moments sketch, an anomaly
    scorer, and a sample->train->drift learner chain; the anomaly and
    learner branches rejoin at the alert head. Because the branches are
    dependency-independent, a frontier cut can keep e.g. `anomaly` on
    the edge while `train` offloads to the cloud — an assignment no
    prefix cut of any op ordering can produce.

    ``fuse`` as in :func:`standard_stream_pipeline`: "op" (default) for
    bitwise cut-invariance, "xla" for fused-segment throughput."""
    return OpGraph([
        normalize_op(dim),
        sketch_op(dim),
        anomaly_op(dim),
        sample_op(dim, sample_rate, reservoir_k),
        logreg_train_op(dim),
        drift_op(drift_detector),
        alert_op(anomaly_threshold),
    ], fuse=fuse)
