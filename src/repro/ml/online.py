"""Streaming (non-DL) learners — the S2CE ML library layer (§2.4, §5.5).

All learners are (state, batch) -> state pure functions with a `predict`;
they run identically on edge (pre-models) and cloud, are jit-compiled, and
their per-update latency is the S2 "microsecond updates" benchmark.

  * online logistic regression (SGD / AdaGrad), drift-resettable
  * streaming k-means (MacQueen / mini-batch)
  * half-space-trees-style anomaly scorer (random projection histograms)
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Online logistic regression
# ---------------------------------------------------------------------------

class LogRegState(NamedTuple):
    w: jax.Array          # (d,)
    b: jax.Array
    g2: jax.Array         # AdaGrad accumulator
    n: jax.Array


def logreg_init(dim: int) -> LogRegState:
    return LogRegState(jnp.zeros((dim,)), jnp.zeros(()),
                       jnp.full((dim,), 1e-8), jnp.zeros(()))


def logreg_predict(state: LogRegState, x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x @ state.w + state.b)


def logreg_update(state: LogRegState, x: jax.Array, y: jax.Array,
                  lr: float = 0.5, l2: float = 1e-4) -> LogRegState:
    """One AdaGrad step on a batch. x: (n,d); y: (n,) in {0,1}."""
    p = logreg_predict(state, x)
    err = p - y.astype(jnp.float32)
    gw = x.T @ err / x.shape[0] + l2 * state.w
    gb = err.mean()
    g2 = state.g2 + jnp.square(gw)
    w = state.w - lr * gw * jax.lax.rsqrt(g2)
    b = state.b - lr * gb
    return LogRegState(w, b, g2, state.n + x.shape[0])


def logreg_reset_soft(state: LogRegState, keep: float = 0.5) -> LogRegState:
    """Drift response: shrink weights toward zero, reset curvature."""
    return LogRegState(state.w * keep, state.b * keep,
                       jnp.full_like(state.g2, 1e-8), jnp.zeros(()))


# ---------------------------------------------------------------------------
# Streaming k-means
# ---------------------------------------------------------------------------

class KMeansState(NamedTuple):
    centers: jax.Array    # (k, d)
    counts: jax.Array     # (k,)


def kmeans_init(k: int, dim: int, seed: int = 0) -> KMeansState:
    c = jax.random.normal(jax.random.PRNGKey(seed), (k, dim))
    return KMeansState(c, jnp.ones((k,)))


def kmeans_assign(state: KMeansState, x: jax.Array) -> jax.Array:
    d2 = jnp.sum(jnp.square(x[:, None, :] - state.centers[None]), -1)
    return jnp.argmin(d2, axis=-1)


def kmeans_update(state: KMeansState, x: jax.Array) -> KMeansState:
    a = kmeans_assign(state, x)
    k = state.centers.shape[0]
    one = jax.nn.one_hot(a, k, dtype=x.dtype)            # (n, k)
    batch_counts = one.sum(0)
    batch_sums = one.T @ x
    counts = state.counts + batch_counts
    centers = state.centers + (batch_sums - batch_counts[:, None]
                               * state.centers) / jnp.maximum(counts, 1.0)[:, None]
    return KMeansState(centers, counts)


# ---------------------------------------------------------------------------
# Anomaly scoring via random-projection histograms (HS-trees flavour)
# ---------------------------------------------------------------------------

class AnomalyState(NamedTuple):
    proj: jax.Array       # (d, m) random projections
    edges: jax.Array      # (m, bins+1) histogram edges
    counts: jax.Array     # (m, bins)
    n: jax.Array


def anomaly_init(dim: int, m: int = 8, bins: int = 32, span: float = 4.0,
                 seed: int = 0) -> AnomalyState:
    proj = jax.random.normal(jax.random.PRNGKey(seed), (dim, m)) / jnp.sqrt(dim)
    edges = jnp.linspace(-span, span, bins + 1)
    return AnomalyState(proj, jnp.tile(edges[None], (m, 1)),
                        jnp.ones((m, bins)), jnp.zeros(()))


def anomaly_update(state: AnomalyState, x: jax.Array) -> AnomalyState:
    z = x @ state.proj                                    # (n, m)
    bins = state.counts.shape[1]
    idx = jnp.clip(jnp.searchsorted(state.edges[0], z) - 1, 0, bins - 1)
    one = jax.nn.one_hot(idx, bins, dtype=jnp.float32)    # (n, m, bins)
    return state._replace(counts=state.counts + one.sum(0),
                          n=state.n + x.shape[0])


def anomaly_score(state: AnomalyState, x: jax.Array) -> jax.Array:
    """Mean negative log-frequency across projections; higher = more anomalous."""
    z = x @ state.proj
    bins = state.counts.shape[1]
    idx = jnp.clip(jnp.searchsorted(state.edges[0], z) - 1, 0, bins - 1)
    freq = jnp.take_along_axis(
        state.counts[None], idx.swapaxes(0, 1)[..., None].swapaxes(0, 1), axis=2
    )[..., 0] / jnp.maximum(state.counts.sum(-1), 1.0)[None]
    return -jnp.log(freq + 1e-9).mean(-1)
