"""Prequential (test-then-train) evaluation for streaming models (§2.4)."""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class PrequentialState(NamedTuple):
    n: jax.Array
    correct: jax.Array
    loss_sum: jax.Array
    ewma_acc: jax.Array   # fading-factor accuracy (tracks drift recovery)


def preq_init() -> PrequentialState:
    return PrequentialState(jnp.zeros(()), jnp.zeros(()), jnp.zeros(()),
                            jnp.asarray(0.5))


def preq_update(st: PrequentialState, p: jax.Array, y: jax.Array,
                fading: float = 0.995) -> PrequentialState:
    """p: (n,) predicted probability of class 1; y: (n,) labels."""
    yhat = (p > 0.5).astype(jnp.int32)
    acc_b = jnp.mean((yhat == y).astype(jnp.float32))
    ll = -jnp.mean(y * jnp.log(p + 1e-9) + (1 - y) * jnp.log(1 - p + 1e-9))
    n = st.n + p.shape[0]
    correct = st.correct + acc_b * p.shape[0]
    decay = fading ** p.shape[0]
    ewma = decay * st.ewma_acc + (1 - decay) * acc_b
    return PrequentialState(n, correct, st.loss_sum + ll * p.shape[0], ewma)


def preq_metrics(st: PrequentialState) -> dict:
    return {
        "accuracy": float(st.correct / jnp.maximum(st.n, 1.0)),
        "logloss": float(st.loss_sum / jnp.maximum(st.n, 1.0)),
        "ewma_accuracy": float(st.ewma_acc),
        "n": int(st.n),
    }
