"""Dynamic topology: pools join, leave, and FAIL mid-stream
(core/membership — S2CE's elastic hybrid cloud/edge axis).

A :class:`MembershipDirectory` owns the authoritative, versioned
ClusterSpec. The run starts on a static edge+cloud seed, then:

* two edge pools ``register()`` mid-run with locality metadata — the
  orchestrator replans onto the better one the moment it joins,
* latency probes (EWMA) keep rewriting the directory's link table from
  measurements, so the placement DP prices real latencies, not the
  declared priors,
* one pool goes SILENT mid-ramp: its heartbeat lease expires, the
  directory declares it dead, and the orchestrator recovers through the
  involuntary checkpoint-rescale cycle + a forced replan that excludes
  the dead pool — all on the deterministic sim clock (no wall time).

  PYTHONPATH=src python examples/dynamic_topology.py
"""

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.membership import Locality, MembershipDirectory
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.sla import SLA
from repro.streams.generators import HyperplaneStream

STEPS = 16
RATE = 1e4


def main():
    # -- seed topology: one gateway edge + one cloud pod -------------------
    seed = cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=2e6, latency=20e-3)])
    directory = MembershipDirectory(seed, lease_ticks=3)
    print(f"== seed directory ==\n  {directory!r}")

    # -- two edge pools join with locality metadata ------------------------
    print("\n== registrations ==")
    for name, loc, flops, link in [
        ("edge_rack", Locality(0.5, 0.0, region="metro"), 4e12,
         cm.Link("edge_rack", "cloud", bw=8e6, latency=5e-3)),
        ("edge_far", Locality(120.0, 90.0, region="rural"), 1e12,
         cm.Link("edge_far", "cloud", bw=1e6, latency=60e-3)),
    ]:
        ev = directory.register(
            cm.Resource(name, "edge", chips=2, flops=flops, mem_bw=100e9,
                        mem_cap=8e9, net_bw=1e9, net_latency=5e-3),
            links=[link], locality=loc, now=0)
        print(f"  v{ev.version} {ev.kind:12s} {ev.subject:10s} {ev.detail}")

    # latency probes refine the rack uplink from measurements
    for t in range(3):
        directory.observe_latency("edge_rack", "cloud", 4e-3, now=0)
    est = directory.probe_estimate("edge_rack", "cloud")
    print(f"  probe edge_rack->cloud EWMA latency {est * 1e3:.2f} ms")

    # -- the job: a DAG pipeline over the LIVE directory -------------------
    job = StreamJob("dyn", dim=8, sla=SLA(max_latency_s=1e3,
                                          error_budget=11.0),
                    pipeline=pl.fanout_stream_graph(8), membership=directory,
                    sla_window=6)
    orch = Orchestrator(job)
    gen = HyperplaneStream(dim=8, seed=0, horizon=STEPS * 32.0)

    def stream():
        for step in range(STEPS):
            # edge_rack heartbeats for the first half of the run, then
            # goes SILENT — a failure, not a polite deregistration
            if step <= STEPS // 2:
                directory.heartbeat("edge_rack", now=step)
            directory.heartbeat("edge_far", now=step)
            yield gen.batch(step, 32)

    print(f"\n== run: {STEPS} steps, edge_rack dies silently at "
          f"t={STEPS // 2} (lease={directory.lease_ticks}) ==")
    metrics = orch.run(stream(), rate_fn=lambda s: RATE)

    print("\n  control trajectory:")
    for line in metrics.decisions:
        print(f"    {line}")

    # -- recovery report ---------------------------------------------------
    print("\n== recovery ==")
    final_pools = sorted(set(orch._exec_assignment.values()))
    print(f"  directory now {directory!r}")
    print(f"  final plan pools: {final_pools} "
          f"(edge_rack excluded: {'edge_rack' not in final_pools})")
    print(f"  events={metrics.events} migrations={metrics.migrations} "
          f"rescales={metrics.rescales}")
    print(f"  windowed SLA ok after recovery: {orch.sla.ok()}")


if __name__ == "__main__":
    main()
