"""Multi-pool placement over a ClusterSpec topology + SLA-driven uplink
codecs + dynamic offloading under a traffic burst (S2CE O2, S3) — plus
the straggler-tolerant feeder.

The cluster is declared as a first-class topology: named Resource pools
(here 2 edge pools + 2 cloud pods) and explicit directed Links carrying
bandwidth, latency, and an uplink codec. ``place_frontier`` assigns each
side of a downward-closed frontier cut across *all* pools of its kind,
pricing every crossing link with codec-compressed bytes and DAG latency
as the critical path.

The old two-pool style — a flat ``{"edge": ..., "cloud": ...}`` dict
collapsed by the ``edge_cloud_pools`` shim to the first pool of each
kind — still works everywhere but is DEPRECATED: it ignores extra pools
and their links. Prefer building a ``ClusterSpec``.

The final section demonstrates **rate-adaptive codec control**: the
offload controller re-runs codec admission on every replan from
windowed SLA telemetry, escalating the uplink codec when the link
saturates and de-escalating toward lossless on recovery — printing the
codec trajectory of a saturating rate ramp.

  PYTHONPATH=src python examples/edge_cloud_pipeline.py
"""

from repro.core import costmodel as cm
from repro.core import pipeline as pl
from repro.core.offload import OffloadController
from repro.core.placement import (Objective, place, place_frontier,
                                  place_graph_exhaustive, standard_pipeline)
from repro.core.sla import SLA, SLATracker, pick_codec
from repro.streams.feeder import StreamFeeder
from repro.streams.generators import HyperplaneStream


def build_cluster(codec: str = "identity") -> cm.ClusterSpec:
    """A 2-edge-pool + 2-cloud-pod topology with per-link codecs:
    a gateway-class edge pool, a weaker far-edge pool, the main pod, and
    a smaller regional pod."""
    far_edge = cm.Resource("far_edge", "edge", chips=1, flops=1e12,
                           mem_bw=40e9, mem_cap=2e9, net_bw=0.5e9,
                           net_latency=35e-3, energy_w=10.0)
    regional = cm.Resource("regional", "cloud", chips=64,
                           net_latency=0.5e-3, energy_w=220.0)
    return cm.ClusterSpec(
        pools=[cm.EDGE_NODE, far_edge, cm.CLOUD_POD, regional],
        links=[
            cm.Link("edge", "cloud", bw=1e9, latency=20e-3, codec=codec),
            cm.Link("edge", "regional", bw=0.8e9, latency=15e-3,
                    codec=codec),
            cm.Link("far_edge", "cloud", bw=0.5e9, latency=35e-3,
                    codec=codec),
            cm.Link("far_edge", "regional", bw=0.5e9, latency=30e-3,
                    codec=codec),
            cm.Link("edge", "far_edge", bw=2e9, latency=5e-3),
        ])


def main():
    # -- SLA-driven codec admission ---------------------------------------
    print("== SLA error budget -> cheapest admissible uplink codec ==")
    for budget in (0.0, 0.1, 11.0):
        c = pick_codec(SLA(error_budget=budget))
        print(f"  budget {budget:5.2f} -> {c.name:13s} "
              f"(wire ratio {c.ratio:.3f}, tested bound {c.error_bound:.4f})")
    codec = pick_codec(SLA(error_budget=11.0))

    # -- multi-pool frontier placement across ingest rates ----------------
    cluster = build_cluster(codec.name)
    print(f"\n== {cluster} ==")
    g = pl.fanout_stream_graph(dim=16)
    print("== multi-pool frontier placement across ingest rates ==")
    for rate in (1e3, 1e5, 1e6):
        plan, frontier = place_frontier(g, cluster, rate,
                                        Objective(energy_weight=0.1))
        oracle = place_graph_exhaustive(g, cluster, rate,
                                        Objective(energy_weight=0.1))
        obj = Objective(energy_weight=0.1)
        pools_used = sorted(set(plan.assignment.values()))
        print(f"rate {rate:9.0f} ev/s -> edge={sorted(frontier) or ['-']}")
        print(f"    pools={pools_used} latency={plan.latency_s*1e3:6.2f}ms "
              f"uplink={plan.uplink_utilization:6.4f} "
              f"feasible={plan.feasible} "
              f"oracle_match={obj.score(plan) <= obj.score(oracle)*1.0001}")

    # -- deprecated two-pool shim (still works, collapses the topology) ---
    print("\n== deprecated flat-dict path (first pool of each kind) ==")
    resources = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    ops = standard_pipeline(dim=64, sample_rate=0.25)
    for rate in (1e3, 1e6):
        plan, cut = place(ops, resources, rate, Objective(energy_weight=0.1))
        print(f"  rate {rate:9.0f} ev/s -> prefix cut {cut} "
              f"latency={plan.latency_s * 1e3:6.2f}ms")

    # -- dynamic offload under a 40x burst, multi-pool plan identity ------
    print("\n== dynamic offload under a 40x burst ==")
    ctl = OffloadController(g.costs(), cluster, graph=g, cooldown=2,
                            codec=codec.name)
    sla = SLATracker(SLA(max_latency_s=0.05))
    ctl.initial_plan(5e3)
    rates = [5e3] * 10 + [2e5] * 10 + [5e3] * 10      # burst in the middle
    for step, rate in enumerate(rates):
        d = ctl.observe(step, rate, sla)
        if d.reason != "hold":
            print(f"step {step:3d}: rate={rate:9.0f} -> {d.reason:9s} "
                  f"edge={sorted(d.frontier) or ['-']} codec={d.codec}")
    print(f"total migrations: {ctl.migrations()}")

    # -- rate-adaptive codec control: re-admission at replan time ---------
    # The uplink codec is a runtime control dimension: on every replan
    # the controller re-runs codec admission against the windowed SLA
    # report + the modeled saturation of the incumbent plan, escalating
    # to cheaper wire when the uplink saturates and de-escalating toward
    # lossless when the link has headroom (hysteresis band + cooldown
    # stop codec flapping). Links here declare no codec, so the blanket
    # candidate actually gets to move.
    print("\n== rate-adaptive uplink codec under a saturating rate ramp ==")
    pipe = pl.standard_stream_pipeline(dim=8, sample_rate=0.5)
    adaptive_sla = SLA(max_latency_s=1e3, error_budget=11.0)
    # a rate-aware initial pick: with no bandwidth pressure the most
    # faithful admissible codec wins (lossless), unlike the static
    # cheapest-wire admission above
    start = pick_codec(adaptive_sla, report={"uplink_utilization": 0.0,
                                             "violation_rate": 0.0})
    actl = OffloadController(
        pipe.costs(), cm.ClusterSpec.edge_cloud(), graph=pipe,
        codec=start.name, sla_spec=adaptive_sla,
        cooldown=2, codec_cooldown=4)
    ramp = [1e4] * 6 + [8e7] * 6 + [1e4] * 6       # saturate, then recover
    actl.initial_plan(ramp[0])
    for step, rate in enumerate(ramp):
        d = actl.observe(step, rate)
        if d.reason != "hold":
            print(f"step {step:3d}: rate={rate:9.0f} -> {d.reason:9s} "
                  f"codec={d.codec:13s} "
                  f"uplink={d.plan.uplink_utilization:6.3f} "
                  f"edge={sorted(d.frontier) or ['-']}")
    traj = [d.codec for d in actl.history]
    compact = [traj[0]] + [b for a, b in zip(traj, traj[1:]) if a != b]
    print(f"codec trajectory: {' -> '.join(compact)}")
    assert len(compact) >= 3, "ramp should escalate and de-escalate"

    print("\n== straggler-tolerant feeding ==")
    def make(shard, idx, n):
        return HyperplaneStream(dim=8, seed=shard).batch(idx, n)
    feeder = StreamFeeder(
        make, n_shards=4, batch_per_shard=256, deadline_s=0.05,
        inject_straggle=lambda s, i: 0.2 if (s == 2 and i % 3 == 1) else 0.0)
    feeder.start()
    for _ in range(6):
        b = feeder.next()
    feeder.stop()
    print(f"batches={feeder.stats.batches} "
          f"straggler_rescues={feeder.stats.straggler_rescues} "
          f"(deterministic replay, no data loss)")
    assert feeder.stats.straggler_rescues >= 1
    print("\nOK")


if __name__ == "__main__":
    main()
