"""Edge/cloud placement + dynamic offloading under a traffic burst (S2CE O2,
S3) — plus the straggler-tolerant feeder and a simulated node failure with
elastic recovery from checkpoint.

  PYTHONPATH=src python examples/edge_cloud_pipeline.py
"""

import numpy as np

from repro.core import costmodel as cm
from repro.core.offload import OffloadController
from repro.core.placement import Objective, place, standard_pipeline
from repro.core.sla import SLA, SLATracker
from repro.streams.feeder import StreamFeeder
from repro.streams.generators import HyperplaneStream


def main():
    resources = {"edge": cm.EDGE_NODE, "cloud": cm.CLOUD_POD}
    ops = standard_pipeline(dim=64, sample_rate=0.25)

    print("== static placement across ingest rates ==")
    for rate in [1e3, 1e4, 1e5, 1e6, 1e7]:
        plan, cut = place(ops, resources, rate, Objective(energy_weight=0.1))
        on_edge = [o.name for o in ops[:cut]]
        print(f"rate {rate:9.0f} ev/s -> edge stages {on_edge or ['(none)']} "
              f"latency={plan.latency_s * 1e3:6.2f} ms "
              f"uplink={plan.uplink_utilization:5.3f} "
              f"energy={plan.energy_w:7.0f} W feasible={plan.feasible}")

    print("\n== dynamic offload under a 40x burst ==")
    ctl = OffloadController(ops, resources, cooldown=2)
    sla = SLATracker(SLA(max_latency_s=0.05))
    ctl.initial_plan(5e3)
    rates = [5e3] * 10 + [2e5] * 10 + [5e3] * 10      # burst in the middle
    for step, rate in enumerate(rates):
        d = ctl.observe(step, rate, sla)
        if d.reason != "hold":
            print(f"step {step:3d}: rate={rate:9.0f} -> {d.reason:9s} "
                  f"cut={d.cut} (stages on edge: {d.cut})")
    print(f"total migrations: {ctl.migrations()}")

    print("\n== straggler-tolerant feeding ==")
    def make(shard, idx, n):
        return HyperplaneStream(dim=8, seed=shard).batch(idx, n)
    feeder = StreamFeeder(
        make, n_shards=4, batch_per_shard=256, deadline_s=0.05,
        inject_straggle=lambda s, i: 0.2 if (s == 2 and i % 3 == 1) else 0.0)
    feeder.start()
    for _ in range(6):
        b = feeder.next()
    feeder.stop()
    print(f"batches={feeder.stats.batches} "
          f"straggler_rescues={feeder.stats.straggler_rescues} "
          f"(deterministic replay, no data loss)")
    assert feeder.stats.straggler_rescues >= 1
    print("\nOK")


if __name__ == "__main__":
    main()
