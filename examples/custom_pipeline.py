"""Defining custom operator pipelines and DAGs for the S2CE orchestrator.

The operator-DAG IR (repro/core/pipeline.py) makes the orchestrator's job
graph user-composable: every stage is an ``Op`` — a pure
``(state, batch) -> (state, batch)`` function, a cost profile, and (for
graph composition) its named channels: the batch keys it reads, writes,
and deletes. Two containers share one placement/execution machinery:

  * ``Pipeline`` — the linear special case: an ordered op list whose
    cuts are the prefixes ``ops[:k]`` (channel declarations optional);
  * ``OpGraph`` — a dataflow graph whose dependency edges are inferred
    from the channel declarations. It partitions at any *frontier*
    (downward-closed op set): the frontier runs on the edge pool, the
    rest on the cloud pool, and the cost model prices the uplink per
    crossing edge. Parallel branches can therefore be split
    independently — an assignment no prefix cut can express.

The cut/frontier is chosen (and re-chosen) by the cost model at runtime;
under the default ``fuse="op"`` mode any partition is bitwise-identical
to the unpartitioned reference.

This example builds four jobs:

  1. the standard supervised chain (what ``StreamJob`` defaults to),
  2. an unsupervised hashing -> streaming-PCA -> sketch volume reducer,
  3. a fully custom op written from scratch (EWMA smoother),
  4. the fan-out/rejoin DAG: normalize fans out to {sketch, anomaly,
     sample -> train -> drift} and the anomaly + learner branches rejoin
     at an alert head.

  PYTHONPATH=src python examples/custom_pipeline.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import pipeline as pl
from repro.core.costmodel import CLOUD_POD, EDGE_NODE, OperatorCost
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.core.placement import place_frontier, place_graph_exhaustive
from repro.streams.events import StreamBatch
from repro.streams.generators import HyperplaneStream


# ---------------------------------------------------------------------------
# A custom Op from scratch: exponential smoothing of the feature stream.
#
# Rules of the game:
#   * fn is PURE and jit-compatible: state/batch in, state/batch out.
#     The batch is a dict of arrays; read the keys you need, write the
#     keys you produce (downstream ops see them).
#   * init() builds the initial state (any pytree; () if stateless).
#   * cost describes per-event work so placement can price the op.
#   * reads/writes declare the op's channels. A linear Pipeline works
#     without them; an OpGraph requires them (they define the edges).
# ---------------------------------------------------------------------------

def ewma_op(dim: int, alpha: float = 0.1) -> pl.Op:
    def fn(state, batch):
        x = batch["x"]
        mean = state + alpha * (jnp.mean(x, axis=0) - state)
        return mean, {**batch, "x": x - mean[None, :]}

    cost = OperatorCost("ewma", flops_per_event=4 * dim,
                        bytes_per_event=8.0 * dim,
                        out_bytes_per_event=4.0 * dim)
    return pl.Op("ewma", fn, cost, init=lambda: jnp.zeros((dim,)),
                 reads=("x",), writes=("x",))


def main():
    # -- 1. the default chain, explicit -----------------------------------
    dim = 16
    default = pl.standard_stream_pipeline(dim, sample_rate=0.5)
    print("default pipeline:", " -> ".join(default.names))

    gen = HyperplaneStream(dim=dim, seed=0, horizon=20 * 64.0)
    batches = [gen.batch(i, 64) for i in range(20)]
    m = Orchestrator(StreamJob("default", dim=dim)).run(
        batches, rate_fn=lambda s: 1e4)
    print(f"  accuracy={m.preq['accuracy']:.2f} cuts={sorted(set(m.cuts))}")

    # -- 2. unsupervised hashing -> PCA -> sketch -------------------------
    hp = pl.Pipeline([pl.hash_op(32), pl.pca_op(32, 4), pl.sketch_op(4)])
    print("hash/pca pipeline:", " -> ".join(hp.names))
    rng = np.random.default_rng(0)
    sparse = [StreamBatch(
        data={"ids": rng.integers(0, 10_000, (64, 8)).astype(np.int32),
              "vals": rng.normal(size=(64, 8)).astype(np.float32)},
        ts=np.arange(64) + 64.0 * i) for i in range(20)]
    orch = Orchestrator(StreamJob("hash-pca", dim=32, pipeline=hp))
    m = orch.run(sparse, rate_fn=lambda s: 1e4)
    print(f"  events={m.events} sketch_n={int(orch.states['sketch'].n)} "
          f"cuts={sorted(set(m.cuts))}")

    # -- 3. custom op spliced into a supervised chain ---------------------
    custom = pl.Pipeline([
        ewma_op(dim),
        pl.normalize_op(dim),
        pl.logreg_train_op(dim),
        pl.drift_op("ph"),
    ])
    print("custom pipeline:", " -> ".join(custom.names))
    m = Orchestrator(StreamJob("custom", dim=dim, pipeline=custom)).run(
        batches, rate_fn=lambda s: 1e4)
    print(f"  accuracy={m.preq['accuracy']:.2f} cuts={sorted(set(m.cuts))}")

    # -- 4. the fan-out/rejoin DAG ----------------------------------------
    g = pl.fanout_stream_graph(dim, sample_rate=0.5)
    print("fan-out graph:", " | ".join(
        f"{n}<-{{{','.join(sorted(g.parents_of(n)))}}}" for n in g.names))
    n_frontiers = sum(1 for _ in g.frontiers())
    print(f"  {n_frontiers} downward-closed cuts "
          f"(a {len(g.names)}-op chain would have {len(g.names) + 1})")

    res = {"edge": EDGE_NODE, "cloud": CLOUD_POD}
    for rate in (1e3, 1e5, 5e6):
        plan, frontier = place_frontier(g, res, rate)
        oracle = place_graph_exhaustive(g, res, rate)
        note = ("all plans infeasible (rate exceeds uplink); all-cloud "
                "fallback" if not plan.feasible else
                f"oracle_assign_match={oracle.assignment == plan.assignment}")
        print(f"  rate={rate:.0e}: edge={sorted(frontier) or ['-']} "
              f"uplink={plan.uplink_utilization:.2e} {note}")

    def rate_fn(step):
        return 1e3 if step < 10 else 5e6     # spike mid-stream

    m = Orchestrator(StreamJob("fanout", dim=dim, pipeline=g)).run(
        batches, rate_fn=rate_fn)
    frontiers_seen = sorted({tuple(sorted(f)) for f in m.assignments})
    print(f"  accuracy={m.preq['accuracy']:.2f} migrations={m.migrations}")
    for f in frontiers_seen:
        print(f"    executed frontier: {list(f) or ['(all-cloud)']}")

    print("\nOK")


if __name__ == "__main__":
    main()
