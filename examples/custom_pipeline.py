"""Defining custom operator pipelines for the S2CE orchestrator.

The pipeline IR (repro/core/pipeline.py) makes the orchestrator's job
graph user-composable: every stage is an ``Op`` — a pure
``(state, batch) -> (state, batch)`` function plus a cost profile — and
a ``Pipeline`` is an ordered op list the placement optimizer, offload
controller, and executor all share. Any prefix of the list can run on
the edge pool; the suffix runs on the cloud pool; the cut is chosen (and
re-chosen) by the cost model at runtime.

This example builds three jobs:

  1. the standard supervised chain (what ``StreamJob`` defaults to),
  2. an unsupervised hashing -> streaming-PCA -> sketch volume reducer,
  3. a fully custom op written from scratch (EWMA smoother).

  PYTHONPATH=src python examples/custom_pipeline.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import pipeline as pl
from repro.core.costmodel import OperatorCost
from repro.core.orchestrator import Orchestrator, StreamJob
from repro.streams.events import StreamBatch
from repro.streams.generators import HyperplaneStream


# ---------------------------------------------------------------------------
# A custom Op from scratch: exponential smoothing of the feature stream.
#
# Rules of the game:
#   * fn is PURE and jit-compatible: state/batch in, state/batch out.
#     The batch is a dict of arrays; read the keys you need, write the
#     keys you produce (downstream ops see them).
#   * init() builds the initial state (any pytree; () if stateless).
#   * cost describes per-event work so placement can price the op.
# ---------------------------------------------------------------------------

def ewma_op(dim: int, alpha: float = 0.1) -> pl.Op:
    def fn(state, batch):
        x = batch["x"]
        mean = state + alpha * (jnp.mean(x, axis=0) - state)
        return mean, {**batch, "x": x - mean[None, :]}

    cost = OperatorCost("ewma", flops_per_event=4 * dim,
                        bytes_per_event=8.0 * dim,
                        out_bytes_per_event=4.0 * dim)
    return pl.Op("ewma", fn, cost, init=lambda: jnp.zeros((dim,)))


def main():
    # -- 1. the default chain, explicit -----------------------------------
    dim = 16
    default = pl.standard_stream_pipeline(dim, sample_rate=0.5)
    print("default pipeline:", " -> ".join(default.names))

    gen = HyperplaneStream(dim=dim, seed=0, horizon=20 * 64.0)
    batches = [gen.batch(i, 64) for i in range(20)]
    m = Orchestrator(StreamJob("default", dim=dim)).run(
        batches, rate_fn=lambda s: 1e4)
    print(f"  accuracy={m.preq['accuracy']:.2f} cuts={sorted(set(m.cuts))}")

    # -- 2. unsupervised hashing -> PCA -> sketch -------------------------
    hp = pl.Pipeline([pl.hash_op(32), pl.pca_op(32, 4), pl.sketch_op(4)])
    print("hash/pca pipeline:", " -> ".join(hp.names))
    rng = np.random.default_rng(0)
    sparse = [StreamBatch(
        data={"ids": rng.integers(0, 10_000, (64, 8)).astype(np.int32),
              "vals": rng.normal(size=(64, 8)).astype(np.float32)},
        ts=np.arange(64) + 64.0 * i) for i in range(20)]
    orch = Orchestrator(StreamJob("hash-pca", dim=32, pipeline=hp))
    m = orch.run(sparse, rate_fn=lambda s: 1e4)
    print(f"  events={m.events} sketch_n={int(orch.states['sketch'].n)} "
          f"cuts={sorted(set(m.cuts))}")

    # -- 3. custom op spliced into a supervised chain ---------------------
    custom = pl.Pipeline([
        ewma_op(dim),
        pl.normalize_op(dim),
        pl.logreg_train_op(dim),
        pl.drift_op("ph"),
    ])
    print("custom pipeline:", " -> ".join(custom.names))
    m = Orchestrator(StreamJob("custom", dim=dim, pipeline=custom)).run(
        batches, rate_fn=lambda s: 1e4)
    print(f"  accuracy={m.preq['accuracy']:.2f} cuts={sorted(set(m.cuts))}")

    print("\nOK")


if __name__ == "__main__":
    main()
