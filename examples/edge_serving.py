"""DL serving on the pipeline substrate: cloud-prefill/edge-decode with
the KV cache as codec-governed uplink state (the PR-10 tentpole).

``serve/ops`` decomposes the ServeEngine into a two-op graph
``prefill -> decode`` whose single flow edge IS the KV-cache hop:

* the decode op's ``state_bytes`` (weights + live KV cache) is priced by
  the placement DP against each pool's ``mem_cap`` — an edge pool too
  small for the cache is provably excluded;
* decode declares ``OperatorCost.downlink_ok``, so ``{decode}`` is a
  legal frontier: prefill runs on the pod, the cache ships *down* the
  priced link, and decode runs at the edge — the split a saturated pod
  forces;
* executing the graph at that frontier goes through the engine's own
  jitted executables, so under the identity codec the output is bitwise
  identical to ``ServeEngine.run``;
* the KV codec ladder (``identity -> kv_int8 -> kv_latent``) plugs into
  the same SLA admission + offload-controller escalation loop the
  gradient codecs use: a saturating decode ramp escalates KV-cache
  compression, and recovery de-escalates back toward lossless.

  PYTHONPATH=src python examples/edge_serving.py
"""

import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.codecs import KV_CODECS
from repro.core.offload import OffloadController
from repro.core.placement import Objective, place_frontier
from repro.core.sla import SLA
from repro.models import model_zoo as zoo
from repro.serve.engine import Request, ServeEngine
from repro.serve.ops import serve_wave_batch, serving_graph
from repro.train.ops import dl_train_op
from repro.train.optim import adamw


def build_cluster(edge_mem: float = 4e9,
                  kv_link_bw: float = 2e7) -> cm.ClusterSpec:
    """One modest edge box and one *narrow* cloud pod: the pod's memory
    bandwidth saturates when it holds both serving phases at high rate,
    which is exactly what pushes decode out to the edge."""
    edge = cm.Resource("edge0", "edge", chips=1, flops=4e9, mem_bw=5e9,
                       mem_cap=edge_mem, net_bw=1e9)
    cloud = cm.Resource("cloud0", "cloud", chips=1, flops=1e13,
                        mem_bw=2.5e9, mem_cap=64e9, net_bw=100e9)
    return cm.ClusterSpec(
        pools=[edge, cloud],
        links=[cm.Link("edge0", "cloud0", bw=1e9, latency=5e-3),
               cm.Link("cloud0", "edge0", bw=kv_link_bw, latency=5e-3)])


def main():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = zoo.init_params(cfg, 0)
    engine = ServeEngine(cfg, params, batch_size=2, max_len=32)
    graph = serving_graph(engine, prompt_len=24, max_new_tokens=4)
    print("== serving as a split op graph ==")
    for c in graph.costs():
        print(f"  {c.name:8s} flops/ev={c.flops_per_event:10.3g} "
              f"state={c.state_bytes / 1e3:7.1f}KB "
              f"downlink_ok={c.downlink_ok}")
    print(f"  frontiers: {sorted(sorted(f) for f in graph.frontiers())}")

    # -- placement: mem_cap exclusion and the forced split ----------------
    obj = Objective()
    print("\n== placement DP prices KV state against mem_cap ==")
    tiny = build_cluster(edge_mem=1e3)       # KV cache cannot fit
    plan, _ = place_frontier(graph, tiny, 1e3, obj, method="dp")
    print(f"  edge mem 1KB  -> {plan.assignment} (edge excluded)")
    assert plan.assignment == {"prefill": "cloud0", "decode": "cloud0"}
    roomy = build_cluster()
    plan, frontier = place_frontier(graph, roomy, 3e3, obj, method="dp")
    print(f"  edge mem 4GB  -> {plan.assignment} at 3000 ev/s "
          f"(pod saturated: cloud-prefill/edge-decode)")
    assert plan.assignment == {"prefill": "cloud0", "decode": "edge0"}
    assert frontier == frozenset({"decode"})

    # -- execution parity: the graph run IS the engine --------------------
    print("\n== graph execution at {decode} vs ServeEngine: bitwise ==")
    prompts = [np.arange(1, 9, dtype=np.int32) + i for i in range(2)]
    ref_eng = ServeEngine(cfg, params, batch_size=2, max_len=32, seed=0)
    reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
    ref_eng.run(reqs)
    ref = np.array([r.out_tokens for r in reqs])
    states = graph.init_states()
    batch = serve_wave_batch(engine, prompts, seed=0)
    states, out = graph.run(states, batch, frontier)
    got = np.asarray(out["out_tokens"])
    assert np.array_equal(ref, got), (ref, got)
    print(f"  out_tokens match: {got.tolist()}")

    # -- the KV codec ladder ----------------------------------------------
    print("\n== KV codec ladder (per-payload bound, stateless) ==")
    for c in KV_CODECS:
        print(f"  {c.name:10s} wire ratio={c.ratio:.3f} "
              f"error bound={c.error_bound:.4f}")

    # -- saturating decode ramp: SLA-governed KV compression --------------
    # The serving SLA (a latency target + an error budget wide enough to
    # admit the lossy KV codecs) drives the controller's escalate/
    # de-escalate loop: as the offered rate ramps, the plan migrates to
    # cloud-prefill/edge-decode, the KV downlink saturates, and codec
    # re-admission escalates the cache compression; the migration itself
    # is priced (state bytes over the old->new link).
    print("\n== saturating decode ramp: KV codec escalation ==")
    sla = SLA(max_latency_s=0.5, error_budget=0.8)
    ctl = OffloadController(
        graph.costs(), roomy, obj, graph=graph, codec="identity",
        sla_spec=sla, codec_candidates=[c.name for c in KV_CODECS],
        cooldown=1, codec_cooldown=2)
    ramp = [1e3] * 3 + [1.8e3, 2.4e3, 3.2e3] + [3.2e3] * 3 + [1e3] * 4
    ctl.initial_plan(ramp[0])
    for step, rate in enumerate(ramp):
        d = ctl.observe(step, rate)
        if d.reason != "hold":
            mig = (f" moved={len(d.migration.moves)} ops "
                   f"({d.migration.bytes / 1e3:.0f}KB, "
                   f"{d.migration.seconds * 1e3:.1f}ms)"
                   if d.migration.moves else "")
            print(f"  step {step:2d}: rate={rate:6.0f} -> {d.reason:9s} "
                  f"codec={d.codec:10s} "
                  f"edge={sorted(d.frontier) or ['-']}{mig}")
    traj = [d.codec for d in ctl.history]
    compact = [traj[0]] + [b for a, b in zip(traj, traj[1:]) if a != b]
    print(f"  codec trajectory: {' -> '.join(compact)}")
    assert any(c != "identity" for c in traj), \
        "the saturating ramp must escalate the KV codec at least once"

    # -- train as an Op: same substrate, same DP --------------------------
    print("\n== train step as a placement-priced op ==")
    top = dl_train_op(cfg, adamw(1e-3), batch_size=4, seq_len=64)
    from repro.core.pipeline import OpGraph
    tplan, _ = place_frontier(OpGraph([top]), roomy, 1e3, obj, method="dp")
    print(f"  {top.name}: state={top.cost.state_bytes / 1e6:.2f}MB "
          f"edge_capable={top.cost.edge_capable} "
          f"-> {tplan.assignment}")
    assert tplan.assignment[top.name] == "cloud0"

    print("\nOK")


if __name__ == "__main__":
    main()
