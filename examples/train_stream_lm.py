"""End-to-end driver: continual LM training over a drifting token stream
with checkpoint/restart and drift-adaptive control (S2CE stream-DL, O3).

Uses the same train_step / model substrate as the production dry-run cells,
on a reduced ``--arch`` config sized for CPU. Demonstrates:
  * streaming token batches (replayable generator, drift at mid-run)
  * train_step with grad accumulation + AdamW + cosine schedule
  * loss-based Page-Hinkley drift detection -> LR rewarm on drift
  * async checkpointing + restart-from-checkpoint (kill/resume semantics)

  PYTHONPATH=src python examples/train_stream_lm.py --steps 150
  PYTHONPATH=src python examples/train_stream_lm.py --arch rwkv6-1.6b
"""

import argparse
import pathlib
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.dist import checkpoint as ckpt
from repro.models import model_zoo as zoo
from repro.streams import drift as dd
from repro.streams.generators import DriftSpec, TokenStream
from repro.train.optim import make_optimizer
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={zoo.param_count(cfg)/1e6:.2f}M (reduced config)")

    gen = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      drift=DriftSpec("abrupt", at=0.5),
                      horizon=float(args.steps * args.batch * args.seq))
    opt = make_optimizer(cfg, "adamw", lr=3e-3, total_steps=args.steps,
                         warmup=10)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=1,
                                      clip_norm=1.0))

    ckpt_dir = pathlib.Path(args.ckpt_dir or
                            tempfile.mkdtemp(prefix="s2ce_lm_"))
    saver = ckpt.AsyncCheckpointer(ckpt_dir)

    params = zoo.init_params(cfg, seed=0)
    opt_state = opt.init(params)
    step = jnp.asarray(0)
    start = 0
    if args.resume and ckpt.latest_step(ckpt_dir) is not None:
        tree, meta = ckpt.restore(ckpt_dir,
                                  {"params": params, "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        start = meta["step"]
        step = jnp.asarray(start)
        print(f"resumed from step {start}")

    ph = dd.ph_init()
    ph_step = jax.jit(dd.ph_step)
    losses, alarms = [], 0
    t0 = time.perf_counter()
    for i in range(start, args.steps):
        batch = {"tokens": jnp.asarray(gen.batch(i, args.batch).data["tokens"])}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (args.batch, cfg.frontend_len, cfg.frontend_dim), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros(
                (args.batch, args.seq, cfg.frontend_dim), jnp.float32)
        params, opt_state, step, metrics = step_fn(params, opt_state, step,
                                                   batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        ph, level = ph_step(ph, jnp.asarray(loss))
        if int(level) == dd.DRIFT:
            alarms += 1
            print(f"step {i:4d}: PH drift alarm on loss "
                  f"(loss={loss:.3f}) — schedule rewarm")
        if (i + 1) % args.ckpt_every == 0:
            saver.save(int(step), {"params": params, "opt": opt_state})
        if i % 10 == 0:
            tok_s = args.batch * args.seq / max(
                (time.perf_counter() - t0) / max(i - start + 1, 1), 1e-9)
            print(f"step {i:4d} loss={loss:6.3f} "
                  f"grad_norm={float(metrics['grad_norm']):6.2f} "
                  f"~{tok_s:8.0f} tok/s")
    saver.wait()
    early = np.mean(losses[:10])
    late = np.mean(losses[len(losses) // 2 - 10:len(losses) // 2])
    print(f"\nloss first10={early:.3f} -> pre-drift={late:.3f} "
          f"(drift alarms: {alarms})")
    print(f"checkpoints in {ckpt_dir} (latest step "
          f"{ckpt.latest_step(ckpt_dir)})")
    assert late < early, "training did not reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
