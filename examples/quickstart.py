"""Quickstart: the S2CE orchestrator on a drifting synthetic stream.

Runs the full paper pipeline on CPU in ~30s: synthetic drifting stream ->
edge preprocessing (normalize/sample/sketch) -> cloud online learning with
DDM drift detection -> SLA-monitored offload decisions.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core.orchestrator import Orchestrator, StreamJob
from repro.streams.generators import DriftSpec, HyperplaneStream


def main():
    job = StreamJob("quickstart", dim=16, drift_detector="ddm",
                    sample_rate=0.8)
    orch = Orchestrator(job)

    gen = HyperplaneStream(
        dim=16, seed=0,
        drift=DriftSpec(kind="abrupt", at=0.5, magnitude=2.0),
        horizon=80 * 128.0)
    batches = [gen.batch(i, 128) for i in range(80)]

    print("running 80 batches (abrupt concept drift at batch 40)...")
    m = orch.run(batches)

    print(f"\nevents processed : {m.events}")
    print(f"drift alarms     : {m.drift_alarms}")
    print(f"plan changes     : {m.migrations}")
    print(f"prequential      : {m.preq}")
    print(f"sla              : {m.sla}")
    print(f"decisions        : {m.decisions[:5]}")
    assert m.preq["ewma_accuracy"] > 0.6, "model failed to recover from drift"
    print("\nOK — drift detected and model recovered (ewma accuracy "
          f"{m.preq['ewma_accuracy']:.2f})")


if __name__ == "__main__":
    main()
