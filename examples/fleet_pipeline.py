"""Multi-tenant fleet scheduling over one shared ClusterSpec: admission
control, residual-capacity pricing, fleet-batched replan arbitration,
and mid-run tenant churn (core/fleet — S2CE's "many concurrent ML/DL
workloads" axis).

Three tenants share one edge+cloud topology:

* ``dl`` — a high-priority (tier 0) streaming DL job with a tight-ish
  latency SLA and a real demand for the uplink,
* ``sketch_a`` / ``sketch_b`` — two best-effort (tier 2) sketch
  pipelines with loose SLAs.

The fleet admits tenants against the RESIDUAL capacity their peers have
left (each admitted tenant books a slice of every pool and link in the
fleet ledger), rejects-and-queues a tenant whose best feasible plan
cannot meet its SLA, batches all replans into one arbitration pass per
round (priority tiers, per-tenant cooldowns — no stampede), and on a
departure immediately re-attempts admission for the queue.

  PYTHONPATH=src python examples/fleet_pipeline.py
"""

from repro.core import costmodel as cm
from repro.core.fleet import FleetOrchestrator, TenantSpec
from repro.core.orchestrator import StreamJob
from repro.core.sla import SLA
from repro.streams.generators import DriftSpec, HyperplaneStream


def build_cluster() -> cm.ClusterSpec:
    """One gateway edge pool + one cloud pod, with a deliberately
    modest uplink so the tenants actually contend for it, and a per-byte
    transmit energy so arbitration can trade latency against radio
    energy."""
    return cm.ClusterSpec(
        pools=[cm.EDGE_NODE, cm.CLOUD_POD],
        links=[cm.Link("edge", "cloud", bw=2e6, latency=20e-3,
                       energy_per_byte=3e-7)])


def main():
    spec = build_cluster()
    fleet = FleetOrchestrator(spec)

    # -- admission: one DL tenant, two sketch tenants ----------------------
    print("== admission ==")
    tenants = [
        (TenantSpec("dl", priority=0, demand_rate=4e4, replan_cooldown=2,
                    sla=SLA(max_latency_s=2.0, error_budget=0.5)),
         StreamJob("dl", dim=32, workers=2)),
        (TenantSpec("sketch_a", priority=2, demand_rate=1e4,
                    sla=SLA(max_latency_s=10.0, error_budget=11.0)),
         StreamJob("sketch_a", dim=8)),
        (TenantSpec("sketch_b", priority=2, demand_rate=1e4,
                    sla=SLA(max_latency_s=10.0, error_budget=11.0)),
         StreamJob("sketch_b", dim=8)),
    ]
    for i, (tspec, job) in enumerate(tenants):
        res = fleet.add_tenant(tspec, job, seed=i)
        state = "ADMITTED" if res.admitted else (
            "QUEUED" if res.queued else "REJECTED")
        print(f"  {tspec.name:10s} tier={tspec.priority} "
              f"rate={tspec.demand_rate:g} -> {state}")
        if not res.admitted:
            print(f"      reason: {res.reason}")

    # a hog that cannot fit is rejected LOUDLY and queued for capacity
    hog = fleet.add_tenant(
        TenantSpec("hog", priority=1, demand_rate=1e9,
                   sla=SLA(max_latency_s=10.0, error_budget=11.0)),
        StreamJob("hog", dim=8))
    print(f"  {'hog':10s} tier=1 rate=1e+09 -> "
          f"{'QUEUED' if hog.queued else 'REJECTED'}")
    print(f"      reason: {hog.reason}")

    print("\n  ledger after admission:")
    for pool, f in fleet.scheduler.ledger.pool_load().items():
        print(f"    pool {pool:6s} {f * 100:6.2f}% booked")
    for (src, dst), b in fleet.scheduler.ledger.link_load().items():
        cap = fleet.scheduler.ledger.spec.link(src, dst).bw
        print(f"    link {src}->{dst} {b:,.0f} / {cap:,.0f} B/s "
              f"({b / cap * 100:.1f}%)")

    # -- fleet rounds: execute + one arbitration pass per round ------------
    print("\n== 6 fleet rounds (round-robin, batched arbitration) ==")
    # offered rates pinned to the declared demand (the rate_fn analogue)
    # so the printed control trajectory reflects load, not CPU wall-clock
    demand = {"dl": 4e4, "sketch_a": 1e4, "sketch_b": 1e4}
    gens = {
        "dl": HyperplaneStream(dim=32, seed=1,
                               drift=DriftSpec("gradual", at=0.5, width=0.3),
                               horizon=6 * 64.0),
        "sketch_a": HyperplaneStream(dim=8, seed=2, horizon=6 * 64.0),
        "sketch_b": HyperplaneStream(dim=8, seed=3, horizon=6 * 64.0),
    }
    for step in range(3):
        fleet.step_round({n: gens[n].batch(step, 64)
                          for n in fleet.orchestrators},
                         rates=demand)

    # -- churn: a sketch tenant departs mid-run ----------------------------
    m, readmits = fleet.leave("sketch_b")
    print(f"  sketch_b left after {m.events} events "
          f"(migrations={m.migrations}); capacity returned")
    if readmits:
        for r in readmits:
            print(f"  re-admitted from queue: {r.name}")
    else:
        print(f"  queue after departure: {fleet.scheduler.queued} "
              "(hog still does not fit)")

    for step in range(3, 6):
        fleet.step_round({n: gens[n].batch(step, 64)
                          for n in fleet.orchestrators},
                         rates=demand)

    # -- wrap-up -----------------------------------------------------------
    print("\n== per-tenant metrics ==")
    for name, metrics in fleet.finish().items():
        print(f"  {name:10s} events={metrics.events:4d} "
              f"codec={metrics.codecs[-1]:13s} "
              f"migrations={metrics.migrations} "
              f"viol_rate={metrics.sla['violation_rate']:.2f}")
    print("\n  scheduler audit log:")
    for line in fleet.scheduler.log:
        print(f"    {line}")
    bad = fleet.scheduler.ledger.check()
    print(f"\n  ledger capacity invariants: {'OK' if not bad else bad}")


if __name__ == "__main__":
    main()
