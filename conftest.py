"""Repo-level pytest bootstrap.

Must run before jax is imported: forces 8 host-platform CPU devices so
mesh-aware tests (dist, sharded train step) exercise real multi-device
layouts on CPU, and puts ``src/`` on sys.path so a plain ``pytest``
works without PYTHONPATH=src.
"""

import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
_cur = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _cur:
    os.environ["XLA_FLAGS"] = f"{_cur} {_FLAG}".strip()

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

import pytest  # noqa: E402  (after the XLA/env bootstrap above)


@pytest.fixture(autouse=True)
def _reset_kernel_dispatch_counts():
    """Test isolation for the module-level kernel-dispatch counters in
    ``repro.streams.sketches``: they accumulate across tests, so any
    assertion on ``dispatch_counts()`` was order-dependent (passing
    alone, failing after another test had already dispatched). Reset
    before every test; import lazily so tests that never touch the
    streams package don't pay for (or trigger) the jax import."""
    sk = sys.modules.get("repro.streams.sketches")
    if sk is not None:
        sk.reset_dispatch_counts()
    yield
